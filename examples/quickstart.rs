//! Quickstart: the neuron-chunking pipeline in ~60 lines.
//!
//! 1. Pick a device profile (Jetson Orin Nano + P31 SSD).
//! 2. Profile the flash once to build the `T[s]` latency table (§3.1).
//! 3. Generate a smooth VLM importance vector (what frame-append
//!    activations look like, §2.2).
//! 4. Select neurons with conventional top-k vs utility-guided chunk
//!    selection (§3.2) and compare estimated I/O latency.
//!
//! Run: `cargo run --release --example quickstart`

use neuron_chunking::latency::ContiguityDistribution;
use neuron_chunking::report::fmt_secs;
use neuron_chunking::sparsify::{ChunkSelect, ChunkSelectConfig, Selector, TopK};
use neuron_chunking::storage::{DeviceProfile, ProfileConfig, Profiler, SimulatedSsd};
use neuron_chunking::workload::ActivationGen;

fn main() -> anyhow::Result<()> {
    // A Qwen2-7B down-projection: 18944 neurons, 7 KB rows (fp16).
    let rows = 18944;
    let row_bytes = 3584 * 2;

    // (1) + (2): device + one-time latency profile.
    let profile = DeviceProfile::nano();
    let device = SimulatedSsd::timing_only(profile.clone(), 1 << 40, 1);
    let sat = profile.saturation_bytes(0.99);
    let table = Profiler::new(&device, ProfileConfig::coarse(sat, row_bytes))
        .build_table()?
        .with_row_bytes(row_bytes);
    println!(
        "profiled {}: saturation at {} KB, 4 KB chunk costs {}",
        profile.name,
        sat / 1024,
        fmt_secs(table.latency_bytes(4096)),
    );

    // (3): a frame's neuron-importance vector (smooth, like real VLMs).
    let importance = ActivationGen::vlm(rows, 196, 0.5, 42).sample(0);
    let budget = rows / 2; // 50% sparsity

    // (4): compare policies.
    for (name, selector) in [
        ("top-k (baseline)", Box::new(TopK) as Box<dyn Selector>),
        (
            "neuron chunking",
            Box::new(ChunkSelect::new(ChunkSelectConfig::new(
                36.0, // chunk_sz_start_in_kb (paper Table 2 for this shape)
                36.0, // jump_cap_in_kb
                sat as f64 / 1024.0,
            ))),
        ),
    ] {
        let sel = selector.select(&importance, budget, &table);
        let dist = ContiguityDistribution::from_chunks(&sel.chunks);
        println!(
            "{name:>18}: {:>5} chunks, mean chunk {:>6.1} rows, \
             importance {:>5.1}%, est. I/O {}",
            dist.num_chunks(),
            dist.mean_chunk(),
            100.0 * sel.captured_importance(&importance)
                / importance.iter().map(|&v| v as f64).sum::<f64>(),
            fmt_secs(table.estimate_chunks(&sel.chunks)),
        );
    }
    println!(
        "\nChunking trades a little importance for far fewer, larger reads —\n\
         the accuracy–latency trade-off of the paper's Fig 6."
    );
    Ok(())
}
