//! End-to-end edge serving driver — the full-system validation run
//! recorded in EXPERIMENTS.md.
//!
//! Loads the runnable `small` transformer (weights generated, written to
//! the simulated flash device, and streamed back on demand), serves
//! batched multi-stream traffic (frame appends + decode steps) through
//! the priority scheduler, and reports:
//!   * per-request latency (median + p95) split into I/O / compute /
//!     selection / host,
//!   * sustained throughput (frames/s),
//!   * output fidelity vs the dense model (relative L2 error),
//! for dense, top-k and neuron-chunking policies.
//!
//! Run: `cargo run --release --example edge_serving [frames_per_stream]`

use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Instant;

use neuron_chunking::coordinator::{Engine, Policy, Request, Scheduler, SchedulerConfig};
use neuron_chunking::report::{fmt_secs, Table};
use neuron_chunking::sparsify::ChunkSelectConfig;
use neuron_chunking::stats;
use neuron_chunking::storage::DeviceProfile;
use neuron_chunking::workload::FrameTrace;

const STREAMS: usize = 2;

fn main() -> anyhow::Result<()> {
    let frames: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(6);
    let artifacts = PathBuf::from(
        std::env::var("NC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let profile = DeviceProfile::nano();
    let sat_kb = profile.saturation_bytes(0.99) as f64 / 1024.0;

    // Dense reference outputs, computed once.
    let spec = neuron_chunking::model::ModelSpec::small();
    let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, frames + 1, 23);
    println!(
        "edge serving: model=small ({} layers, d={}, {:.1} MB weights on flash), \
         {STREAMS} streams x {frames} frames + decode",
        spec.layers,
        spec.d,
        spec.total_bytes() as f64 / 1e6
    );
    let dense_outputs = {
        let eng = Engine::builder("small")
            .profile(profile.clone())
            .artifacts(&artifacts)
            .build()?;
        let session = eng.new_session();
        let mut outs = Vec::new();
        for f in 0..frames {
            outs.push(session.append_frame(&trace.frame(f))?.0);
        }
        outs
    };

    let mut summary = Table::new(
        "edge serving summary (per frame-append request)",
        &[
            "policy", "med_io", "med_compute", "med_select", "med_e2e", "p95_e2e",
            "frames/s", "MB/frame", "rel_err_vs_dense",
        ],
    );

    let cases: Vec<(&str, Policy, f64)> = vec![
        ("dense", Policy::Dense, 0.0),
        ("topk", Policy::TopK, 0.4),
        (
            "chunking",
            Policy::Chunking {
                config: ChunkSelectConfig::new(2.0, 2.0, sat_kb),
            },
            0.4,
        ),
    ];
    for (label, policy, sparsity) in cases {
        let profile = profile.clone();
        let artifacts = artifacts.clone();
        let policy2 = policy.clone();
        let sched = Scheduler::spawn(SchedulerConfig::default(), move || {
            let e = Engine::builder("small")
                .policy(policy2)
                .sparsity(sparsity)
                .profile(profile)
                .artifacts(&artifacts)
                .build()
                .expect("engine");
            e.warmup().expect("warmup");
            e
        });

        // Submit multi-stream traffic in rounds. Decode steps go in only
        // after the round's appends complete — decodes preempt queued
        // appends (scheduler priority), so submitting them earlier would
        // race ahead of the KV state they depend on.
        let t0 = Instant::now();
        let mut per_kind: HashMap<&str, Vec<f64>> = HashMap::new();
        let mut io = Vec::new();
        let mut comp = Vec::new();
        let mut sel = Vec::new();
        let mut bytes = Vec::new();
        let mut outputs: Vec<Vec<f32>> = Vec::new();
        let mut collect = |kind: &'static str,
                           rxs: Vec<std::sync::mpsc::Receiver<
            neuron_chunking::coordinator::Completion,
        >>,
                           per_kind: &mut HashMap<&str, Vec<f64>>|
         -> anyhow::Result<()> {
            for rx in rxs {
                let c = rx.recv()?;
                let out = c.output.map_err(|e| anyhow::anyhow!(e))?;
                per_kind
                    .entry(kind)
                    .or_default()
                    .push(c.stats.end_to_end().as_secs_f64());
                if kind == "append" {
                    io.push(c.stats.io.as_secs_f64());
                    comp.push(c.stats.compute.as_secs_f64());
                    sel.push(c.stats.select.as_secs_f64());
                    bytes.push(c.stats.bytes_loaded as f64);
                    if c.stream == 0 {
                        outputs.push(out);
                    }
                }
            }
            Ok(())
        };
        for f in 0..frames {
            let rxs: Vec<_> = (0..STREAMS)
                .map(|stream| {
                    sched
                        .submit(Request::prefill(stream, trace.frame(f)))
                        .map_err(anyhow::Error::from)
                })
                .collect::<anyhow::Result<_>>()?;
            collect("append", rxs, &mut per_kind)?;
            // A decode step per stream every other frame (interactive user).
            if f % 2 == 1 {
                let rxs: Vec<_> = (0..STREAMS)
                    .map(|stream| {
                        sched
                            .submit(Request::decode(stream, vec![0.05; spec.d]))
                            .map_err(anyhow::Error::from)
                    })
                    .collect::<anyhow::Result<_>>()?;
                collect("decode", rxs, &mut per_kind)?;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        sched.shutdown();

        // Fidelity vs dense (stream 0's appends arrive in order).
        let rel_err = if label == "dense" {
            0.0
        } else {
            let mut errs = Vec::new();
            for (got, want) in outputs.iter().zip(&dense_outputs) {
                let num: f64 = got
                    .iter()
                    .zip(want.iter())
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
                    .sqrt();
                let den: f64 = want.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
                errs.push(num / den.max(1e-12));
            }
            stats::mean(&errs)
        };

        let appends = &per_kind["append"];
        summary.row(vec![
            label.into(),
            fmt_secs(stats::median(&io)),
            fmt_secs(stats::median(&comp)),
            fmt_secs(stats::median(&sel)),
            fmt_secs(stats::median(appends)),
            fmt_secs(stats::percentile(appends, 95.0)),
            format!("{:.2}", (frames * STREAMS) as f64 / wall),
            format!("{:.1}", stats::mean(&bytes) / 1e6),
            format!("{rel_err:.4}"),
        ]);
        if let Some(decodes) = per_kind.get("decode") {
            println!(
                "  [{label}] decode median {} over {} steps",
                fmt_secs(stats::median(decodes)),
                decodes.len()
            );
        }
    }
    println!("\n{}", summary.render());
    println!(
        "I/O latency is simulated (nano profile); compute/select are real\n\
         wall time through the XLA CPU runtime. Chunking cuts I/O versus\n\
         top-k at the same sparsity with bounded extra output error."
    );
    Ok(())
}
