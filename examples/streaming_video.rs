//! Streaming-video scenario: the paper's motivating workload (§1).
//!
//! A video stream is appended frame-by-frame into a flash-offloaded VLM
//! (paper-scale matrix shapes, I/O simulated on the calibrated Jetson
//! profiles). We compare the per-frame I/O latency of conventional top-k
//! sparsification against neuron chunking at the same effective sparsity,
//! and check both against the frame budget of a 1 FPS stream.
//!
//! Run: `cargo run --release --example streaming_video [nano|agx]`

use neuron_chunking::experiments::{IoPolicy, PaperRig, RigConfig};
use neuron_chunking::model::ModelSpec;
use neuron_chunking::report::{fmt_secs, Table};
use neuron_chunking::stats;
use neuron_chunking::storage::DeviceProfile;
use neuron_chunking::workload::{AccuracyModel, DatasetSpec};

fn main() -> anyhow::Result<()> {
    let device = std::env::args().nth(1).unwrap_or_else(|| "nano".into());
    let profile = DeviceProfile::by_name(&device)
        .ok_or_else(|| anyhow::anyhow!("unknown device {device}"))?;
    let model = ModelSpec::llava_7b();
    println!(
        "streaming into {} on {} ({} of weights on flash)…",
        model.name,
        profile.name,
        format!("{:.1} GB", model.total_bytes() as f64 / 1e9),
    );
    let rig = PaperRig::new(
        model,
        profile,
        RigConfig {
            calib_samples: 16,
            tokens_per_frame: 0,
            seed: 7,
        },
    )?;
    let dataset = DatasetSpec::tempcompass();
    let acc_model = AccuracyModel::new(dataset.clone());
    let sparsity = 0.5;
    let budgets = rig.budgets(sparsity);
    let scale = rig.spec.layers as f64 / rig.layers.len() as f64;

    let frames = 12u64;
    let mut t = Table::new(
        &format!("per-frame I/O at sparsity {sparsity} (proxy accuracy in parens)"),
        &["frame", "top-k", "chunking", "speedup"],
    );
    let mut speedups = Vec::new();
    for f in 0..frames {
        let mut io = [0.0f64; 2];
        let mut kept = [0.0f64; 2];
        let mut total = [0.0f64; 2];
        for (i, policy) in [IoPolicy::TopK, IoPolicy::Chunking].iter().enumerate() {
            for ls in &rig.layers {
                let r = rig.frame_layer_io(policy, ls.layer, 500 + f, &budgets)?;
                io[i] += r.io_seconds * scale;
                kept[i] += r.kept;
                total[i] += r.total;
            }
        }
        speedups.push(io[0] / io[1]);
        t.row(vec![
            format!("{f}"),
            format!(
                "{} ({:.3})",
                fmt_secs(io[0]),
                acc_model.score(kept[0] / total[0])
            ),
            format!(
                "{} ({:.3})",
                fmt_secs(io[1]),
                acc_model.score(kept[1] / total[1])
            ),
            format!("{:.2}x", io[0] / io[1]),
        ]);
    }
    println!("{}", t.render());
    println!(
        "median I/O speedup {:.2}x at the same effective sparsity.",
        stats::median(&speedups)
    );
    Ok(())
}
