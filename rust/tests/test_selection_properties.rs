//! Property tests on selection policies — the invariants the whole
//! coordinator relies on, over adversarial random inputs (in-tree
//! property harness; see `neuron_chunking::proptest`).

use neuron_chunking::latency::{chunks_from_mask, ContiguityDistribution};
use neuron_chunking::proptest::{arb_importance, arb_latency_table, check};
use neuron_chunking::sparsify::{
    Bundling, ChunkSelect, ChunkSelectConfig, Selector, Threshold, TopK,
};

fn all_selectors(rng: &mut neuron_chunking::rng::Rng) -> Vec<Box<dyn Selector>> {
    vec![
        Box::new(TopK),
        Box::new(Threshold::new(rng.f32())),
        Box::new(ChunkSelect::new(ChunkSelectConfig::new(
            1.0 + rng.f64() * 15.0,
            1.0 + rng.f64() * 15.0,
            32.0 + rng.f64() * 300.0,
        ))),
        Box::new(Bundling::new(rng.range(1, 4))),
    ]
}

#[test]
fn prop_budget_never_exceeded() {
    check("budget never exceeded", 120, |rng| {
        let imp = arb_importance(rng, 512);
        let table = arb_latency_table(rng);
        let budget = rng.below(imp.len() + 8);
        for sel in all_selectors(rng) {
            let m = sel.select(&imp, budget, &table);
            if m.rows() > budget.min(imp.len()) {
                return Err(format!(
                    "{} selected {} > budget {}",
                    sel.name(),
                    m.rows(),
                    budget
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mask_and_chunks_consistent() {
    check("mask/chunks consistency", 120, |rng| {
        let imp = arb_importance(rng, 512);
        let table = arb_latency_table(rng);
        let budget = rng.below(imp.len() + 1);
        for sel in all_selectors(rng) {
            let m = sel.select(&imp, budget, &table);
            if m.chunks != chunks_from_mask(&m.mask) {
                return Err(format!("{}: chunks != mask runs", sel.name()));
            }
            if m.mask.len() != imp.len() {
                return Err(format!("{}: mask length mismatch", sel.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_chunks_sorted_disjoint() {
    check("chunks sorted and disjoint", 120, |rng| {
        let imp = arb_importance(rng, 600);
        let table = arb_latency_table(rng);
        let budget = rng.below(imp.len() + 1);
        for sel in all_selectors(rng) {
            let m = sel.select(&imp, budget, &table);
            for w in m.chunks.windows(2) {
                if w[0].end() > w[1].start {
                    return Err(format!("{}: overlapping/unsorted chunks", sel.name()));
                }
            }
            if m.chunks.iter().any(|c| c.end() > imp.len()) {
                return Err(format!("{}: chunk out of range", sel.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_topk_importance_dominates_all() {
    // Top-k is optimal on captured importance at equal row count.
    check("topk dominance", 80, |rng| {
        let imp = arb_importance(rng, 400);
        let table = arb_latency_table(rng);
        let budget = rng.range(1, imp.len());
        let topk = TopK.select(&imp, budget, &table);
        for sel in all_selectors(rng) {
            let m = sel.select(&imp, budget, &table);
            // Compare at the row count the other selector achieved.
            let fair = TopK.select(&imp, m.rows().max(1), &table);
            if m.captured_importance(&imp) > fair.captured_importance(&imp) + 1e-3 {
                return Err(format!(
                    "{} captured more importance than top-k at equal rows",
                    sel.name()
                ));
            }
        }
        let _ = topk;
        Ok(())
    });
}

#[test]
fn prop_chunking_never_worse_estimated_latency_per_row() {
    // At equal row counts, chunk selection's estimated latency must not
    // exceed top-k's (it optimizes the latency term top-k ignores).
    check("chunking latency advantage", 60, |rng| {
        let imp = arb_importance(rng, 512);
        if imp.len() < 32 {
            return Ok(());
        }
        let table = arb_latency_table(rng);
        let budget = rng.range(8, imp.len());
        let cs = ChunkSelect::new(ChunkSelectConfig::new(2.0, 4.0, 128.0));
        let ours = cs.select(&imp, budget, &table);
        let base = TopK.select(&imp, ours.rows().max(1), &table);
        let ours_lat = table.estimate_chunks(&ours.chunks) / ours.rows().max(1) as f64;
        let base_lat = table.estimate_chunks(&base.chunks) / base.rows().max(1) as f64;
        if ours_lat > base_lat * 1.05 {
            return Err(format!(
                "chunking per-row latency {ours_lat} > topk {base_lat}"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_full_budget_selects_everything() {
    check("full budget", 60, |rng| {
        let imp = arb_importance(rng, 256);
        let table = arb_latency_table(rng);
        for sel in [
            Box::new(TopK) as Box<dyn Selector>,
            Box::new(ChunkSelect::new(ChunkSelectConfig::new(1.0, 2.0, 64.0))),
        ] {
            let m = sel.select(&imp, imp.len(), &table);
            if m.rows() != imp.len() {
                return Err(format!(
                    "{} selected {}/{} at full budget",
                    sel.name(),
                    m.rows(),
                    imp.len()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_latency_model_additivity() {
    // L(chunks A ∪ B) = L(A) + L(B) for disjoint chunk sets — the §3.1
    // additive assumption the selector exploits.
    check("latency additivity", 100, |rng| {
        let table = arb_latency_table(rng);
        let n = rng.range(16, 256);
        let mut mask = vec![false; n];
        for i in 0..n {
            mask[i] = rng.bool(0.4);
        }
        let chunks = chunks_from_mask(&mask);
        let total = table.estimate_chunks(&chunks);
        let split = rng.below(chunks.len().max(1));
        let sum = table.estimate_chunks(&chunks[..split])
            + table.estimate_chunks(&chunks[split..]);
        if (total - sum).abs() > 1e-12 * total.max(1.0) {
            return Err(format!("non-additive: {total} vs {sum}"));
        }
        Ok(())
    });
}

#[test]
fn prop_contiguity_distribution_conserves_rows() {
    check("distribution row conservation", 150, |rng| {
        let n = rng.range(1, 512);
        let density = rng.f64();
        let mask: Vec<bool> = (0..n).map(|_| rng.bool(density)).collect();
        let d = ContiguityDistribution::from_mask(&mask);
        let selected = mask.iter().filter(|&&b| b).count() as u64;
        if d.num_rows() != selected {
            return Err(format!("{} != {}", d.num_rows(), selected));
        }
        let from_iter: u64 = d.iter().map(|(s, c)| s as u64 * c).sum();
        if from_iter != selected {
            return Err("iter() row count mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_reorder_permutation_preserves_importance_multiset() {
    use neuron_chunking::reorder::HotColdReorder;
    check("reorder preserves values", 60, |rng| {
        let n = rng.range(4, 128);
        let mut samples: Vec<Vec<f32>> = Vec::with_capacity(6);
        for _ in 0..6 {
            samples.push((0..n).map(|_| rng.f32()).collect());
        }
        let perm = HotColdReorder.build(&samples, n);
        let imp: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let re = perm.apply(&imp);
        let mut a = imp.clone();
        let mut b = re.clone();
        a.sort_by(|x, y| x.partial_cmp(y).unwrap());
        b.sort_by(|x, y| x.partial_cmp(y).unwrap());
        if a != b {
            return Err("permutation changed the value multiset".into());
        }
        // Round trip.
        if perm.apply_inv(&re) != imp {
            return Err("apply_inv does not invert apply".into());
        }
        Ok(())
    });
}

#[test]
fn prop_teal_budgets_within_rows() {
    use neuron_chunking::sparsify::teal::{MatrixCalibration, SparsityAllocator};
    check("teal budgets bounded", 40, |rng| {
        let nm = rng.range(1, 6);
        let cals: Vec<MatrixCalibration> = (0..nm)
            .map(|i| MatrixCalibration {
                name: format!("m{i}"),
                rows: rng.range(16, 4096),
                samples: (0..200).map(|_| rng.f32()).collect(),
            })
            .collect();
        let rows: Vec<usize> = cals.iter().map(|c| c.rows).collect();
        let alloc = SparsityAllocator::new(cals);
        let target = rng.f64() * 0.9;
        for (b, r) in alloc.budgets(target).iter().zip(&rows) {
            if b > r {
                return Err(format!("budget {b} > rows {r}"));
            }
        }
        Ok(())
    });
}
