//! Quantized chunk storage (int8/fp16) end-to-end: the dtype knob must
//! leave the f32 path bit-identical, make every quantized path
//! deterministic, strictly shrink flash traffic, keep output error
//! bounded by the storage format's rounding, and stay bit-identical
//! across the RAM-cache on/off toggle (cached rows re-encode through the
//! same codec as flash rows).

use std::path::PathBuf;

use neuron_chunking::coordinator::{Engine, Policy};
use neuron_chunking::model::DType;
use neuron_chunking::sparsify::ChunkSelectConfig;
use neuron_chunking::workload::FrameTrace;

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn builder(policy: Policy, sparsity: f64) -> neuron_chunking::coordinator::EngineBuilder {
    Engine::builder("tiny")
        .policy(policy)
        .sparsity(sparsity)
        .prefetch(true)
        .exec_threads(1)
        .artifacts(&artifact_dir())
}

fn chunking() -> Policy {
    Policy::Chunking {
        config: ChunkSelectConfig::new(2.0, 2.0, 348.0),
    }
}

/// Two appends + two decodes; returns the outputs and the exact
/// (bytes_loaded, importance_kept) selection observables per call.
fn run(engine: &Engine) -> (Vec<Vec<f32>>, Vec<(u64, f64)>) {
    let spec = engine.spec();
    let session = engine.new_session();
    let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 4, 11);
    let mut outs = Vec::new();
    let mut sels = Vec::new();
    for i in 0..2 {
        let (y, s) = session.append_frame(&trace.frame(i)).unwrap();
        outs.push(y);
        sels.push((s.bytes_loaded, s.importance_kept));
    }
    let token = vec![0.03f32; spec.d];
    for _ in 0..2 {
        let (y, s) = session.decode_step(&token).unwrap();
        outs.push(y);
        sels.push((s.bytes_loaded, s.importance_kept));
    }
    (outs, sels)
}

#[test]
fn f32_knob_is_bit_identical_to_default() {
    // Explicitly requesting f32 must be indistinguishable from the
    // pre-knob default build: same outputs, same selections, same bytes.
    if std::env::var("NC_DTYPE").is_ok() {
        return; // the harness pinned the default this test is about
    }
    for (policy, sparsity) in [(Policy::Dense, 0.0), (chunking(), 0.5)] {
        let default_build = builder(policy.clone(), sparsity).build().unwrap();
        let explicit = builder(policy.clone(), sparsity)
            .dtype(DType::F32)
            .build()
            .unwrap();
        assert_eq!(explicit.dtype(), DType::F32);
        assert_eq!(run(&default_build), run(&explicit), "policy={policy:?}");
    }
}

#[test]
fn quantized_runs_are_deterministic() {
    // Same build twice → bit-identical outputs and selections per dtype.
    for dtype in [DType::F16, DType::Int8] {
        for (policy, sparsity) in [(Policy::Dense, 0.0), (chunking(), 0.5)] {
            let a = builder(policy.clone(), sparsity).dtype(dtype).build().unwrap();
            let b = builder(policy.clone(), sparsity).dtype(dtype).build().unwrap();
            assert_eq!(a.dtype(), dtype);
            assert_eq!(run(&a), run(&b), "dtype={dtype:?} policy={policy:?}");
        }
    }
}

#[test]
fn quantized_dense_bytes_strictly_shrink() {
    // Dense reads every row, so flash traffic per call is exactly the
    // layout's encoded footprint: int8 < fp16 < f32, strictly.
    let mut per_dtype = Vec::new();
    for dtype in [DType::F32, DType::F16, DType::Int8] {
        let engine = builder(Policy::Dense, 0.0).dtype(dtype).build().unwrap();
        let (_, sels) = run(&engine);
        let bytes: u64 = sels.iter().map(|&(b, _)| b).sum();
        assert!(bytes > 0, "dtype={dtype:?} loaded nothing");
        per_dtype.push(bytes);
    }
    assert!(
        per_dtype[2] < per_dtype[1] && per_dtype[1] < per_dtype[0],
        "bytes not strictly shrinking: f32={} fp16={} int8={}",
        per_dtype[0],
        per_dtype[1],
        per_dtype[2]
    );
    // fp16 is exactly half of f32 (2 vs 4 bytes per element).
    assert_eq!(per_dtype[1] * 2, per_dtype[0]);
}

#[test]
fn sparse_repricing_still_shrinks_bytes() {
    // Under chunk selection the utility denominator is repriced to the
    // encoded row width, so the selected sets may differ across dtypes —
    // but with a fixed row budget the narrower encoding must still load
    // strictly fewer bytes per step.
    let mut per_dtype = Vec::new();
    for dtype in [DType::F32, DType::F16, DType::Int8] {
        let engine = builder(chunking(), 0.5).dtype(dtype).build().unwrap();
        let (_, sels) = run(&engine);
        per_dtype.push(sels.iter().map(|&(b, _)| b).sum::<u64>());
    }
    assert!(
        per_dtype[2] < per_dtype[1] && per_dtype[1] < per_dtype[0],
        "sparse bytes not strictly shrinking: f32={} fp16={} int8={}",
        per_dtype[0],
        per_dtype[1],
        per_dtype[2]
    );
}

/// Max |a - b| over flattened output sequences of equal shape.
fn max_delta(a: &[Vec<f32>], b: &[Vec<f32>]) -> f32 {
    let mut d = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.len(), y.len(), "output shapes diverged");
        for (&u, &v) in x.iter().zip(y) {
            assert!(u.is_finite() && v.is_finite(), "non-finite output");
            d = d.max((u - v).abs());
        }
    }
    d
}

fn max_abs(a: &[Vec<f32>]) -> f32 {
    a.iter()
        .flat_map(|v| v.iter())
        .fold(0.0f32, |m, &v| m.max(v.abs()))
}

#[test]
fn quantized_output_error_is_bounded() {
    // Dequantize-on-gather means quantized outputs differ from f32 only
    // by the storage format's rounding error through the forward pass.
    // fp16 carries ~2^-11 relative weight error, int8 ~0.4% of each
    // row's max — both bounds below are an order of magnitude above the
    // expected accumulated error but far below signal scale.
    let f32_engine = builder(Policy::Dense, 0.0).dtype(DType::F32).build().unwrap();
    let (base, _) = run(&f32_engine);
    let scale = max_abs(&base);
    assert!(scale > 0.0, "degenerate f32 reference");
    for (dtype, rel_bound) in [(DType::F16, 0.02f32), (DType::Int8, 0.25f32)] {
        let engine = builder(Policy::Dense, 0.0).dtype(dtype).build().unwrap();
        let (outs, _) = run(&engine);
        let delta = max_delta(&base, &outs);
        assert!(
            delta <= rel_bound * scale,
            "dtype={dtype:?} max |delta| {delta} exceeds {} (= {rel_bound} x max |f32| {scale})",
            rel_bound * scale
        );
        assert!(delta > 0.0, "dtype={dtype:?} suspiciously exact (codec bypassed?)");
    }
}

#[test]
fn chunk_cache_composes_bit_identically_with_quantized_storage() {
    // Cached rows are stored encoded and re-encoded through the same
    // codec as the flash image, so serving with the RAM cache on must be
    // bit-identical to cache-off at every dtype — including after
    // maintenance passes admit entries mid-stream.
    for dtype in [DType::F32, DType::F16, DType::Int8] {
        let plain = builder(chunking(), 0.5).dtype(dtype).build().unwrap();
        let cached = builder(chunking(), 0.5)
            .dtype(dtype)
            .cache_mb(4)
            .build()
            .unwrap();
        let spec = plain.spec();
        let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 4, 11);
        let sp = plain.new_session();
        let sc = cached.new_session();
        let token = vec![0.03f32; spec.d];
        let mut outs_plain = Vec::new();
        let mut outs_cached = Vec::new();
        for i in 0..2 {
            outs_plain.push(sp.append_frame(&trace.frame(i)).unwrap().0);
            outs_cached.push(sc.append_frame(&trace.frame(i)).unwrap().0);
        }
        for round in 0..4 {
            outs_plain.push(sp.decode_step(&token).unwrap().0);
            outs_cached.push(sc.decode_step(&token).unwrap().0);
            if round == 1 {
                // Populate the cache from live frequency mid-stream.
                cached.maintain_cache().unwrap();
            }
        }
        assert_eq!(
            outs_plain, outs_cached,
            "dtype={dtype:?}: cache-on diverged from cache-off"
        );
        // The cache actually held entries (the toggle was exercised).
        let m = cached.metrics();
        assert!(m.bytes("cache.admissions") > 0, "dtype={dtype:?}: cache never admitted");
    }
}

#[test]
fn per_dtype_io_counter_tracks_total() {
    // The per-dtype bytes counter mirrors `io` exactly — same fold sites,
    // same increments — giving `/metrics` a dtype-keyed traffic series.
    for (dtype, key) in [
        (DType::F32, "io.bytes_f32"),
        (DType::F16, "io.bytes_fp16"),
        (DType::Int8, "io.bytes_int8"),
    ] {
        let engine = builder(chunking(), 0.5).dtype(dtype).build().unwrap();
        run(&engine);
        let m = engine.metrics();
        assert_eq!(m.bytes(key), m.bytes("io"), "dtype={dtype:?}");
        assert!(m.bytes(key) > 0, "dtype={dtype:?} counter never bumped");
    }
}
