//! Fault tolerance: replicated stripes, retries, hedged reads, and
//! degraded-mode serving.
//!
//! The kill-test contract (DESIGN.md §9): with hot-stripe replication,
//! a pool that loses a member keeps serving every replica-covered
//! extent **bit-identical** to the healthy pool — replication changes
//! where a byte is read, never the byte — while requests touching
//! extents held only by the corpse fail with a typed
//! [`PoolError::Uncovered`], never a panic or a hang.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use neuron_chunking::coordinator::{Engine, Policy};
use neuron_chunking::latency::Chunk;
use neuron_chunking::model::{MatrixId, MatrixKind, ModelSpec, WeightStore};
use neuron_chunking::plan::{CoalescePolicy, IoPlanner, PlanReceipt, ReadPlan, ShardedPlan};
use neuron_chunking::storage::{
    DevicePool, DeviceProfile, Extent, FaultConfig, FaultInjector, FlashDevice, HedgeConfig,
    PoolError, PoolStats, SimulatedSsd, StripeLayout, StripePolicy, READ_ATTEMPTS,
};
use neuron_chunking::workload::FrameTrace;

fn store() -> WeightStore {
    WeightStore::new(ModelSpec::tiny(), false, 42)
}

fn replicated_pool(s: &WeightStore, image: &[u8], devices: usize, r: usize) -> DevicePool {
    let stripe =
        StripeLayout::build_replicated(&s.layout, devices, StripePolicy::RoundRobin, None, r);
    DevicePool::simulated(&vec![DeviceProfile::nano(); devices], stripe, image, 7).unwrap()
}

/// Wrap member `m` in a [`FaultInjector`] with the given config.
fn inject(pool: &mut DevicePool, m: usize, cfg: FaultConfig) {
    pool.wrap_members(|i, inner| {
        if i == m {
            Arc::new(FaultInjector::new(inner, cfg.clone()))
        } else {
            inner
        }
    });
}

/// Route + submit one plan through the pool's replica-aware path.
fn submit_routed(pool: &DevicePool, plan: &ReadPlan) -> anyhow::Result<PlanReceipt> {
    let mut sharded = ShardedPlan::default();
    pool.route_plan(plan, &mut sharded);
    let mut staging = Vec::new();
    let mut receipt = PlanReceipt::default();
    let mut stats = PoolStats::default();
    pool.submit_sharded_into(plan, &sharded, &mut staging, &mut receipt, &mut stats)?;
    Ok(receipt)
}

#[test]
fn dead_member_serves_replica_covered_extents_bit_identical() {
    let s = store();
    let image = s.build_image();
    let planner = IoPlanner::new(CoalescePolicy::contiguous());
    // The region head lands in the hot (replicated) stripe blocks.
    let plan = planner.plan_chunks(
        &s.layout,
        MatrixId::new(0, MatrixKind::Gate),
        &[Chunk::new(0, 8), Chunk::new(12, 4)],
        None,
    );
    let dead = [true, false, false, false];
    let healthy = replicated_pool(&s, &image, 4, 2);
    assert!(
        healthy.stripe().covered_without(plan.cmds(), &dead),
        "test plan must be replica-covered with member 0 dead"
    );
    let want = submit_routed(&healthy, &plan).unwrap();
    // Same pool, but member 0 is a corpse from the first read on.
    let mut degraded = replicated_pool(&s, &image, 4, 2);
    inject(&mut degraded, 0, FaultConfig { dead: true, ..FaultConfig::default() });
    let got = submit_routed(&degraded, &plan).unwrap();
    assert_eq!(
        got.bytes, want.bytes,
        "degraded pool must serve replica-covered extents bit-identical"
    );
    // Both equal the flat single-device read of the same plan.
    let flat = SimulatedSsd::with_image(DeviceProfile::nano(), image.clone(), 5);
    assert_eq!(want.bytes, flat.submit(&plan).unwrap().bytes);
    // The death was absorbed through the retry → mark-dead → failover
    // ladder and is visible in the health snapshot.
    let h = degraded.health().snapshot();
    assert_eq!(h.dead_members, vec![0]);
    assert!(h.retries >= READ_ATTEMPTS as u64 - 1, "retries {}", h.retries);
    assert!(h.failovers >= 1, "death must be absorbed via failover");
    assert!(h.degraded());
    assert!(!healthy.health().snapshot().degraded());
}

#[test]
fn uncovered_extents_fail_with_typed_error() {
    let s = store();
    let image = s.build_image();
    let planner = IoPlanner::new(CoalescePolicy::contiguous());
    let mut degraded = replicated_pool(&s, &image, 4, 2);
    inject(&mut degraded, 0, FaultConfig { dead: true, ..FaultConfig::default() });
    // Find a row whose only copy lives on member 0 (a cold single-copy
    // stripe block) by scanning the layout.
    let dead = [true, false, false, false];
    let mut uncovered = None;
    'scan: for (rid, _base, _row_bytes, rows) in s.layout.regions_in_order() {
        for r in 0..rows {
            let plan = planner.plan_chunks(&s.layout, rid, &[Chunk::new(r, 1)], None);
            if !degraded.stripe().covered_without(plan.cmds(), &dead) {
                uncovered = Some(plan);
                break 'scan;
            }
        }
    }
    let plan = uncovered.expect("tiny layout has cold single-copy blocks on member 0");
    let err = submit_routed(&degraded, &plan).unwrap_err();
    assert_eq!(
        err.downcast_ref::<PoolError>(),
        Some(&PoolError::Uncovered { member: 0 }),
        "uncovered extents must fail with a typed error, got: {err:#}"
    );
    // A second submission fails just as cleanly — degraded mode is a
    // steady state, not a one-shot.
    let err2 = submit_routed(&degraded, &plan).unwrap_err();
    assert!(err2.downcast_ref::<PoolError>().is_some(), "{err2:#}");
}

#[test]
fn hedged_submit_recovers_from_straggler_and_counts_hedges() {
    // A wall-clock member that stalls every read by 25ms gets hedged:
    // its commands are re-issued to the replica after the hedge floor,
    // the replica's bytes win, and the result is still bit-exact.
    let s = store();
    let image = s.build_image();
    let stripe = StripeLayout::build_replicated(&s.layout, 2, StripePolicy::RoundRobin, None, 2);
    let shards = stripe.shard_image(&image);
    let dir = std::env::temp_dir().join(format!("nc_hedge_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let paths: Vec<PathBuf> = shards
        .iter()
        .enumerate()
        .map(|(m, data)| {
            let p = dir.join(format!("member{m}.img"));
            std::fs::write(&p, data).unwrap();
            p
        })
        .collect();
    let mut pool = DevicePool::from_files(&paths, stripe, 2, false)
        .unwrap()
        .with_hedge(HedgeConfig { factor: 4.0, floor: Duration::from_micros(500) });
    inject(
        &mut pool,
        0,
        FaultConfig {
            spike_rate: 1.0,
            spike: Duration::from_millis(25),
            ..FaultConfig::default()
        },
    );
    let planner = IoPlanner::new(CoalescePolicy::contiguous());
    let plan = planner.plan_chunks(
        &s.layout,
        MatrixId::new(0, MatrixKind::Up),
        &[Chunk::new(0, 16)],
        None,
    );
    assert!(
        pool.stripe().covered_without(plan.cmds(), &[true, false]),
        "hedge test plan must be replica-covered"
    );
    let got = submit_routed(&pool, &plan).unwrap();
    let flat = SimulatedSsd::with_image(DeviceProfile::nano(), image.clone(), 5);
    assert_eq!(got.bytes, flat.submit(&plan).unwrap().bytes, "hedged read corrupted bytes");
    let h = pool.health().snapshot();
    assert!(h.hedges >= 1, "straggling member never got hedged: {h:?}");
    assert!(h.hedge_wins >= 1, "replica re-issue should beat a 25ms stall: {h:?}");
    assert!(h.dead_members.is_empty(), "a straggler is slow, not dead");
    for p in paths {
        std::fs::remove_file(p).ok();
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn engine_with_dead_member_degrades_to_typed_errors() {
    // Dense serving touches cold single-copy extents, so an engine that
    // loses a pool member must answer with clean typed errors — never a
    // panic or a hang — and report the death through its health
    // snapshot.
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let engine = Engine::builder("tiny")
        .policy(Policy::Dense)
        .sparsity(0.0)
        .devices(4)
        .replication(2)
        .exec_threads(1)
        .async_io(false)
        .artifacts(&artifacts)
        .build()
        .unwrap();
    assert_eq!(engine.replication(), 2);
    let _handle = engine.inject_faults(0, FaultConfig { dead: true, ..FaultConfig::default() });
    let spec = engine.spec();
    let session = engine.new_session();
    let frame = FrameTrace::new(spec.d, spec.tokens_per_frame, 4, 11).frame(0);
    let err = session.append_frame(&frame).unwrap_err();
    assert!(
        matches!(err.downcast_ref::<PoolError>(), Some(PoolError::Uncovered { .. })),
        "dense request over a dead member must surface a typed error, got: {err:#}"
    );
    let h = engine.pool_health();
    assert_eq!(h.dead_members, vec![0]);
    assert!(h.degraded());
    // Still no panic on repeat traffic; the engine stays answerable.
    let err2 = session.append_frame(&frame).unwrap_err();
    assert!(err2.downcast_ref::<PoolError>().is_some(), "{err2:#}");
    // Health counters surface through the metrics seam the server
    // exposes on /metrics and in every response's "engine" object.
    let m = engine.metrics();
    assert_eq!(m.bytes("pool.dead"), 1);
    assert!(m.bytes("io.retries") >= READ_ATTEMPTS as u64 - 1);
}

#[test]
fn replicated_healthy_pool_matches_unreplicated_bit_identical() {
    // Replication must be invisible when nothing fails: same bytes as
    // an unreplicated pool and as the flat image, across several plans.
    let s = store();
    let image = s.build_image();
    let planner = IoPlanner::new(CoalescePolicy::contiguous());
    let plain = replicated_pool(&s, &image, 4, 1);
    let replicated = replicated_pool(&s, &image, 4, 2);
    for (layer, kind) in [(0, MatrixKind::Gate), (0, MatrixKind::Up), (1, MatrixKind::Down)] {
        let plan = planner.plan_chunks(
            &s.layout,
            MatrixId::new(layer, kind),
            &[Chunk::new(0, 4), Chunk::new(8, 2)],
            None,
        );
        let mut sharded = ShardedPlan::default();
        planner.shard_into(&plan, plain.stripe(), &mut sharded);
        let mut staging = Vec::new();
        let mut receipt = PlanReceipt::default();
        let mut stats = PoolStats::default();
        plain
            .submit_sharded_into(&plan, &sharded, &mut staging, &mut receipt, &mut stats)
            .unwrap();
        let routed = submit_routed(&replicated, &plan).unwrap();
        assert_eq!(
            routed.bytes, receipt.bytes,
            "replication changed served bytes for layer {layer} {kind:?}"
        );
    }
    // Replica copies inflate per-member images, never the logical space.
    assert!(
        replicated.stripe().device_bytes().iter().sum::<u64>()
            > plain.stripe().device_bytes().iter().sum::<u64>(),
        "replication must store extra copies"
    );
    assert_eq!(replicated.stripe().total_bytes(), plain.stripe().total_bytes());
}

#[test]
fn extent_scatter_hits_every_member_boundary() {
    // Replicated routing still covers every byte exactly once: route an
    // extent spanning many stripe blocks and check full reassembly.
    let s = store();
    let image = s.build_image();
    let pool = replicated_pool(&s, &image, 4, 3);
    let e = Extent::new(64, 16_384.min(image.len() - 64));
    let (bytes, _) = pool.read_batch_vec(&[e]).unwrap();
    assert_eq!(&bytes[..], &image[e.offset as usize..e.end() as usize]);
}
