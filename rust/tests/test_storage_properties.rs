//! Property tests on the storage substrate and latency model: physical
//! sanity of the simulator, the profile→table→estimate pipeline, and the
//! I/O planning layer (coverage, ordering, alignment).

use neuron_chunking::latency::chunks_from_mask;
use neuron_chunking::model::{FlashLayout, MatrixId, ModelSpec};
use neuron_chunking::plan::{
    CoalescePolicy, FuseScratch, FusedPlan, IoPlanner, PlanReceipt, PlanRequest, PlannedRead,
    ReadPlan, ShardedPlan,
};
use neuron_chunking::proptest::check;
use neuron_chunking::storage::{
    DevicePool, DeviceProfile, Extent, FlashDevice, PoolStats, ProfileConfig, Profiler,
    SimulatedSsd, StripeLayout, StripePolicy,
};

fn arb_profile(rng: &mut neuron_chunking::rng::Rng) -> DeviceProfile {
    match rng.below(3) {
        0 => DeviceProfile::nano(),
        1 => DeviceProfile::agx(),
        _ => DeviceProfile::macbook(),
    }
}

#[test]
fn prop_service_time_positive_and_monotone_in_volume() {
    check("service time monotone in volume", 60, |rng| {
        let dev = SimulatedSsd::timing_only(arb_profile(rng), 1 << 40, 7);
        let n = rng.range(1, 64);
        let size = rng.range(1, 64) * 1024;
        let mk = |count: usize| -> Vec<Extent> {
            (0..count)
                .map(|i| Extent::new((i * size * 2) as u64, size))
                .collect()
        };
        let t1 = dev.model_service_seconds(&mk(n), 1.0);
        let t2 = dev.model_service_seconds(&mk(n * 2), 1.0);
        if t1 <= 0.0 {
            return Err("non-positive service time".into());
        }
        if t2 < t1 {
            return Err(format!("doubling volume reduced time: {t1} -> {t2}"));
        }
        Ok(())
    });
}

#[test]
fn prop_merging_adjacent_extents_never_slower_at_depth() {
    // Coalescing adjacent reads must never be slower *at saturating
    // concurrency* — the physical fact chunking exploits. (At queue depth
    // 1-2 the thread pool can genuinely beat a single serial read by
    // splitting it, so the property is asserted on deep batches.)
    check("merge never slower at depth", 80, |rng| {
        let dev = SimulatedSsd::timing_only(arb_profile(rng), 1 << 40, 7);
        let a = rng.range(1, 128) * 1024;
        let b = rng.range(1, 128) * 1024;
        let copies = 32u64;
        let stride = (2 * (a + b)) as u64;
        let mut split = Vec::new();
        let mut merged = Vec::new();
        for i in 0..copies {
            let off = i * stride;
            split.push(Extent::new(off, a));
            split.push(Extent::new(off + a as u64, b));
            merged.push(Extent::new(off, a + b));
        }
        let ts = dev.model_service_seconds(&split, 1.0);
        let tm = dev.model_service_seconds(&merged, 1.0);
        if tm > ts * 1.0001 {
            return Err(format!("merged {tm} > split {ts}"));
        }
        Ok(())
    });
}

#[test]
fn prop_throughput_bounded_by_peak() {
    check("throughput <= peak", 60, |rng| {
        let profile = arb_profile(rng);
        let peak = profile.peak_bw;
        let dev = SimulatedSsd::timing_only(profile, 1 << 40, 9);
        let n = rng.range(1, 256);
        let size = rng.range(1, 512) * 1024;
        let extents: Vec<Extent> = (0..n)
            .map(|i| Extent::new((i * size * 2) as u64, size))
            .collect();
        let t = dev.model_service_seconds(&extents, 1.0);
        let tput = (n * size) as f64 / t;
        if tput > peak * 1.001 {
            return Err(format!("throughput {tput} exceeds peak {peak}"));
        }
        Ok(())
    });
}

#[test]
fn prop_profiled_table_monotone_nondecreasing() {
    check("profiled table monotone", 6, |rng| {
        let profile = arb_profile(rng);
        let dev = SimulatedSsd::timing_only(profile.clone(), 1 << 40, rng.next_u64());
        let table = Profiler::new(
            &dev,
            ProfileConfig::coarse(profile.saturation_bytes(0.99), 1024),
        )
        .build_table()
        .map_err(|e| e.to_string())?;
        let mut prev = 0.0;
        let mut kb = 4;
        while kb * 1024 <= table.max_bytes() {
            let l = table.latency_bytes(kb * 1024);
            if l + 1e-15 < prev {
                return Err(format!("latency dropped at {kb} KB"));
            }
            prev = l;
            kb += 4;
        }
        Ok(())
    });
}

#[test]
fn prop_estimate_scales_with_fragmentation() {
    // Same rows, more fragments -> higher estimated latency.
    check("fragmentation raises estimate", 50, |rng| {
        let profile = arb_profile(rng);
        let dev = SimulatedSsd::timing_only(profile.clone(), 1 << 40, 3);
        let table = Profiler::new(
            &dev,
            ProfileConfig::coarse(profile.saturation_bytes(0.99), 4096),
        )
        .build_table()
        .map_err(|e| e.to_string())?;
        let rows = rng.range(16, 128);
        let one = [neuron_chunking::latency::Chunk::new(0, rows)];
        let frag: Vec<neuron_chunking::latency::Chunk> = (0..rows)
            .map(|i| neuron_chunking::latency::Chunk::new(i * 2, 1))
            .collect();
        let l_one = table.estimate_chunks(&one);
        let l_frag = table.estimate_chunks(&frag);
        if l_frag < l_one {
            return Err(format!("fragmented {l_frag} < contiguous {l_one}"));
        }
        Ok(())
    });
}

#[test]
fn prop_image_reads_roundtrip() {
    check("image read round trip", 30, |rng| {
        let size = rng.range(4096, 1 << 16);
        let image: Vec<u8> = (0..size).map(|_| rng.below(256) as u8).collect();
        let dev = SimulatedSsd::with_image(DeviceProfile::nano(), image.clone(), 5);
        let n = rng.range(1, 8);
        let extents: Vec<Extent> = (0..n)
            .map(|_| {
                let len = rng.range(1, 64);
                let off = rng.below(size - len);
                Extent::new(off as u64, len)
            })
            .collect();
        let (bytes, _) = dev.read_batch_vec(&extents).map_err(|e| e.to_string())?;
        let mut at = 0;
        for e in &extents {
            let want = &image[e.offset as usize..e.offset as usize + e.len];
            if &bytes[at..at + e.len] != want {
                return Err(format!("mismatch at extent {e:?}"));
            }
            at += e.len;
        }
        Ok(())
    });
}

// ------------------------------------------------------ planning layer

/// Random chunk demands for a random subset of one layer's matrices.
fn arb_requests(
    rng: &mut neuron_chunking::rng::Rng,
    spec: &ModelSpec,
) -> Vec<PlanRequest> {
    let mut requests = Vec::new();
    for m in spec.matrices() {
        if rng.bool(0.4) {
            continue; // not every matrix participates
        }
        let mask: Vec<bool> = (0..m.rows).map(|_| rng.bool(0.3)).collect();
        let chunks = chunks_from_mask(&mask);
        if !chunks.is_empty() {
            requests.push(PlanRequest::new(MatrixId::new(0, m.kind), chunks));
        }
    }
    requests
}

#[test]
fn prop_plan_covers_exactly_the_selected_bytes() {
    // The plan's payload equals the selected rows' bytes, and submitting
    // it returns exactly the image bytes of every selected row.
    check("plan covers selected rows", 25, |rng| {
        let spec = ModelSpec::tiny();
        let store = neuron_chunking::model::WeightStore::new(spec.clone(), false, 7);
        let image = store.build_image();
        let dev = SimulatedSsd::with_image(DeviceProfile::nano(), image.clone(), 3);
        let requests = arb_requests(rng, &spec);
        let planner = IoPlanner::new(CoalescePolicy::contiguous());
        let plan = planner.plan(&store.layout, &requests, None);
        plan.validate().map_err(|e| e.to_string())?;
        let want_payload: u64 = requests
            .iter()
            .map(|r| {
                let rb = store.layout.row_bytes(r.id) as u64;
                r.chunks.iter().map(|c| c.len as u64 * rb).sum::<u64>()
            })
            .sum();
        if plan.payload_bytes() != want_payload {
            return Err(format!(
                "payload {} != selected bytes {}",
                plan.payload_bytes(),
                want_payload
            ));
        }
        let receipt = dev.submit(&plan).map_err(|e| e.to_string())?;
        let read = PlannedRead { plan, receipt };
        for r in &requests {
            let rb = store.layout.row_bytes(r.id);
            for c in &r.chunks {
                for row in c.start..c.end() {
                    let got = read
                        .row_data(r.id, row)
                        .ok_or_else(|| format!("row {row} of {:?} uncovered", r.id))?;
                    let off = store.layout.row_offset(r.id, row) as usize;
                    if got != &image[off..off + rb] {
                        return Err(format!("row {row} of {:?} bytes differ", r.id));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_plan_extents_sorted_and_disjoint() {
    check("plan extents sorted/disjoint", 40, |rng| {
        let spec = ModelSpec::tiny();
        let layout = FlashLayout::build(&spec, false);
        let merge = rng.bool(0.5);
        let planner = IoPlanner::new(CoalescePolicy {
            merge_adjacent: merge,
            page_bytes: 0,
            max_batch: [0usize, 3, 16][rng.below(3)],
        });
        let plan = planner.plan(&layout, &arb_requests(rng, &spec), None);
        plan.validate().map_err(|e| e.to_string())?;
        for w in plan.cmds().windows(2) {
            if w[0].end() > w[1].offset {
                return Err(format!("overlapping cmds {:?} {:?}", w[0], w[1]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_plan_page_alignment_respected_for_aligned_layouts() {
    check("plan page alignment", 25, |rng| {
        let spec = ModelSpec::tiny();
        let layout = FlashLayout::build(&spec, true); // 4 KiB-aligned rows
        let planner = IoPlanner::new(CoalescePolicy {
            merge_adjacent: rng.bool(0.5),
            page_bytes: 4096,
            max_batch: 0,
        });
        let requests = arb_requests(rng, &spec);
        let plan = planner.plan(&layout, &requests, None);
        plan.validate().map_err(|e| e.to_string())?;
        for c in plan.cmds() {
            if c.offset % 4096 != 0 || c.len % 4096 != 0 {
                return Err(format!("unaligned cmd {c:?}"));
            }
        }
        // Alignment may widen commands but never drops payload.
        let want_payload: u64 = requests
            .iter()
            .map(|r| {
                let rb = layout.row_bytes(r.id) as u64;
                r.chunks.iter().map(|c| c.len as u64 * rb).sum::<u64>()
            })
            .sum();
        if plan.payload_bytes() != want_payload {
            return Err("alignment changed payload".into());
        }
        if plan.cmd_bytes() < plan.payload_bytes() {
            return Err("commands smaller than payload".into());
        }
        Ok(())
    });
}

#[test]
fn prop_sharded_pool_submit_matches_single_device() {
    // Stripe round-trip identity: shard a logical plan across a pool,
    // submit per device, and the reassembled PlanReceipt must be
    // bit-identical to a single-device submission — for random chunk
    // demands, random coalesce/stripe settings, and 1/2/4 members.
    check("stripe round-trip identity", 12, |rng| {
        let spec = ModelSpec::tiny();
        let store = neuron_chunking::model::WeightStore::new(spec.clone(), false, 11);
        let image = store.build_image();
        let flat = SimulatedSsd::with_image(DeviceProfile::nano(), image.clone(), 3);
        let requests = arb_requests(rng, &spec);
        let planner = IoPlanner::new(if rng.bool(0.5) {
            CoalescePolicy::contiguous()
        } else {
            CoalescePolicy::passthrough()
        });
        let plan = planner.plan(&store.layout, &requests, None);
        let want = flat.submit(&plan).map_err(|e| e.to_string())?;
        for devices in [1usize, 2, 4] {
            let policy = if rng.bool(0.5) {
                StripePolicy::RoundRobin
            } else {
                StripePolicy::HotAware
            };
            let stripe_bytes = if rng.bool(0.5) {
                None
            } else {
                Some(rng.range(1, 16) * 1024)
            };
            let stripe = StripeLayout::build(&store.layout, devices, policy, stripe_bytes);
            let profiles = vec![DeviceProfile::nano(); devices];
            let pool = DevicePool::simulated(&profiles, stripe, &image, 3)
                .map_err(|e| e.to_string())?;
            let mut sharded = ShardedPlan::default();
            planner.shard_into(&plan, pool.stripe(), &mut sharded);
            if sharded.total_bytes() as u64 != plan.cmd_bytes() {
                return Err(format!(
                    "shards cover {} of {} bytes (n={devices})",
                    sharded.total_bytes(),
                    plan.cmd_bytes()
                ));
            }
            if devices == 1 && sharded.shards[0].cmds.as_slice() != plan.cmds() {
                return Err("1-member shard must reproduce the logical commands".into());
            }
            let mut receipt = PlanReceipt::default();
            let mut staging = Vec::new();
            let mut stats = PoolStats::default();
            pool.submit_sharded_into(&plan, &sharded, &mut staging, &mut receipt, &mut stats)
                .map_err(|e| e.to_string())?;
            if receipt.bytes != want.bytes {
                return Err(format!("receipt bytes differ at n={devices}"));
            }
            if receipt.cmd_offsets != want.cmd_offsets {
                return Err(format!("cmd offsets differ at n={devices}"));
            }
            if stats.total_bytes() != plan.cmd_bytes() {
                return Err(format!(
                    "per-device accounting {} != {} at n={devices}",
                    stats.total_bytes(),
                    plan.cmd_bytes()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fused_plan_covers_union_and_scatters_bit_identically() {
    // Fusion round-trip identity: random per-stream plans × {1, 2, 4}
    // streams → the fused command list covers exactly the union of the
    // streams' byte ranges (shared ranges once), and scattering one
    // fused submission through the subscriber copies reproduces every
    // stream's solo receipt bytes bit for bit.
    check("fusion round-trip identity", 12, |rng| {
        let spec = ModelSpec::tiny();
        let store = neuron_chunking::model::WeightStore::new(spec.clone(), false, 13);
        let image = store.build_image();
        let dev = SimulatedSsd::with_image(DeviceProfile::nano(), image.clone(), 3);
        let planner = IoPlanner::new(if rng.bool(0.5) {
            CoalescePolicy::contiguous()
        } else {
            CoalescePolicy::passthrough()
        });
        for streams in [1usize, 2, 4] {
            let plans: Vec<ReadPlan> = (0..streams)
                .map(|_| planner.plan(&store.layout, &arb_requests(rng, &spec), None))
                .collect();
            let solo: Vec<PlanReceipt> = plans
                .iter()
                .map(|p| dev.submit(p))
                .collect::<Result<_, _>>()
                .map_err(|e| e.to_string())?;
            let refs: Vec<&ReadPlan> = plans.iter().collect();
            let mut scratch = FuseScratch::default();
            let mut fused = FusedPlan::default();
            planner.fuse_into(&refs, None, &mut scratch, &mut fused);
            fused.plan.validate().map_err(|e| e.to_string())?;
            // Byte coverage equals the union of the stream extents
            // (touching ranges merged, like the fusion step itself).
            let mut spans: Vec<(u64, u64)> = plans
                .iter()
                .flat_map(|p| p.cmds().iter().map(|c| (c.offset, c.end())))
                .collect();
            spans.sort_unstable();
            let mut union: Vec<(u64, u64)> = Vec::new();
            for (lo, hi) in spans {
                match union.last_mut() {
                    Some(last) if lo <= last.1 => last.1 = last.1.max(hi),
                    _ => union.push((lo, hi)),
                }
            }
            let got: Vec<(u64, u64)> = fused
                .plan
                .cmds()
                .iter()
                .map(|c| (c.offset, c.end()))
                .collect();
            if got != union {
                return Err(format!(
                    "fused cover {got:?} != union {union:?} (n={streams})"
                ));
            }
            let union_bytes: u64 = union.iter().map(|(lo, hi)| hi - lo).sum();
            if fused.fused_bytes() != union_bytes {
                return Err(format!(
                    "fused bytes {} != union size {union_bytes}",
                    fused.fused_bytes()
                ));
            }
            let solo_total: u64 = plans.iter().map(|p| p.cmd_bytes()).sum();
            if fused.shared_bytes() != solo_total - union_bytes {
                return Err(format!(
                    "shared accounting {} != {} (n={streams})",
                    fused.shared_bytes(),
                    solo_total - union_bytes
                ));
            }
            // One fused submission scattered through the subscriber
            // copies == each stream's solo submission, bit for bit.
            let fused_receipt = dev.submit(&fused.plan).map_err(|e| e.to_string())?;
            for (i, want) in solo.iter().enumerate() {
                let mut got = vec![0u8; want.bytes.len()];
                for c in fused.copies.iter().filter(|c| c.stream == i) {
                    got[c.dst..c.dst + c.len]
                        .copy_from_slice(&fused_receipt.bytes[c.src..c.src + c.len]);
                }
                if got != want.bytes {
                    return Err(format!(
                        "stream {i} scattered bytes differ from solo (n={streams})"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_merged_plan_never_reads_less_than_payload() {
    // Merging coalesces touching extents; the device traffic can only
    // grow (gap swallowing), never shrink below the payload.
    check("merge conserves payload", 40, |rng| {
        let spec = ModelSpec::tiny();
        let layout = FlashLayout::build(&spec, false);
        let requests = arb_requests(rng, &spec);
        let merged =
            IoPlanner::new(CoalescePolicy::contiguous()).plan(&layout, &requests, None);
        let split =
            IoPlanner::new(CoalescePolicy::passthrough()).plan(&layout, &requests, None);
        if merged.payload_bytes() != split.payload_bytes() {
            return Err("policies disagree on payload".into());
        }
        if merged.cmd_bytes() < merged.payload_bytes() {
            return Err("merged cmds below payload".into());
        }
        if merged.num_cmds() > split.num_cmds() {
            return Err(format!(
                "merging increased command count: {} > {}",
                merged.num_cmds(),
                split.num_cmds()
            ));
        }
        Ok(())
    });
}
