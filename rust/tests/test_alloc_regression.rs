//! Allocation-regression guard: after one warm-up token, further
//! `decode_step` calls perform **zero heap allocations** on the serving
//! path.
//!
//! A counting global allocator flags every `alloc`/`alloc_zeroed` and
//! every growing `realloc` while armed. The engine's scratch arena,
//! pooled plans/receipts, `*_into` APIs and pre-reserved
//! selection-shape-dependent buffers are exactly what this test pins
//! down; any new per-token allocation on the hot path fails it.
//!
//! All configurations run inside one `#[test]` so the global counter is
//! never toggled from two test threads at once.

use std::alloc::{GlobalAlloc, Layout, System};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use neuron_chunking::coordinator::{DecodeRequest, Engine, Policy, StageStats};
use neuron_chunking::model::DType;
use neuron_chunking::sparsify::ChunkSelectConfig;
use neuron_chunking::workload::FrameTrace;

struct CountingAlloc;

static ARMED: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // Growing an existing buffer is an allocation for our purposes;
        // shrinks are not.
        if ARMED.load(Ordering::Relaxed) && new_size > layout.size() {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Build an engine, warm one session (frame + one token), then count heap
/// allocations across `steps` further decode steps. `devices > 1` runs
/// the sharded storage-pool path (simulated members fan out serially, so
/// pooling must stay allocation-free too); `async_io` runs the async
/// pipeline (virtual-clock members submit inline with analytic overlap
/// credit, which must also stay allocation-free).
#[allow(clippy::too_many_arguments)]
fn decode_allocs(
    policy: Policy,
    sparsity: f64,
    prefetch: bool,
    devices: usize,
    async_io: bool,
    dtype: DType,
    steps: usize,
) -> u64 {
    let engine = Engine::builder("tiny")
        .policy(policy)
        .sparsity(sparsity)
        .prefetch(prefetch)
        .exec_threads(1)
        .devices(devices)
        .async_io(async_io)
        .io_queue_depth(2)
        .dtype(dtype)
        .artifacts(&artifact_dir())
        .build()
        .unwrap();
    engine.warmup().unwrap();
    let spec = engine.spec();
    let session = engine.new_session();
    let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 2, 7);
    let mut out = Vec::new();
    session.append_frame_into(&trace.frame(0), &mut out).unwrap();
    let token = vec![0.08f32; spec.d];
    // One warm-up token grows every arena buffer to its high-water mark.
    session.decode_step_into(&token, &mut out).unwrap();

    ALLOCS.store(0, Ordering::SeqCst);
    ARMED.store(true, Ordering::SeqCst);
    for _ in 0..steps {
        session.decode_step_into(&token, &mut out).unwrap();
    }
    ARMED.store(false, Ordering::SeqCst);
    ALLOCS.load(Ordering::SeqCst)
}

/// Like [`decode_allocs`], but with the shared hot-chunk RAM cache
/// enabled and **warm**: a few unarmed tokens accumulate selection
/// frequency, a maintenance pass admits the hot rows (maintenance
/// allocates freely — it is off the serving path), and one more unarmed
/// token lets the now-hit-serving gather path reach its high-water mark.
/// Steady-state cached decode — shard read lock, run splitting, staging
/// into the arena, RAM-served gather — must then be allocation-free.
fn cached_decode_allocs(
    policy: Policy,
    sparsity: f64,
    prefetch: bool,
    devices: usize,
    dtype: DType,
    steps: usize,
) -> u64 {
    let engine = Engine::builder("tiny")
        .policy(policy)
        .sparsity(sparsity)
        .prefetch(prefetch)
        .exec_threads(1)
        .devices(devices)
        .cache_mb(64)
        .dtype(dtype)
        .artifacts(&artifact_dir())
        .build()
        .unwrap();
    engine.warmup().unwrap();
    let spec = engine.spec();
    let session = engine.new_session();
    let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 2, 7);
    let mut out = Vec::new();
    session.append_frame_into(&trace.frame(0), &mut out).unwrap();
    let token = vec![0.08f32; spec.d];
    for _ in 0..3 {
        session.decode_step_into(&token, &mut out).unwrap();
    }
    engine.maintain_cache().unwrap();
    session.decode_step_into(&token, &mut out).unwrap();
    // The warm cache must actually be serving rows, or this row would
    // silently regress into the uncached case.
    let warm_hits = engine.metrics().bytes("io.cache_hit_bytes");
    assert!(warm_hits > 0, "cache never served a row before arming");

    ALLOCS.store(0, Ordering::SeqCst);
    ARMED.store(true, Ordering::SeqCst);
    for _ in 0..steps {
        session.decode_step_into(&token, &mut out).unwrap();
    }
    ARMED.store(false, Ordering::SeqCst);
    ALLOCS.load(Ordering::SeqCst)
}

/// Build an engine with two sessions, warm both plus the batch arena,
/// then count heap allocations across `steps` fused batched decodes.
/// Steady-state batched decoding must be allocation-free too: the batch
/// arena is pooled in the engine core, fusion scratch and the fused
/// plan/receipt reuse capacity, and all batch bookkeeping is
/// stack-allocated.
fn batched_decode_allocs(policy: Policy, sparsity: f64, devices: usize, steps: usize) -> u64 {
    let engine = Engine::builder("tiny")
        .policy(policy)
        .sparsity(sparsity)
        .prefetch(true)
        .exec_threads(1)
        .devices(devices)
        .artifacts(&artifact_dir())
        .build()
        .unwrap();
    engine.warmup().unwrap();
    let spec = engine.spec();
    let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 2, 7);
    let s0 = engine.new_session();
    let s1 = engine.new_session();
    let mut out = Vec::new();
    s0.append_frame_into(&trace.frame(0), &mut out).unwrap();
    s1.append_frame_into(&trace.frame(1), &mut out).unwrap();
    let t0 = vec![0.08f32; spec.d];
    let t1 = vec![-0.04f32; spec.d];
    let mut outs: Vec<Vec<f32>> = vec![Vec::new(), Vec::new()];
    let mut stats = vec![StageStats::default(); 2];
    // Two warm-up batches grow the pooled batch arena and both members'
    // buffers to their high-water marks.
    for _ in 0..2 {
        let reqs = [
            DecodeRequest {
                session: &s0,
                token: &t0,
            },
            DecodeRequest {
                session: &s1,
                token: &t1,
            },
        ];
        engine.decode_batch_into(&reqs, &mut outs, &mut stats).unwrap();
    }

    ALLOCS.store(0, Ordering::SeqCst);
    ARMED.store(true, Ordering::SeqCst);
    for _ in 0..steps {
        let reqs = [
            DecodeRequest {
                session: &s0,
                token: &t0,
            },
            DecodeRequest {
                session: &s1,
                token: &t1,
            },
        ];
        engine.decode_batch_into(&reqs, &mut outs, &mut stats).unwrap();
    }
    ARMED.store(false, Ordering::SeqCst);
    ALLOCS.load(Ordering::SeqCst)
}

#[test]
fn steady_state_decode_is_allocation_free() {
    // One test body: the counting allocator is process-global state.
    // The `pool4` rows pin the acceptance criterion that sharded
    // multi-device serving stays allocation-free per decode step; the
    // `async` rows pin the same for the async I/O pipeline on
    // virtual-clock pools.
    let configs: Vec<(&str, Policy, f64, bool, usize, bool)> = vec![
        ("dense +pf", Policy::Dense, 0.0, true, 1, false),
        ("dense -pf", Policy::Dense, 0.0, false, 1, false),
        ("topk +pf", Policy::TopK, 0.5, true, 1, false),
        ("topk -pf", Policy::TopK, 0.5, false, 1, false),
        (
            "chunking +pf",
            Policy::Chunking {
                config: ChunkSelectConfig::new(2.0, 2.0, 348.0),
            },
            0.5,
            true,
            1,
            false,
        ),
        (
            "chunking -pf",
            Policy::Chunking {
                config: ChunkSelectConfig::new(2.0, 2.0, 348.0),
            },
            0.5,
            false,
            1,
            false,
        ),
        ("dense pool4", Policy::Dense, 0.0, true, 4, false),
        ("topk pool4", Policy::TopK, 0.5, true, 4, false),
        (
            "chunking pool4",
            Policy::Chunking {
                config: ChunkSelectConfig::new(2.0, 2.0, 348.0),
            },
            0.5,
            true,
            4,
            false,
        ),
        ("dense async", Policy::Dense, 0.0, true, 1, true),
        ("topk async", Policy::TopK, 0.5, true, 1, true),
        ("topk async pool4", Policy::TopK, 0.5, true, 4, true),
        (
            "chunking async pool4",
            Policy::Chunking {
                config: ChunkSelectConfig::new(2.0, 2.0, 348.0),
            },
            0.5,
            true,
            4,
            true,
        ),
    ];
    for (label, policy, sparsity, prefetch, devices, async_io) in configs {
        let allocs = decode_allocs(policy, sparsity, prefetch, devices, async_io, DType::F32, 8);
        assert_eq!(
            allocs, 0,
            "[{label}] decode_step allocated {allocs} times across 8 steady-state steps"
        );
    }
    // Quantized-storage rows: dequantize-on-gather decodes encoded rows
    // into the existing f32 arenas, so int8/fp16 serving must be exactly
    // as allocation-free as f32.
    for dtype in [DType::F16, DType::Int8] {
        for (label, policy, sparsity) in [
            ("dense", Policy::Dense, 0.0),
            ("topk", Policy::TopK, 0.5),
            (
                "chunking",
                Policy::Chunking {
                    config: ChunkSelectConfig::new(2.0, 2.0, 348.0),
                },
                0.5,
            ),
        ] {
            let allocs = decode_allocs(policy, sparsity, true, 1, false, dtype, 8);
            assert_eq!(
                allocs, 0,
                "[{label} {dtype:?}] decode_step allocated {allocs} times across 8 steps"
            );
        }
    }
    // Batched decode rows: the fused cross-stream path (plan fusion,
    // shared submission + scatter, cohort kernels) must also be
    // allocation-free at steady state, on single devices and pools.
    let batched: Vec<(&str, Policy, f64, usize)> = vec![
        ("batch topk", Policy::TopK, 0.5, 1),
        ("batch dense pool4", Policy::Dense, 0.0, 4),
        (
            "batch chunking",
            Policy::Chunking {
                config: ChunkSelectConfig::new(2.0, 2.0, 348.0),
            },
            0.5,
            1,
        ),
    ];
    for (label, policy, sparsity, devices) in batched {
        let allocs = batched_decode_allocs(policy, sparsity, devices, 8);
        assert_eq!(
            allocs, 0,
            "[{label}] decode_batch allocated {allocs} times across 8 steady-state batches"
        );
    }
    // Cached decode rows: with the shared hot-chunk RAM cache warm,
    // steady-state decode (frequency recording, residency subtraction,
    // staging, RAM-served gather) must stay allocation-free too.
    let cached: Vec<(&str, Policy, f64, bool, usize)> = vec![
        ("topk cached +pf", Policy::TopK, 0.5, true, 1),
        ("topk cached -pf", Policy::TopK, 0.5, false, 1),
        (
            "chunking cached pool4",
            Policy::Chunking {
                config: ChunkSelectConfig::new(2.0, 2.0, 348.0),
            },
            0.5,
            true,
            4,
        ),
    ];
    for (label, policy, sparsity, prefetch, devices) in cached {
        let allocs = cached_decode_allocs(policy, sparsity, prefetch, devices, DType::F32, 8);
        assert_eq!(
            allocs, 0,
            "[{label}] cached decode_step allocated {allocs} times across 8 steady-state steps"
        );
    }
    // Cached + quantized: staging decodes the cache's encoded bytes into
    // the arena per hit — still zero steady-state allocations.
    for dtype in [DType::F16, DType::Int8] {
        let allocs = cached_decode_allocs(Policy::TopK, 0.5, true, 1, dtype, 8);
        assert_eq!(
            allocs, 0,
            "[topk cached {dtype:?}] cached decode_step allocated {allocs} times across 8 steps"
        );
    }
}
