//! Cross-module integration tests: weight store + flash sim + selection +
//! runtime + engine composing into the full serving pipeline, plus the
//! experiment harness's qualitative guarantees (the DESIGN.md §7 success
//! criteria that don't need full figure runs).

use std::path::{Path, PathBuf};

use neuron_chunking::coordinator::{Engine, HotNeuronCache, Policy};
use neuron_chunking::experiments::{IoPolicy, PaperRig, RigConfig};
use neuron_chunking::latency::ContiguityDistribution;
use neuron_chunking::model::{MatrixId, MatrixKind, ModelSpec, WeightStore};
use neuron_chunking::sparsify::ChunkSelectConfig;
use neuron_chunking::storage::DeviceProfile;
use neuron_chunking::workload::{DatasetSpec, FrameTrace};

fn artifact_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn rig(model: ModelSpec) -> PaperRig {
    PaperRig::new(
        model,
        DeviceProfile::nano(),
        RigConfig {
            calib_samples: 8,
            tokens_per_frame: 0,
            seed: 5,
        },
    )
    .unwrap()
}

// ------------------------------------------------------- success criteria

#[test]
fn chunking_pareto_dominates_topk_midrange() {
    // DESIGN §7: at mid sparsities ours must be strictly faster at (near)
    // equal accuracy (7B-class model; sub-1B models trade more accuracy,
    // see EXPERIMENTS.md).
    let r = rig(ModelSpec::llava_7b());
    let ds = DatasetSpec::tempcompass();
    for s in [0.3, 0.5] {
        let base = r.run_point(&IoPolicy::TopK, s, &ds, 3).unwrap();
        let ours = r.run_point(&IoPolicy::Chunking, s, &ds, 3).unwrap();
        assert!(
            ours.io_seconds < base.io_seconds * 0.8,
            "s={s}: ours {} base {}",
            ours.io_seconds,
            base.io_seconds
        );
        assert!(ours.accuracy > base.accuracy - 0.05);
    }
}

#[test]
fn ablation_ordering_holds() {
    // baseline <= +reorder <= +reorder+chunking in I/O at fixed sparsity.
    let r = rig(ModelSpec::llava_05b());
    let ds = DatasetSpec::nextqa();
    let io = |p: &IoPolicy| r.run_point(p, 0.4, &ds, 3).unwrap().io_seconds;
    let base = io(&IoPolicy::TopK);
    let reord = io(&IoPolicy::TopKReordered);
    let full = io(&IoPolicy::Chunking);
    assert!(reord <= base * 1.02, "reorder {reord} vs base {base}");
    assert!(full < reord, "chunking {full} vs reorder {reord}");
}

#[test]
fn mean_chunk_size_grows_dramatically() {
    // DESIGN §7 / Fig 10: mean chunk ~1-2 rows (top-k) -> tens (ours).
    let r = rig(ModelSpec::llava_7b());
    let budgets = r.budgets(0.4);
    let layer = r.layers[0].layer;
    let base = r
        .frame_layer_io(&IoPolicy::TopK, layer, 7, &budgets)
        .unwrap();
    let ours = r
        .frame_layer_io(&IoPolicy::Chunking, layer, 7, &budgets)
        .unwrap();
    let mean = |m: &neuron_chunking::sparsify::SelectionMask| {
        ContiguityDistribution::from_chunks(&m.chunks).mean_chunk()
    };
    let base_mean = mean(&base.masks[&MatrixKind::Down]);
    let ours_mean = mean(&ours.masks[&MatrixKind::Down]);
    assert!(base_mean < 5.0, "top-k mean chunk {base_mean}");
    assert!(ours_mean > 15.0, "ours mean chunk {ours_mean}");
}

#[test]
fn agx_profile_is_faster_but_same_winner() {
    let nano = rig(ModelSpec::llava_05b());
    let agx = PaperRig::new(
        ModelSpec::llava_05b(),
        DeviceProfile::agx(),
        RigConfig {
            calib_samples: 8,
            tokens_per_frame: 0,
            seed: 5,
        },
    )
    .unwrap();
    let ds = DatasetSpec::tempcompass();
    let n_base = nano.run_point(&IoPolicy::TopK, 0.4, &ds, 2).unwrap();
    let n_ours = nano.run_point(&IoPolicy::Chunking, 0.4, &ds, 2).unwrap();
    let a_base = agx.run_point(&IoPolicy::TopK, 0.4, &ds, 2).unwrap();
    let a_ours = agx.run_point(&IoPolicy::Chunking, 0.4, &ds, 2).unwrap();
    // AGX strictly faster in absolute terms; chunking wins on both.
    assert!(a_base.io_seconds < n_base.io_seconds);
    assert!(a_ours.io_seconds < n_ours.io_seconds);
    assert!(n_ours.io_seconds < n_base.io_seconds);
    assert!(a_ours.io_seconds < a_base.io_seconds);
}

// ------------------------------------------------------ engine end-to-end

#[test]
fn engine_full_pipeline_with_reorder_and_chunking() {
    let sat_kb = DeviceProfile::nano().saturation_bytes(0.99) as f64 / 1024.0;
    let engine = Engine::builder("tiny")
        .policy(Policy::Chunking {
            config: ChunkSelectConfig::new(2.0, 2.0, sat_kb),
        })
        .sparsity(0.3)
        .seed(17)
        .artifacts(&artifact_dir())
        .build()
        .unwrap();
    let spec = engine.spec();
    let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 6, 3);
    let calib: Vec<Vec<f32>> = (0..3).map(|i| trace.frame(i)).collect();
    engine.calibrate_and_reorder(&calib).unwrap();

    let session = engine.new_session();
    let mut last_io = None;
    for f in 0..3 {
        let (out, stats) = session.append_frame(&trace.frame(f)).unwrap();
        assert_eq!(out.len(), spec.tokens_per_frame * spec.d);
        assert!(out.iter().all(|v| v.is_finite()));
        assert!(stats.io.as_nanos() > 0);
        assert!(stats.retained_fraction() > 0.5);
        last_io = Some(stats.io);
    }
    // Decode still works after reordering. Its selection budgets are
    // row-based (independent of token count), so I/O is comparable to a
    // frame append, not smaller.
    let (out, stats) = session.decode_step(&vec![0.1; spec.d]).unwrap();
    assert_eq!(out.len(), spec.d);
    assert!(stats.io.as_nanos() > 0);
    assert!(stats.io.as_secs_f64() < last_io.unwrap().as_secs_f64() * 1.5);
}

#[test]
fn engine_neuron_cache_reduces_flash_bytes_keeps_output_close() {
    let dir = artifact_dir();
    let build = || {
        Engine::builder("tiny")
            .policy(Policy::TopK)
            .sparsity(0.3)
            .artifacts(&dir)
            .build()
            .unwrap()
    };
    let trace = FrameTrace::new(64, 8, 4, 9);

    // Baseline: no cache.
    let plain = build();
    let (out_plain, stats_plain) = plain.new_session().append_frame(&trace.frame(0)).unwrap();

    // With a hot-neuron cache built from uniform frequencies.
    let cached = build();
    let store = WeightStore::new(ModelSpec::tiny(), false, 42); // same seed as engine
    let mut freqs = std::collections::HashMap::new();
    for layer in 0..2 {
        for kind in MatrixKind::SCORED {
            let rows = ModelSpec::tiny().shape_of(kind).rows;
            freqs.insert(
                MatrixId::new(layer, kind),
                (0..rows).map(|i| 1.0 - i as f64 / rows as f64).collect(),
            );
        }
    }
    let cache = HotNeuronCache::build(&store, &freqs, 0.25, u64::MAX, true);
    assert!(cache.bytes() > 0);
    cached.set_neuron_cache(cache);
    let (out_cached, stats_cached) = cached.new_session().append_frame(&trace.frame(0)).unwrap();

    // At a fixed row budget the cache does not shrink flash traffic (the
    // budget is spent on uncached rows); its benefit is the extra free
    // importance the cached rows contribute (§5: "assigning zero
    // importance to cached neurons").
    assert!(
        stats_cached.bytes_loaded <= stats_plain.bytes_loaded,
        "cache must never increase flash traffic: {} vs {}",
        stats_cached.bytes_loaded,
        stats_plain.bytes_loaded
    );
    // Cached rows are *added* to the compute set, so output can only get
    // closer to dense — check it stays finite and same shape.
    assert_eq!(out_cached.len(), out_plain.len());
    assert!(out_cached.iter().all(|v| v.is_finite()));
    // Retained importance strictly improves: budgeted rows + free cached.
    assert!(
        stats_cached.retained_fraction() > stats_plain.retained_fraction(),
        "cache should add free importance: {} vs {}",
        stats_cached.retained_fraction(),
        stats_plain.retained_fraction()
    );
}

#[test]
fn engine_matches_manifest_bucket_grid() {
    // Every budget the engine can produce maps to a compiled artifact.
    let e = Engine::builder("tiny")
        .policy(Policy::TopK)
        .sparsity(0.33)
        .artifacts(&artifact_dir())
        .build()
        .unwrap();
    let meta = e.meta();
    for rows in 0..=meta.d {
        let b = neuron_chunking::runtime::ModelMeta::bucket_for(&meta.d_buckets, rows);
        assert!(meta.d_buckets.contains(&b));
    }
    for rows in 0..=meta.h {
        let b = neuron_chunking::runtime::ModelMeta::bucket_for(&meta.h_buckets, rows);
        assert!(meta.h_buckets.contains(&b));
    }
}

#[test]
fn small_model_sparse_vs_dense_error_budget() {
    // The e2e fidelity claim of examples/edge_serving.rs in test form.
    let dir = artifact_dir();
    let trace = FrameTrace::new(256, 16, 3, 5);
    let dense_out = {
        let e = Engine::builder("small").artifacts(&dir).build().unwrap();
        e.new_session().append_frame(&trace.frame(0)).unwrap().0
    };
    let sparse_out = {
        let e = Engine::builder("small")
            .policy(Policy::TopK)
            .sparsity(0.3)
            .artifacts(&dir)
            .build()
            .unwrap();
        e.new_session().append_frame(&trace.frame(0)).unwrap().0
    };
    let num: f64 = dense_out
        .iter()
        .zip(&sparse_out)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    let den: f64 = dense_out.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    assert!(num / den < 0.35, "rel err {} too high at 30% sparsity", num / den);
}

// --------------------------------------------------- store/device plumbing

#[test]
fn paper_model_io_only_pipeline() {
    // Timing-only reads across every matrix of a paper model layer.
    let spec = ModelSpec::nvila_2b();
    let store = WeightStore::new(spec.clone(), false, 3);
    let dev = neuron_chunking::storage::SimulatedSsd::timing_only(
        DeviceProfile::agx(),
        store.layout.total_bytes(),
        1,
    );
    for m in spec.matrices() {
        let id = MatrixId::new(spec.layers - 1, m.kind);
        let rows = spec.shape_of(m.kind).rows;
        let t = store
            .read_timing(&dev, id, &[neuron_chunking::latency::Chunk::new(0, rows)])
            .unwrap();
        assert!(t.as_secs_f64() > 0.0);
    }
}

#[test]
fn real_file_device_serves_weight_store() {
    // Write a tiny model image to a temp file and read rows back through
    // the real-file backend: same bytes as the simulator path.
    use neuron_chunking::storage::{FlashDevice, RealFileDevice};
    let store = WeightStore::new(ModelSpec::tiny(), false, 11);
    let image = store.build_image();
    let path = std::env::temp_dir().join(format!("nc_itest_{}.img", std::process::id()));
    std::fs::write(&path, &image).unwrap();
    let real = RealFileDevice::open(&path, 4, false).unwrap();
    assert_eq!(real.capacity(), image.len() as u64);
    let id = MatrixId::new(0, MatrixKind::Gate);
    let chunks = [neuron_chunking::latency::Chunk::new(2, 3)];
    let (rows_real, _) = store.read_rows(&real, id, &chunks).unwrap();
    let sim = neuron_chunking::storage::SimulatedSsd::with_image(
        DeviceProfile::nano(),
        image,
        1,
    );
    let (rows_sim, _) = store.read_rows(&sim, id, &chunks).unwrap();
    assert_eq!(rows_real, rows_sim);
    std::fs::remove_file(path).ok();
}
