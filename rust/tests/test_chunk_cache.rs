//! Shared hot-chunk RAM cache: serving-path invariants.
//!
//! The cache's default mode serves *already-selected* rows from RAM and
//! never touches selection, so enabling it must be a pure I/O change:
//! decode outputs and selected-chunk sets are **bit-identical** with the
//! cache on or off, across batch compositions, pool sizes, and the async
//! I/O toggle. What changes is accounting — flash bytes shrink and the
//! difference lands in `cache_hit_bytes`, exactly.

use std::path::PathBuf;

use neuron_chunking::coordinator::{DecodeRequest, Engine, Policy, Session};
use neuron_chunking::sparsify::ChunkSelectConfig;
use neuron_chunking::workload::FrameTrace;

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn build(policy: Policy, sparsity: f64, devices: usize, async_io: bool, cache_mb: usize) -> Engine {
    Engine::builder("tiny")
        .policy(policy)
        .sparsity(sparsity)
        .prefetch(true)
        .exec_threads(1)
        .devices(devices)
        .async_io(async_io)
        .cache_mb(cache_mb)
        .artifacts(&artifact_dir())
        .build()
        .unwrap()
}

fn policies() -> Vec<(Policy, f64)> {
    vec![
        (Policy::TopK, 0.5),
        (
            Policy::Chunking {
                config: ChunkSelectConfig::new(2.0, 2.0, 348.0),
            },
            0.5,
        ),
    ]
}

/// Four streams with distinct histories and tokens (same fixture shape
/// as the batching determinism tests).
fn fixture(engine: &Engine) -> (Vec<Session>, Vec<Vec<f32>>) {
    let spec = engine.spec();
    let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 8, 11);
    let sessions: Vec<Session> = (0..4)
        .map(|i| {
            let s = engine.new_session();
            s.append_frame(&trace.frame(i)).unwrap();
            s
        })
        .collect();
    let tokens: Vec<Vec<f32>> = (0..4)
        .map(|i| vec![0.01 * (i as f32 + 1.0); spec.d])
        .collect();
    (sessions, tokens)
}

/// Per-stream, per-step observation: output plus `importance_kept`
/// (the summed importance of the selected set — identical selections
/// produce bit-identical sums, so equal pairs mean both *what* was
/// computed and *what* was selected matched). Byte-exact I/O accounting
/// is pinned separately with prefetch off: with prefetch on, the
/// next-layer prediction is recorded post-subtraction, so the cached
/// run legitimately prefetches fewer bytes than the uncached one.
type StreamTrace = Vec<(Vec<f32>, f64)>;

/// Three warm-up rounds, a cache-maintenance pass (no-op without a
/// cache), then three measured rounds in fused groups of `batch`.
fn run_rounds(engine: &Engine, batch: usize) -> Vec<StreamTrace> {
    let (sessions, tokens) = fixture(engine);
    let mut out: Vec<StreamTrace> = (0..4).map(|_| Vec::new()).collect();
    for phase in 0..2 {
        if phase == 1 {
            engine.maintain_cache().unwrap();
        }
        for _round in 0..3 {
            let mut start = 0usize;
            while start < 4 {
                let end = (start + batch).min(4);
                let reqs: Vec<DecodeRequest> = (start..end)
                    .map(|i| DecodeRequest {
                        session: &sessions[i],
                        token: &tokens[i],
                    })
                    .collect();
                let results = engine.decode_batch(&reqs).unwrap();
                for (i, (y, st)) in (start..end).zip(results) {
                    out[i].push((y, st.importance_kept));
                }
                start = end;
            }
        }
    }
    out
}

#[test]
fn outputs_and_selection_bit_identical_cache_on_off() {
    // The tentpole invariant, across batch {1, 4} × devices {1, 4} ×
    // async {off, on}: a warm cache changes where bytes come from, never
    // what is selected or computed.
    for (policy, sparsity) in policies() {
        let reference = run_rounds(&build(policy.clone(), sparsity, 1, false, 0), 1);
        for async_io in [false, true] {
            for devices in [1usize, 4] {
                for batch in [1usize, 4] {
                    let engine = build(policy.clone(), sparsity, devices, async_io, 64);
                    let got = run_rounds(&engine, batch);
                    assert_eq!(
                        reference, got,
                        "policy={policy:?} devices={devices} async={async_io} batch={batch} \
                         diverged from the uncached single-device reference"
                    );
                    // The warm phase really was served partly from RAM.
                    assert!(
                        engine.metrics().bytes("io.cache_hit_bytes") > 0,
                        "policy={policy:?} devices={devices} async={async_io} batch={batch}: \
                         cache never served a row"
                    );
                }
            }
        }
    }
}

/// Solo decode with prefetch off: every group load goes through one
/// plan, so the byte accounting is exact per step.
fn run_solo_no_prefetch(cache_mb: usize) -> (Engine, Vec<(Vec<f32>, u64, u64, f64)>) {
    let engine = Engine::builder("tiny")
        .policy(Policy::TopK)
        .sparsity(0.5)
        .prefetch(false)
        .exec_threads(1)
        .cache_mb(cache_mb)
        .artifacts(&artifact_dir())
        .build()
        .unwrap();
    let spec = engine.spec();
    let session = engine.new_session();
    let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 2, 7);
    session.append_frame(&trace.frame(0)).unwrap();
    let token = vec![0.05f32; spec.d];
    let mut steps = Vec::new();
    for phase in 0..2 {
        if phase == 1 {
            engine.maintain_cache().unwrap();
        }
        for _ in 0..4 {
            let (y, st) = session.decode_step(&token).unwrap();
            steps.push((y, st.bytes_loaded, st.cache_hit_bytes, st.importance_kept));
        }
    }
    (engine, steps)
}

#[test]
fn cache_hits_account_for_exactly_the_flash_bytes_saved() {
    // Per step: flash bytes with the cache on, plus the bytes the cache
    // served, equals the uncached flash bytes — i.e. the `ReadPlan`s the
    // pool saw contained exactly the misses, no more, no less.
    let (_ref_engine, reference) = run_solo_no_prefetch(0);
    let (engine, cached) = run_solo_no_prefetch(64);
    assert_eq!(reference.len(), cached.len());
    let mut warm_hits = 0u64;
    for (i, (r, c)) in reference.iter().zip(&cached).enumerate() {
        assert_eq!(r.0, c.0, "output diverged at step {i}");
        assert_eq!(r.3, c.3, "importance diverged at step {i}");
        assert_eq!(r.2, 0, "uncached run reported cache hits at step {i}");
        assert_eq!(
            c.1 + c.2,
            r.1,
            "step {i}: cached flash bytes {} + hit bytes {} != uncached {}",
            c.1,
            c.2,
            r.1
        );
        if i >= 4 {
            warm_hits += c.2;
        }
    }
    assert!(warm_hits > 0, "warm phase never hit the cache");
    // Warm flash traffic is strictly below the uncached run's.
    let warm_flash: u64 = cached[4..].iter().map(|s| s.1).sum();
    let ref_flash: u64 = reference[4..].iter().map(|s| s.1).sum();
    assert!(warm_flash < ref_flash, "{warm_flash} !< {ref_flash}");
    // And the engine-level counters agree with the per-step stats.
    let m = engine.metrics();
    let total_hits: u64 = cached.iter().map(|s| s.2).sum();
    assert_eq!(m.bytes("io.cache_hit_bytes"), total_hits);
    assert!(m.bytes("cache.resident_bytes") > 0);
    assert!(m.bytes("cache.resident_bytes") <= m.bytes("cache.budget_bytes"));
}

#[test]
fn resident_bytes_never_exceed_budget_under_shifting_traffic() {
    // Engine-level view of the eviction-under-budget property (the
    // chunk-granular version lives in `cache::tests`): across repeated
    // maintenance passes with drifting per-token selections, residency
    // stays within the configured budget.
    let engine = build(Policy::TopK, 0.5, 1, false, 1);
    let spec = engine.spec();
    let session = engine.new_session();
    let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 4, 13);
    session.append_frame(&trace.frame(0)).unwrap();
    let budget = engine.metrics().bytes("cache.budget_bytes");
    assert_eq!(budget, 1 << 20);
    for round in 0..6 {
        let token: Vec<f32> = (0..spec.d)
            .map(|i| ((i * (round + 2)) % 17) as f32 * 0.01 - 0.08)
            .collect();
        for _ in 0..3 {
            session.decode_step(&token).unwrap();
        }
        engine.maintain_cache().unwrap();
        let m = engine.metrics();
        let resident = m.bytes("cache.resident_bytes");
        assert!(
            resident <= budget,
            "round {round}: resident {resident} exceeds budget {budget}"
        );
    }
    assert!(engine.metrics().bytes("cache.admissions") > 0);
}

#[test]
fn drift_triggers_online_rereorder_and_sessions_reset() {
    // With a drift threshold armed and no calibrated baseline, the first
    // maintenance pass compares concentrated live traffic against the
    // uniform prior, crosses the threshold, and re-reorders online:
    // epoch bumps (stale sessions error, exactly like offline
    // re-calibration) and the cache restarts in the new physical order.
    let engine = Engine::builder("tiny")
        .policy(Policy::TopK)
        .sparsity(0.5)
        .exec_threads(1)
        .cache_mb(64)
        .drift_threshold(Some(0.05))
        .artifacts(&artifact_dir())
        .build()
        .unwrap();
    let spec = engine.spec();
    let stale = engine.new_session();
    let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 2, 7);
    stale.append_frame(&trace.frame(0)).unwrap();
    let token = vec![0.05f32; spec.d];
    for _ in 0..4 {
        stale.decode_step(&token).unwrap();
    }
    let drift = engine.maintain_cache().unwrap();
    assert!(
        drift >= 0.05,
        "sparse selection vs uniform prior must register drift, got {drift}"
    );
    // The re-reorder invalidated the pre-drift session…
    assert!(stale.decode_step(&token).is_err());
    // …and a fresh session serves normally against the new layout, with
    // the cache re-seeded from the live profile (admissions on the next
    // maintenance pass, without any new traffic having accumulated).
    let fresh = engine.new_session();
    fresh.append_frame(&trace.frame(0)).unwrap();
    let (y, _) = fresh.decode_step(&token).unwrap();
    assert_eq!(y.len(), spec.d);
    engine.maintain_cache().unwrap();
    assert!(engine.metrics().bytes("cache.resident_bytes") > 0);
    fresh.decode_step(&token).unwrap();
}
