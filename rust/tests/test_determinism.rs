//! Parallel-executor determinism: engine outputs must be **bit-identical**
//! across executor thread counts (1/2/4), for both `append_frame` and
//! `decode_step`, with prefetch on and off.
//!
//! The blocked kernels keep every output element's f64 reduction in a
//! fixed order (ascending contraction index per column, ascending slot
//! per attention head), so tiling and threading must not change a single
//! bit. This is what lets the serving stack scale worker threads without
//! perturbing accuracy experiments.

use std::path::PathBuf;

use neuron_chunking::coordinator::{DecodeRequest, Engine, Policy, Session};
use neuron_chunking::sparsify::ChunkSelectConfig;
use neuron_chunking::workload::FrameTrace;

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Two appends + two decodes on one session; returns all four outputs.
fn run(model: &str, policy: Policy, sparsity: f64, prefetch: bool, threads: usize) -> Vec<Vec<f32>> {
    let engine = Engine::builder(model)
        .policy(policy)
        .sparsity(sparsity)
        .prefetch(prefetch)
        .exec_threads(threads)
        .artifacts(&artifact_dir())
        .build()
        .unwrap();
    let spec = engine.spec();
    let session = engine.new_session();
    let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 4, 11);
    let mut outs = Vec::new();
    outs.push(session.append_frame(&trace.frame(0)).unwrap().0);
    outs.push(session.append_frame(&trace.frame(1)).unwrap().0);
    let token = vec![0.03f32; spec.d];
    outs.push(session.decode_step(&token).unwrap().0);
    outs.push(session.decode_step(&token).unwrap().0);
    outs
}

fn policies() -> Vec<(Policy, f64)> {
    vec![
        (Policy::Dense, 0.0),
        (Policy::TopK, 0.5),
        (
            Policy::Chunking {
                config: ChunkSelectConfig::new(2.0, 2.0, 348.0),
            },
            0.5,
        ),
    ]
}

#[test]
fn tiny_outputs_bit_identical_across_thread_counts() {
    for prefetch in [false, true] {
        for (policy, sparsity) in policies() {
            let base = run("tiny", policy.clone(), sparsity, prefetch, 1);
            for threads in [2usize, 4] {
                let got = run("tiny", policy.clone(), sparsity, prefetch, threads);
                for (step, (want, have)) in base.iter().zip(&got).enumerate() {
                    assert_eq!(
                        want, have,
                        "tiny policy={policy:?} prefetch={prefetch} threads={threads} \
                         diverged at step {step}"
                    );
                }
            }
        }
    }
}

/// Two appends + two decodes against a `devices`-member homogeneous
/// pool; returns outputs plus the per-call (bytes_loaded,
/// importance_kept) pair — equal pairs mean the selected-chunk sets were
/// identical.
fn run_pool(
    policy: Policy,
    sparsity: f64,
    devices: usize,
) -> (Vec<Vec<f32>>, Vec<(u64, f64)>) {
    let engine = Engine::builder("tiny")
        .policy(policy)
        .sparsity(sparsity)
        .prefetch(true)
        .exec_threads(1)
        .devices(devices)
        .artifacts(&artifact_dir())
        .build()
        .unwrap();
    let spec = engine.spec();
    let session = engine.new_session();
    let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 4, 11);
    let mut outs = Vec::new();
    let mut sels = Vec::new();
    for i in 0..2 {
        let (y, s) = session.append_frame(&trace.frame(i)).unwrap();
        outs.push(y);
        sels.push((s.bytes_loaded, s.importance_kept));
    }
    let token = vec![0.03f32; spec.d];
    for _ in 0..2 {
        let (y, s) = session.decode_step(&token).unwrap();
        outs.push(y);
        sels.push((s.bytes_loaded, s.importance_kept));
    }
    (outs, sels)
}

#[test]
fn tiny_outputs_bit_identical_across_pool_sizes() {
    // Sharding the flash image across a homogeneous pool is a pure
    // I/O-topology change: decode outputs are bit-identical and the
    // selected-chunk sets (observed through loaded bytes and captured
    // importance, both exact) are unchanged for 1/2/4 members.
    for (policy, sparsity) in policies() {
        let (base_out, base_sel) = run_pool(policy.clone(), sparsity, 1);
        for devices in [2usize, 4] {
            let (out, sel) = run_pool(policy.clone(), sparsity, devices);
            assert_eq!(
                base_out, out,
                "policy={policy:?} devices={devices} outputs diverged"
            );
            assert_eq!(
                base_sel, sel,
                "policy={policy:?} devices={devices} selections diverged"
            );
        }
    }
}

/// Per-stream observation of one decode: output plus the exact
/// (bytes_loaded, importance_kept) pair — equal pairs mean the
/// selected-chunk sets were identical.
type StreamTrace = Vec<(Vec<f32>, u64, f64)>;

fn batch_engine(policy: Policy, sparsity: f64, async_io: bool, devices: usize) -> Engine {
    Engine::builder("tiny")
        .policy(policy)
        .sparsity(sparsity)
        .prefetch(true)
        .exec_threads(1)
        .devices(devices)
        .async_io(async_io)
        .artifacts(&artifact_dir())
        .build()
        .unwrap()
}

/// Four streams with distinct histories and tokens; three decode rounds.
fn batch_fixture(engine: &Engine) -> (Vec<Session>, Vec<Vec<f32>>) {
    let spec = engine.spec();
    let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 8, 11);
    let sessions: Vec<Session> = (0..4)
        .map(|i| {
            let s = engine.new_session();
            s.append_frame(&trace.frame(i)).unwrap();
            s
        })
        .collect();
    let tokens: Vec<Vec<f32>> = (0..4)
        .map(|i| vec![0.01 * (i as f32 + 1.0); spec.d])
        .collect();
    (sessions, tokens)
}

/// Solo reference: each stream decodes alone via `decode_step`.
fn run_batch_solo(
    policy: Policy,
    sparsity: f64,
    async_io: bool,
    devices: usize,
) -> Vec<StreamTrace> {
    let engine = batch_engine(policy, sparsity, async_io, devices);
    let (sessions, tokens) = batch_fixture(&engine);
    let mut out: Vec<StreamTrace> = (0..4).map(|_| Vec::new()).collect();
    for _round in 0..3 {
        for i in 0..4 {
            let (y, st) = sessions[i].decode_step(&tokens[i]).unwrap();
            out[i].push((y, st.bytes_loaded, st.importance_kept));
        }
    }
    out
}

/// Batched: the same four streams decode in fused groups of `batch`.
fn run_batch_grouped(
    policy: Policy,
    sparsity: f64,
    async_io: bool,
    devices: usize,
    batch: usize,
) -> Vec<StreamTrace> {
    let engine = batch_engine(policy, sparsity, async_io, devices);
    let (sessions, tokens) = batch_fixture(&engine);
    let mut out: Vec<StreamTrace> = (0..4).map(|_| Vec::new()).collect();
    for _round in 0..3 {
        let mut start = 0usize;
        while start < 4 {
            let end = (start + batch).min(4);
            let reqs: Vec<DecodeRequest> = (start..end)
                .map(|i| DecodeRequest {
                    session: &sessions[i],
                    token: &tokens[i],
                })
                .collect();
            let results = engine.decode_batch(&reqs).unwrap();
            for (i, (y, st)) in (start..end).zip(results) {
                out[i].push((y, st.bytes_loaded, st.importance_kept));
            }
            start = end;
        }
    }
    out
}

#[test]
fn batched_decode_bit_identical_across_batch_compositions() {
    // The tentpole invariant: a stream's outputs and selected-chunk sets
    // are bit-identical whether it decodes solo or inside any batch
    // composition — per policy, across batch sizes {1, 2, 4}.
    for (policy, sparsity) in policies() {
        let solo = run_batch_solo(policy.clone(), sparsity, false, 1);
        for batch in [1usize, 2, 4] {
            let got = run_batch_grouped(policy.clone(), sparsity, false, 1, batch);
            assert_eq!(
                solo, got,
                "policy={policy:?} batch={batch} diverged from solo"
            );
        }
    }
}

#[test]
fn batched_decode_bit_identical_across_async_and_pool_sizes() {
    // The same invariant across the async I/O pipeline toggle and pool
    // sizes {1, 4}: batching must compose with every I/O topology.
    let base = run_batch_solo(Policy::TopK, 0.5, false, 1);
    for async_io in [false, true] {
        for devices in [1usize, 4] {
            let solo = run_batch_solo(Policy::TopK, 0.5, async_io, devices);
            assert_eq!(
                base, solo,
                "solo async={async_io} devices={devices} diverged"
            );
            for batch in [2usize, 4] {
                let got = run_batch_grouped(Policy::TopK, 0.5, async_io, devices, batch);
                assert_eq!(
                    base, got,
                    "batched async={async_io} devices={devices} batch={batch} diverged"
                );
            }
        }
    }
}

#[test]
fn small_outputs_bit_identical_across_thread_counts() {
    // The small model's matmuls are large enough to actually cross the
    // parallel-dispatch threshold on the decode path too.
    let base = run("small", Policy::TopK, 0.5, true, 1);
    for threads in [2usize, 4] {
        let got = run("small", Policy::TopK, 0.5, true, threads);
        for (step, (want, have)) in base.iter().zip(&got).enumerate() {
            assert_eq!(
                want, have,
                "small threads={threads} diverged at step {step}"
            );
        }
    }
}
