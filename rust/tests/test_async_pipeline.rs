//! Async/sync equivalence: the asynchronous I/O pipeline is a pure
//! timing change. The same workload through `async_io` on/off × queue
//! depths {1, 2, 4} × pool sizes {1, 4} must produce **bit-identical
//! outputs and selections** (observed through loaded bytes and captured
//! importance, both exact), and a wall-clock file-backed pool must
//! reproduce the simulated pool's outputs byte for byte — the backing
//! files hold the same flash image, and selection prices chunks with the
//! same profiled tables either way.

use std::path::PathBuf;

use neuron_chunking::coordinator::{DecodeRequest, Engine, Policy};
use neuron_chunking::sparsify::ChunkSelectConfig;
use neuron_chunking::workload::FrameTrace;

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn policies() -> Vec<(Policy, f64)> {
    vec![
        (Policy::Dense, 0.0),
        (Policy::TopK, 0.5),
        (
            Policy::Chunking {
                config: ChunkSelectConfig::new(2.0, 2.0, 348.0),
            },
            0.5,
        ),
    ]
}

/// Two appends + two decodes on one session; returns the outputs plus
/// the per-call (bytes_loaded, importance_kept) pair — equal pairs mean
/// the selected-chunk sets were identical.
#[allow(clippy::too_many_arguments)]
fn run_model(
    model: &str,
    policy: Policy,
    sparsity: f64,
    devices: usize,
    async_io: bool,
    depth: usize,
    file_backed: Option<&std::path::Path>,
) -> (Vec<Vec<f32>>, Vec<(u64, f64)>) {
    let mut builder = Engine::builder(model)
        .policy(policy)
        .sparsity(sparsity)
        .prefetch(true)
        .exec_threads(1)
        .devices(devices)
        .async_io(async_io)
        .io_queue_depth(depth)
        .artifacts(&artifact_dir());
    if let Some(dir) = file_backed {
        builder = builder.file_backed(dir);
    }
    let engine = builder.build().unwrap();
    let spec = engine.spec();
    let session = engine.new_session();
    let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 4, 11);
    let mut outs = Vec::new();
    let mut sels = Vec::new();
    for i in 0..2 {
        let (y, s) = session.append_frame(&trace.frame(i)).unwrap();
        outs.push(y);
        sels.push((s.bytes_loaded, s.importance_kept));
    }
    let token = vec![0.03f32; spec.d];
    for _ in 0..2 {
        let (y, s) = session.decode_step(&token).unwrap();
        outs.push(y);
        sels.push((s.bytes_loaded, s.importance_kept));
    }
    (outs, sels)
}

fn run(
    policy: Policy,
    sparsity: f64,
    devices: usize,
    async_io: bool,
    depth: usize,
    file_backed: Option<&std::path::Path>,
) -> (Vec<Vec<f32>>, Vec<(u64, f64)>) {
    run_model("tiny", policy, sparsity, devices, async_io, depth, file_backed)
}

#[test]
fn async_matches_sync_across_depths_and_pools() {
    for (policy, sparsity) in policies() {
        for devices in [1usize, 4] {
            let (base_out, base_sel) = run(policy.clone(), sparsity, devices, false, 1, None);
            for depth in [1usize, 2, 4] {
                let (out, sel) = run(policy.clone(), sparsity, devices, true, depth, None);
                assert_eq!(
                    base_out, out,
                    "policy={policy:?} devices={devices} depth={depth} outputs diverged"
                );
                assert_eq!(
                    base_sel, sel,
                    "policy={policy:?} devices={devices} depth={depth} selections diverged"
                );
            }
        }
    }
}

#[test]
fn small_model_deep_queue_matches_sync() {
    // The 4-layer `small` model genuinely keeps several whole-layer
    // prefetches in flight at depths 2/4 (tiny has only one prefetchable
    // layer), so this is the case that exercises real pipelining.
    let (base_out, base_sel) = run_model("small", Policy::TopK, 0.5, 2, false, 1, None);
    for depth in [2usize, 4] {
        let (out, sel) = run_model("small", Policy::TopK, 0.5, 2, true, depth, None);
        assert_eq!(base_out, out, "small depth={depth} outputs diverged");
        assert_eq!(base_sel, sel, "small depth={depth} selections diverged");
    }
}

#[test]
fn file_backed_async_matches_simulated_sync() {
    // Wall-clock pool members (real backing files, per-member async I/O
    // workers) must reproduce the simulated pool's serving byte for byte.
    let dir = std::env::temp_dir().join(format!("nc_async_eq_{}", std::process::id()));
    let (base_out, base_sel) = run(Policy::TopK, 0.4, 2, false, 1, None);
    for depth in [1usize, 2] {
        let (out, sel) = run(Policy::TopK, 0.4, 2, true, depth, Some(&dir));
        assert_eq!(base_out, out, "depth={depth} outputs diverged");
        assert_eq!(base_sel, sel, "depth={depth} selections diverged");
    }
    // Sync mode over the same files too (scoped-thread fan-out path).
    let (out, sel) = run(Policy::TopK, 0.4, 2, false, 1, Some(&dir));
    assert_eq!(base_out, out, "sync file-backed outputs diverged");
    assert_eq!(base_sel, sel, "sync file-backed selections diverged");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn reset_mid_pipeline_discards_stale_prefetch_state() {
    // Satellite regression: `Session::reset` drains every in-flight /
    // pending prefetch slot (`drain_stale`), so a reset between requests
    // can never scatter stale bytes into the next one. Exercised on the
    // wall-clock file-backed async pipeline (real tickets) by comparing
    // a reset-then-replay session against a fresh session bit for bit.
    let dir = std::env::temp_dir().join(format!("nc_async_reset_{}", std::process::id()));
    let engine = Engine::builder("tiny")
        .policy(Policy::TopK)
        .sparsity(0.4)
        .devices(2)
        .async_io(true)
        .io_queue_depth(2)
        .file_backed(&dir)
        .artifacts(&artifact_dir())
        .build()
        .unwrap();
    let spec = engine.spec();
    let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 4, 11);
    let token = vec![0.03f32; spec.d];
    // Run a session mid-conversation, then reset it with prefetch slots
    // populated for the next call.
    let recycled = engine.new_session();
    recycled.append_frame(&trace.frame(0)).unwrap();
    recycled.decode_step(&token).unwrap();
    recycled.reset();
    assert_eq!(recycled.kv_tokens(), 0, "reset must clear KV state");
    // Replay a different history: outputs must match a fresh session
    // exactly — any stale prefetched bytes would perturb them.
    let fresh = engine.new_session();
    let (y_fresh, s_fresh) = fresh.append_frame(&trace.frame(2)).unwrap();
    let (y_recycled, s_recycled) = recycled.append_frame(&trace.frame(2)).unwrap();
    assert_eq!(y_fresh, y_recycled, "reset session served stale state");
    assert_eq!(s_fresh.bytes_loaded, s_recycled.bytes_loaded);
    assert_eq!(
        s_recycled.prefetch_hits, 0,
        "reset must discard the prefetch buffers"
    );
    let (d_fresh, _) = fresh.decode_step(&token).unwrap();
    let (d_recycled, _) = recycled.decode_step(&token).unwrap();
    assert_eq!(d_fresh, d_recycled);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn batched_decode_matches_solo_on_wall_clock_async_pool() {
    // The batch driver's fused reads route through the async I/O workers
    // on wall-clock pools (one fused ticket scattering to N subscriber
    // receipts): outputs and selections must still be bit-identical to
    // solo decoding over the same files.
    let root = std::env::temp_dir().join(format!("nc_async_batch_{}", std::process::id()));
    let mk = |sub: &str| {
        Engine::builder("tiny")
            .policy(Policy::TopK)
            .sparsity(0.4)
            .devices(2)
            .async_io(true)
            .io_queue_depth(2)
            .file_backed(&root.join(sub))
            .artifacts(&artifact_dir())
            .build()
            .unwrap()
    };
    let trace = FrameTrace::new(64, 8, 4, 11);
    let tokens: Vec<Vec<f32>> = (0..2).map(|i| vec![0.02 * (i as f32 + 1.0); 64]).collect();
    // Solo reference.
    let solo_engine = mk("solo");
    let solo: Vec<(Vec<f32>, u64, f64)> = (0..2)
        .map(|i| {
            let s = solo_engine.new_session();
            s.append_frame(&trace.frame(i)).unwrap();
            let (y, st) = s.decode_step(&tokens[i]).unwrap();
            (y, st.bytes_loaded, st.importance_kept)
        })
        .collect();
    // Fused batch over the same histories.
    let batch_engine = mk("batch");
    let sessions: Vec<_> = (0..2)
        .map(|i| {
            let s = batch_engine.new_session();
            s.append_frame(&trace.frame(i)).unwrap();
            s
        })
        .collect();
    let reqs: Vec<DecodeRequest> = sessions
        .iter()
        .zip(&tokens)
        .map(|(s, t)| DecodeRequest {
            session: s,
            token: t,
        })
        .collect();
    let results = batch_engine.decode_batch(&reqs).unwrap();
    for (i, ((y, st), (want_y, want_b, want_imp))) in
        results.into_iter().zip(solo).enumerate()
    {
        assert_eq!(y, want_y, "stream {i} outputs diverged on async pool");
        assert_eq!(st.bytes_loaded, want_b, "stream {i} bytes diverged");
        assert_eq!(st.importance_kept, want_imp, "stream {i} selections diverged");
    }
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn async_overlap_is_observed_and_bounded() {
    let engine = Engine::builder("tiny")
        .policy(Policy::Dense)
        .sparsity(0.0)
        .async_io(true)
        .io_queue_depth(3)
        .artifacts(&artifact_dir())
        .build()
        .unwrap();
    let spec = engine.spec();
    let session = engine.new_session();
    let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 2, 7);
    let (_, cold) = session.append_frame(&trace.frame(0)).unwrap();
    // Nothing was in flight on the cold call (no prior masks to predict
    // from), so no overlap was earned yet.
    assert_eq!(cold.max_inflight, 0);
    let (_, warm) = session.append_frame(&trace.frame(0)).unwrap();
    // Dense repeat traffic: every non-first layer is prefetched, the
    // pipeline keeps submissions in flight up to the configured depth,
    // and the overlap ratio is a valid fraction.
    assert!(warm.max_inflight >= 1, "no prefetch in flight");
    assert!(warm.max_inflight <= 3, "queue depth bound violated");
    assert!(warm.prefetch_hits > 0);
    assert!(warm.overlapped_io > std::time::Duration::ZERO);
    let r = warm.overlap_ratio();
    assert!((0.0..=1.0).contains(&r), "overlap ratio {r}");
}
