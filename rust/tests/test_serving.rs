//! Wire-level tests for the serving front end + load harness.
//!
//! The load-bearing guarantee: a decode served over the network is
//! **bit-identical** to the same decode run in-process through
//! `Session::decode_step` — the HTTP/JSON layer adds latency, never
//! numerics (float arrays survive the wire exactly; see
//! `serving::json`). The rest pins the protocol's failure behavior:
//! malformed traffic gets clean statuses, the connection bound answers
//! `503` instead of hanging, and shutdown is graceful from both the
//! explicit and the `Drop` path.

use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::time::Duration;

use neuron_chunking::coordinator::{Engine, Policy, Scheduler, SchedulerConfig};
use neuron_chunking::serving::http;
use neuron_chunking::serving::json::{self, Json};
use neuron_chunking::serving::loadgen::{self, client::Client, compare_files, RunConfig};
use neuron_chunking::serving::{Server, ServerConfig};
use neuron_chunking::workload::FrameTrace;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn tiny_engine() -> Engine {
    Engine::builder("tiny")
        .policy(Policy::TopK)
        .sparsity(0.3)
        .artifacts(&artifacts_dir())
        .build()
        .expect("tiny engine")
}

/// A live server over a fresh tiny engine; port 0 → OS-assigned.
fn start_server(max_connections: usize, workers: usize) -> Server {
    let sched = Scheduler::spawn(
        SchedulerConfig {
            workers,
            ..SchedulerConfig::default()
        },
        tiny_engine,
    );
    sched.engine().warmup().expect("warmup");
    let cfg = ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        max_connections,
        max_body_bytes: 64 * 1024,
        read_timeout: Duration::from_millis(200),
        extra_config: vec![("test".to_string(), "true".to_string())],
    };
    Server::start(cfg, sched).expect("server start")
}

fn addr_of(server: &Server) -> String {
    server.local_addr().to_string()
}

/// The acceptance criterion: open stream → append → decode over
/// loopback HTTP, outputs bit-identical to the in-process engine.
#[test]
fn loopback_round_trip_is_bit_identical_to_in_process() {
    // In-process reference: same model, same policy, same seed.
    let reference = tiny_engine();
    reference.warmup().expect("warmup");
    let spec = reference.spec();
    let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 2, 11);
    let frame = trace.frame(0);
    let token = vec![0.05f32; spec.d];
    let session = reference.new_session();
    let (ref_append, _) = session.append_frame(&frame).expect("reference append");
    let ref_decodes: Vec<Vec<f32>> = (0..3)
        .map(|_| session.decode_step(&token).expect("reference decode").0)
        .collect();

    // Served: same traffic over the wire, echoing outputs back.
    let server = start_server(8, 1);
    let mut client = Client::connect(&addr_of(&server)).expect("connect");
    let stream = client.open_stream().expect("open stream");

    let mut body = String::from("{\"echo\":true,\"frame\":");
    json::push_f32_array(&mut body, &frame);
    body.push('}');
    let reply = client
        .request("POST", &format!("/v1/streams/{stream}/append"), &body)
        .expect("served append");
    let served_append = reply
        .get("output")
        .and_then(Json::as_f32s)
        .expect("append echoes output");
    assert_bits_eq(&served_append, &ref_append, "append");

    for (step, expected) in ref_decodes.iter().enumerate() {
        let mut body = String::from("{\"echo\":true,\"steps\":1,\"token\":");
        json::push_f32_array(&mut body, &token);
        body.push('}');
        let reply = client
            .request("POST", &format!("/v1/streams/{stream}/decode"), &body)
            .expect("served decode");
        let served = reply
            .get("output")
            .and_then(Json::as_f32s)
            .expect("decode echoes output");
        assert_bits_eq(&served, expected, &format!("decode step {step}"));
        // The response carries the engine's accounting, not just data.
        assert!(reply.get("latency_us").and_then(Json::as_f64).is_some());
        assert!(reply.get("engine").and_then(|e| e.get("io_bytes")).is_some());
    }
    server.shutdown();
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{what}: element {i} differs: {g} vs {w}"
        );
    }
}

/// Raw-socket request, returning (status, body).
fn raw_request(addr: &str, payload: &[u8]) -> (u16, Vec<u8>) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    stream.write_all(payload).expect("send");
    let mut reader = BufReader::new(stream);
    let (status, body, _keep) = http::read_response(&mut reader).expect("response");
    (status, body)
}

#[test]
fn protocol_violations_get_clean_statuses() {
    let server = start_server(8, 1);
    let addr = addr_of(&server);

    // Chunked transfer encoding → 501.
    let (status, _) = raw_request(
        &addr,
        b"POST /v1/streams HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n",
    );
    assert_eq!(status, 501);

    // Declared body larger than the server limit → 413.
    let (status, _) = raw_request(
        &addr,
        b"POST /v1/streams HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n",
    );
    assert_eq!(status, 413);

    // POST without a length → 411.
    let (status, _) = raw_request(&addr, b"POST /v1/streams HTTP/1.1\r\n\r\n");
    assert_eq!(status, 411);

    // Unknown route → 404; wrong method on a known route → 405.
    let (status, _) = raw_request(&addr, b"GET /nope HTTP/1.1\r\n\r\n");
    assert_eq!(status, 404);
    let (status, _) = raw_request(&addr, b"DELETE /healthz HTTP/1.1\r\n\r\n");
    assert_eq!(status, 405);

    // Stream that was never opened → 404 with a JSON error body.
    let (status, body) = raw_request(
        &addr,
        b"POST /v1/streams/7/decode HTTP/1.1\r\nContent-Length: 2\r\n\r\n{}",
    );
    assert_eq!(status, 404);
    let err = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert!(err.get("error").and_then(Json::as_str).is_some());

    // Garbage JSON on an open stream → 400.
    let mut client = Client::connect(&addr).expect("connect");
    let stream = client.open_stream().expect("open");
    let (status, _) = raw_request(
        &addr,
        format!("POST /v1/streams/{stream}/decode HTTP/1.1\r\nContent-Length: 4\r\n\r\nnope")
            .as_bytes(),
    );
    assert_eq!(status, 400);
    server.shutdown();
}

#[test]
fn health_metrics_and_config_respond() {
    let server = start_server(8, 1);
    let mut client = Client::connect(&addr_of(&server)).expect("connect");

    let (status, body) = raw_request(&addr_of(&server), b"GET /healthz HTTP/1.1\r\n\r\n");
    assert_eq!(status, 200);
    assert_eq!(body, b"ok\n");

    let cfg = client.get("/v1/config").expect("config");
    assert_eq!(cfg.get("model").and_then(Json::as_str), Some("tiny"));
    assert_eq!(cfg.get("policy").and_then(Json::as_str), Some("topk"));
    assert!(cfg.get("d").and_then(Json::as_usize).is_some());
    // extra_config pairs pass through verbatim.
    assert_eq!(cfg.get("test").and_then(Json::as_bool), Some(true));
    // The active storage dtype is reported ("f32" unless the harness
    // pins NC_DTYPE) and must agree with the /metrics info gauge below.
    let dtype = cfg
        .get("dtype")
        .and_then(Json::as_str)
        .expect("config reports dtype")
        .to_string();
    assert!(["f32", "fp16", "int8"].contains(&dtype.as_str()), "{dtype}");

    // Drive one request so the metrics fold is non-trivial.
    let stream = client.open_stream().expect("open");
    let d = cfg.get("d").and_then(Json::as_usize).unwrap();
    let tpf = cfg.get("tokens_per_frame").and_then(Json::as_usize).unwrap();
    client.append(stream, &vec![0.05f32; tpf * d]).expect("append");
    let (status, body) = raw_request(&addr_of(&server), b"GET /metrics HTTP/1.1\r\n\r\n");
    assert_eq!(status, 200);
    let text = String::from_utf8(body).unwrap();
    assert!(text.contains("nc_stage_seconds{stage=\"io\"}"), "{text}");
    assert!(text.contains("nc_server_streams_open 1"), "{text}");
    assert!(
        text.contains(&format!("nc_storage_dtype{{dtype=\"{dtype}\"}} 1")),
        "{text}"
    );
    // The per-dtype traffic counter flows through the generic byte loop.
    let key = match dtype.as_str() {
        "fp16" => "nc_stage_bytes{stage=\"io.bytes_fp16\"}",
        "int8" => "nc_stage_bytes{stage=\"io.bytes_int8\"}",
        _ => "nc_stage_bytes{stage=\"io.bytes_f32\"}",
    };
    assert!(text.contains(key), "{text}");
    server.shutdown();
}

/// Clients beyond the connection bound get an immediate `503`, never a
/// hang (requests on the in-bound connections keep working).
#[test]
fn connection_limit_returns_503_not_a_hang() {
    let server = start_server(2, 1);
    let addr = addr_of(&server);
    // Two keep-alive connections, both established and answering (so the
    // acceptor has definitely counted them).
    let mut a = Client::connect(&addr).expect("conn a");
    let mut b = Client::connect(&addr).expect("conn b");
    a.get("/healthz").expect("a healthz");
    b.get("/healthz").expect("b healthz");

    // The third is over the bound: answered 503 and closed, within the
    // read timeout (a hang would error the read instead).
    let (status, body) = raw_request(&addr, b"GET /healthz HTTP/1.1\r\n\r\n");
    assert_eq!(status, 503);
    assert!(String::from_utf8(body).unwrap().contains("connection limit"));

    // The in-bound connections still serve.
    a.get("/healthz").expect("a again");
    drop(a);
    drop(b);
    // Freed capacity is reusable (allow a beat for the handler threads
    // to notice the closes and decrement).
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        let mut c = Client::connect(&addr).expect("conn c");
        match c.get("/healthz") {
            Ok(_) => break,
            Err(_) if std::time::Instant::now() < deadline => {
                std::thread::sleep(Duration::from_millis(50));
            }
            Err(e) => panic!("capacity never freed: {e}"),
        }
    }
    server.shutdown();
}

/// Stream capacity (scheduler `max_streams`) is enforced at open with a
/// `503`, and shutdown works from the `Drop` path too.
#[test]
fn stream_capacity_and_drop_shutdown() {
    let sched = Scheduler::spawn(
        SchedulerConfig {
            workers: 1,
            max_streams: 2,
            ..SchedulerConfig::default()
        },
        tiny_engine,
    );
    sched.engine().warmup().expect("warmup");
    let server = Server::start(
        ServerConfig {
            listen: "127.0.0.1:0".to_string(),
            read_timeout: Duration::from_millis(200),
            ..ServerConfig::default()
        },
        sched,
    )
    .expect("start");
    let mut client = Client::connect(&addr_of(&server)).expect("connect");
    assert_eq!(client.open_stream().expect("first"), 0);
    assert_eq!(client.open_stream().expect("second"), 1);
    let err = client.open_stream().expect_err("third must be rejected");
    assert!(err.contains("503"), "{err}");
    drop(server); // Drop path: must not panic or deadlock.
}

/// The full harness loop: redline drives a live server open-loop, the
/// report carries served identity + percentiles, and comparing a run
/// against itself is regression-free.
#[test]
fn redline_run_and_compare_end_to_end() {
    let server = start_server(16, 2);
    let cfg = RunConfig {
        addr: addr_of(&server),
        rps: 60.0,
        burst: 4,
        duration: Duration::from_millis(900),
        streams: 2,
        connections: 2,
        mix: (1, 4),
        steps: 2,
        deadline_ms: None,
    };
    let report = loadgen::run(&cfg).expect("redline run");
    assert!(report.decode.requests > 0, "no decodes issued");
    assert_eq!(report.decode.errors, 0, "decode errors");
    assert_eq!(report.append.errors, 0, "append errors");
    assert_eq!(report.decode.tokens, 2 * report.decode.requests);
    assert!(report.decode.hist.percentile(0.99) > 0);

    let text = report.to_json();
    let doc = Json::parse(&text).expect("run file parses");
    let entries = doc.get("entries").and_then(Json::as_arr).expect("entries");
    assert!(!entries.is_empty());
    for e in entries {
        assert_eq!(e.get("mode").and_then(Json::as_str), Some("served"));
        assert_eq!(e.get("policy").and_then(Json::as_str), Some("topk"));
        assert!(e.get("tokens_per_s").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(e.get("p99_us").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(e.get("p999_us").is_some());
    }

    // Same build, same run → identical file → zero regressions: the
    // `redline compare` half of the acceptance criterion.
    let report2 = compare_files(&text, &text, 10.0).expect("compare");
    assert_eq!(report2.regressions(), 0);
    assert!(report2.matched >= 1);
    assert!(report2.render().contains("0 regression(s)"));
    server.shutdown();
}

/// Decode responses from concurrent network streams are bit-identical
/// to solo in-process decoding even through the batching window (the
/// scheduler's fused path guarantees it; this pins the network layer on
/// top of it).
#[test]
fn served_batched_decodes_stay_bit_identical() {
    let sched = Scheduler::spawn(
        SchedulerConfig {
            workers: 2,
            batch_window: Duration::from_micros(300),
            ..SchedulerConfig::default()
        },
        tiny_engine,
    );
    sched.engine().warmup().expect("warmup");
    let server = Server::start(
        ServerConfig {
            listen: "127.0.0.1:0".to_string(),
            read_timeout: Duration::from_millis(200),
            ..ServerConfig::default()
        },
        sched,
    )
    .expect("start");
    let addr = addr_of(&server);

    // Reference: two independent in-process sessions.
    let reference = tiny_engine();
    reference.warmup().expect("warmup");
    let spec = reference.spec();
    let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 2, 11);
    let token = vec![0.05f32; spec.d];
    let mut expected: Vec<Vec<Vec<f32>>> = Vec::new();
    for s in 0..2 {
        let session = reference.new_session();
        session.append_frame(&trace.frame(s)).expect("ref append");
        expected.push(
            (0..2)
                .map(|_| session.decode_step(&token).expect("ref decode").0)
                .collect(),
        );
    }

    // Served: two clients decoding concurrently through the window.
    let mut handles = Vec::new();
    for s in 0..2usize {
        let addr = addr.clone();
        let frame = trace.frame(s);
        let token = token.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            let stream = client.open_stream().expect("open");
            client.append(stream, &frame).expect("append");
            let mut outs = Vec::new();
            for _ in 0..2 {
                let mut body = String::from("{\"echo\":true,\"steps\":1,\"token\":");
                json::push_f32_array(&mut body, &token);
                body.push('}');
                let reply = client
                    .request("POST", &format!("/v1/streams/{stream}/decode"), &body)
                    .expect("decode");
                outs.push(reply.get("output").and_then(Json::as_f32s).expect("echo"));
            }
            // Key by the frame index, not the server-assigned stream
            // id — open order between the threads is racy.
            (s, outs)
        }));
    }
    for handle in handles {
        let (s, outs) = handle.join().expect("client thread");
        for (step, out) in outs.iter().enumerate() {
            assert_bits_eq(out, &expected[s][step], &format!("client {s} step {step}"));
        }
    }
    server.shutdown();
}

/// Keep-alive raw-status client: like [`Client`] but returning the
/// status line + body instead of folding non-2xx into an error string,
/// so tests can inspect shed responses (`429` + `retry_after_ms`).
struct RawClient {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl RawClient {
    fn connect(addr: &str) -> Self {
        let stream = TcpStream::connect(addr).expect("connect");
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
        let writer = stream.try_clone().expect("clone socket");
        Self {
            writer,
            reader: BufReader::new(stream),
        }
    }

    fn post(&mut self, path: &str, body: &str) -> (u16, String) {
        let head = format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: keep-alive\r\n\r\n",
            body.len()
        );
        self.writer.write_all(head.as_bytes()).expect("send head");
        self.writer.write_all(body.as_bytes()).expect("send body");
        let (status, bytes, _keep) = http::read_response(&mut self.reader).expect("response");
        (status, String::from_utf8_lossy(&bytes).into_owned())
    }
}

fn frame_body(frame: &[f32]) -> String {
    let mut body = String::from("{\"frame\":");
    json::push_f32_array(&mut body, frame);
    body.push('}');
    body
}

fn metric_value(text: &str, name: &str) -> Option<f64> {
    text.lines()
        .find(|l| l.starts_with(name))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|v| v.parse().ok())
}

/// SLO admission over the wire: once queue delay blows past the SLO,
/// prefills get `429` with a machine-readable `retry_after_ms`, the
/// shed shows up in `/metrics`, and after the flood drains the same
/// traffic is admitted again (shedding is load control, not a latch).
#[test]
fn overloaded_prefills_get_429_then_recover_after_drain() {
    let sched = Scheduler::spawn(
        SchedulerConfig::default()
            .with_workers(1)
            .with_slo(Some(Duration::from_millis(1))),
        tiny_engine,
    );
    sched.engine().warmup().expect("warmup");
    let server = Server::start(
        ServerConfig {
            listen: "127.0.0.1:0".to_string(),
            max_connections: 32,
            read_timeout: Duration::from_millis(500),
            ..ServerConfig::default()
        },
        sched,
    )
    .expect("start");
    let addr = addr_of(&server);

    let mut control = Client::connect(&addr).expect("connect");
    let cfg = control.get("/v1/config").expect("config");
    let d = cfg.get("d").and_then(Json::as_usize).unwrap();
    let tpf = cfg.get("tokens_per_frame").and_then(Json::as_usize).unwrap();
    let frame = FrameTrace::new(d, tpf, 1, 11).frame(0);
    let token = vec![0.05f32; d];

    // A long interactive decode keeps the worker's priority lane hot so
    // bulk prefills age in their queue instead of draining instantly.
    let hog_stream = control.open_stream().expect("hog stream");
    control.append(hog_stream, &frame).expect("prime hog");
    let hog = {
        let addr = addr.clone();
        let token = token.clone();
        std::thread::spawn(move || {
            let mut c = Client::connect(&addr).expect("hog connect");
            c.decode(hog_stream, &token, 512, Some(1000)).expect("hog decode");
        })
    };

    // Six connections flooding prefills through the single worker.
    let shed_bodies: Vec<(u16, String)> = {
        let mut floods = Vec::new();
        for _ in 0..6 {
            let addr = addr.clone();
            let body = frame_body(&frame);
            floods.push(std::thread::spawn(move || {
                let mut c = RawClient::connect(&addr);
                let mut open = Client::connect(&addr).expect("open conn");
                let stream = open.open_stream().expect("flood stream");
                let mut sheds = Vec::new();
                for _ in 0..80 {
                    let (status, reply) =
                        c.post(&format!("/v1/streams/{stream}/append"), &body);
                    if status == 429 {
                        sheds.push((status, reply));
                    }
                }
                sheds
            }));
        }
        floods
            .into_iter()
            .flat_map(|h| h.join().expect("flood thread"))
            .collect()
    };
    hog.join().expect("hog thread");

    assert!(
        !shed_bodies.is_empty(),
        "a 1ms SLO under a 6-way flood must shed at least one prefill"
    );
    for (_, body) in &shed_bodies {
        assert!(body.contains("retry_after_ms"), "shed body lacks hint: {body}");
    }

    // The sheds are visible per class on /metrics.
    let (status, body) = raw_request(&addr, b"GET /metrics HTTP/1.1\r\n\r\n");
    assert_eq!(status, 200);
    let text = String::from_utf8(body).unwrap();
    let shed = metric_value(&text, "nc_shed_total{class=\"bulk\"}").expect("shed metric");
    assert!(shed >= 1.0, "metrics did not count the sheds:\n{text}");

    // Recovery: queue drained → the same request is admitted again.
    std::thread::sleep(Duration::from_millis(200));
    control.append(hog_stream, &frame).expect("admitted after drain");
    server.shutdown();
}

/// Decode latency stays bounded while prefills saturate the worker:
/// the interactive queue plus chunked prefill means a decode never
/// waits out a whole flood of queued prefills.
#[test]
fn decode_stays_responsive_under_prefill_flood() {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    let sched = Scheduler::spawn(
        SchedulerConfig::default()
            .with_workers(1)
            .with_slo(None)
            .with_prefill_chunk(1),
        tiny_engine,
    );
    sched.engine().warmup().expect("warmup");
    let server = Server::start(
        ServerConfig {
            listen: "127.0.0.1:0".to_string(),
            max_connections: 32,
            read_timeout: Duration::from_millis(500),
            ..ServerConfig::default()
        },
        sched,
    )
    .expect("start");
    let addr = addr_of(&server);

    let mut control = Client::connect(&addr).expect("connect");
    let cfg = control.get("/v1/config").expect("config");
    let d = cfg.get("d").and_then(Json::as_usize).unwrap();
    let tpf = cfg.get("tokens_per_frame").and_then(Json::as_usize).unwrap();
    let frame = FrameTrace::new(d, tpf, 1, 11).frame(0);
    let token = vec![0.05f32; d];

    let decode_stream = control.open_stream().expect("decode stream");
    control.append(decode_stream, &frame).expect("prime");

    let stop = Arc::new(AtomicBool::new(false));
    let mut floods = Vec::new();
    for _ in 0..6 {
        let addr = addr.clone();
        let frame = frame.clone();
        let stop = Arc::clone(&stop);
        floods.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).expect("flood connect");
            let stream = c.open_stream().expect("flood stream");
            while !stop.load(Ordering::Relaxed) {
                let _ = c.append(stream, &frame); // errors fine: load, not data
            }
        }));
    }

    // Interactive decodes in the thick of the flood: every one must
    // come back promptly (no starvation), and correctly.
    let mut worst = Duration::ZERO;
    for _ in 0..20 {
        let start = std::time::Instant::now();
        control
            .decode(decode_stream, &token, 1, Some(5))
            .expect("decode under flood");
        worst = worst.max(start.elapsed());
    }
    stop.store(true, Ordering::Relaxed);
    for h in floods {
        h.join().expect("flood thread");
    }
    assert!(
        worst < Duration::from_secs(1),
        "decode starved behind the prefill flood: worst {worst:?}"
    );
    server.shutdown();
}
