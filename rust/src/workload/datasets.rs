//! Evaluation "datasets" and the accuracy proxy.
//!
//! The paper evaluates on TempCompass / NExT-QA (multiple-choice accuracy)
//! and VideoDetailCaption (0–5 GPT score). We cannot run those; instead
//! each dataset becomes a named proxy curve mapping **retained importance
//! fraction** (the paper's own Appendix-N proxy) to task quality, with
//! dataset-specific dense scores and degradation knees, plus the small
//! mid-sparsity regularization bump §4.2 notes (accuracy can tick *up*
//! when weak/noisy activations are dropped).

/// One evaluation dataset: naming, sampling seed, and proxy parameters.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: String,
    pub seed: u64,
    /// Accuracy (or normalized score) of the dense model.
    pub dense_score: f64,
    /// Chance floor (multiple choice: 1/#options; captioning: low score).
    pub floor_score: f64,
    /// Retained-importance fraction where quality is halfway degraded.
    pub knee: f64,
    /// Degradation sharpness (higher = cliffier).
    pub sharpness: f64,
    /// Amplitude of the mid-sparsity regularization bump.
    pub bump: f64,
}

impl DatasetSpec {
    pub fn tempcompass() -> Self {
        Self {
            name: "tempcompass".into(),
            seed: 101,
            dense_score: 0.621,
            floor_score: 0.25,
            knee: 0.70,
            sharpness: 12.0,
            bump: 0.006,
        }
    }

    pub fn nextqa() -> Self {
        Self {
            name: "nextqa".into(),
            seed: 202,
            dense_score: 0.583,
            floor_score: 0.20,
            knee: 0.72,
            sharpness: 11.0,
            bump: 0.004,
        }
    }

    /// VideoDetailCaption: 0–5 GPT score, reported normalized to [0,1].
    pub fn videodc() -> Self {
        Self {
            name: "videodc".into(),
            seed: 303,
            dense_score: 3.31 / 5.0,
            floor_score: 1.1 / 5.0,
            knee: 0.66,
            sharpness: 10.0,
            bump: 0.005,
        }
    }

    pub fn all() -> Vec<DatasetSpec> {
        vec![Self::tempcompass(), Self::nextqa(), Self::videodc()]
    }

    pub fn by_name(name: &str) -> Option<DatasetSpec> {
        Self::all().into_iter().find(|d| d.name == name)
    }
}

/// Maps retained-importance fraction → task quality for a dataset.
#[derive(Clone, Debug)]
pub struct AccuracyModel {
    spec: DatasetSpec,
}

impl AccuracyModel {
    pub fn new(spec: DatasetSpec) -> Self {
        Self { spec }
    }

    /// Quality at a retained-importance fraction `r ∈ [0, 1]`.
    ///
    /// Monotone logistic from floor to dense score, plus a small bump
    /// peaking around r≈0.9 (mild sparsity acts as regularization).
    pub fn score(&self, retained: f64) -> f64 {
        let s = &self.spec;
        let r = retained.clamp(0.0, 1.0);
        let x = (r - s.knee) * s.sharpness;
        let logistic = 1.0 / (1.0 + (-x).exp());
        // Rescale so score(1.0) == dense exactly.
        let at_one = 1.0 / (1.0 + (-(1.0 - s.knee) * s.sharpness).exp());
        let at_zero = 1.0 / (1.0 + (s.knee * s.sharpness).exp());
        let base = s.floor_score
            + (s.dense_score - s.floor_score) * (logistic - at_zero) / (at_one - at_zero);
        let bump = s.bump * (-(r - 0.9f64).powi(2) / 0.008).exp();
        base + bump
    }

    pub fn dataset(&self) -> &DatasetSpec {
        &self.spec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_score_exact_at_full_retention() {
        for spec in DatasetSpec::all() {
            let dense = spec.dense_score;
            let m = AccuracyModel::new(spec);
            assert!((m.score(1.0) - dense).abs() < 0.01);
        }
    }

    #[test]
    fn degrades_to_floor() {
        let m = AccuracyModel::new(DatasetSpec::tempcompass());
        assert!(m.score(0.0) < 0.30);
        assert!(m.score(0.0) >= 0.2);
    }

    #[test]
    fn mostly_monotone_with_small_bump() {
        let m = AccuracyModel::new(DatasetSpec::nextqa());
        // Monotone over the main range...
        let mut prev = 0.0;
        for i in 0..=80 {
            let r = i as f64 / 100.0;
            let s = m.score(r);
            assert!(s >= prev - 1e-6, "drop at r={r}");
            prev = s;
        }
        // ...and the bump can push slightly above dense near r=0.9
        // (the paper's "slight accuracy gain at higher sparsity").
        let peak = (80..=100)
            .map(|i| m.score(i as f64 / 100.0))
            .fold(0.0f64, f64::max);
        assert!(peak >= m.score(1.0) - 1e-9);
    }

    #[test]
    fn flat_region_near_dense_then_knee() {
        // Dropping 10% of importance costs almost nothing; past the knee
        // the curve falls (the paper's Fig 6 shape: flat to moderate
        // sparsity, degrading beyond).
        let m = AccuracyModel::new(DatasetSpec::tempcompass());
        assert!(m.score(1.0) - m.score(0.9) < 0.03);
        assert!(m.score(0.9) - m.score(0.6) > 0.1);
    }

    #[test]
    fn by_name() {
        assert!(DatasetSpec::by_name("tempcompass").is_some());
        assert!(DatasetSpec::by_name("imagenet").is_none());
    }
}
