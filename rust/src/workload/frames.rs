//! Synthetic streaming-video frame traces for the runnable models.
//!
//! Frames arrive as `tokens_per_frame` d-dimensional embeddings (what the
//! vision encoder + projector would emit — the paper keeps the vision
//! encoder in memory and out of scope). Consecutive frames are temporally
//! correlated (AR(1) over a scene latent) so KV/activation statistics
//! drift like real video. `pooling` reduces tokens per frame (Fig 16's
//! spatial-pooling token-density knob).

use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct FrameTrace {
    pub d: usize,
    pub tokens_per_frame: usize,
    pub frames: usize,
    /// Spatial pooling factor (1 = full density; 4 = quarter tokens).
    pub pooling: usize,
    /// Temporal correlation of the scene latent (0..1).
    pub temporal_rho: f64,
    seed: u64,
}

impl FrameTrace {
    pub fn new(d: usize, tokens_per_frame: usize, frames: usize, seed: u64) -> Self {
        Self {
            d,
            tokens_per_frame,
            frames,
            pooling: 1,
            temporal_rho: 0.85,
            seed,
        }
    }

    pub fn with_pooling(mut self, pooling: usize) -> Self {
        assert!(pooling >= 1);
        self.pooling = pooling;
        self
    }

    /// Effective tokens per frame after pooling.
    pub fn tokens(&self) -> usize {
        (self.tokens_per_frame / self.pooling).max(1)
    }

    /// Frame `f`'s token embeddings, row-major [tokens(), d].
    ///
    /// Scene latent evolves as AR(1); tokens are latent + iid detail.
    /// Pooling averages adjacent unpooled tokens (like spatial pooling),
    /// which *smooths* embeddings — the mechanism behind Fig 16's accuracy
    /// drop at low densities.
    pub fn frame(&self, f: usize) -> Vec<f32> {
        let mut latent = vec![0.0f64; self.d];
        let mut rng = Rng::new(self.seed ^ 0xABCD);
        for v in latent.iter_mut() {
            *v = rng.normal();
        }
        // Roll the latent forward to frame f (deterministic, O(f·d); frame
        // counts here are tens, not millions).
        for step in 0..=f {
            let mut step_rng = Rng::new(self.seed ^ (step as u64 + 1).wrapping_mul(0x5851F42D));
            let rho = self.temporal_rho;
            for v in latent.iter_mut() {
                *v = rho * *v + (1.0 - rho * rho).sqrt() * step_rng.normal();
            }
        }
        let mut tok_rng = Rng::new(self.seed ^ (f as u64).wrapping_mul(0xD1B54A33) ^ 0x7777);
        let full: Vec<f32> = (0..self.tokens_per_frame)
            .flat_map(|_| {
                latent
                    .iter()
                    .map(|&l| (0.6 * l + 0.4 * tok_rng.normal()) as f32 * 0.35)
                    .collect::<Vec<f32>>()
            })
            .collect();
        if self.pooling == 1 {
            return full;
        }
        // Average groups of `pooling` consecutive tokens.
        let t_out = self.tokens();
        let mut out = vec![0.0f32; t_out * self.d];
        for to in 0..t_out {
            let lo = to * self.pooling;
            let hi = ((to + 1) * self.pooling).min(self.tokens_per_frame);
            for j in 0..self.d {
                let mut acc = 0.0f32;
                for ti in lo..hi {
                    acc += full[ti * self.d + j];
                }
                out[to * self.d + j] = acc / (hi - lo) as f32;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_shape() {
        let t = FrameTrace::new(64, 16, 10, 1);
        assert_eq!(t.frame(0).len(), 16 * 64);
        let p = FrameTrace::new(64, 16, 10, 1).with_pooling(4);
        assert_eq!(p.tokens(), 4);
        assert_eq!(p.frame(0).len(), 4 * 64);
    }

    #[test]
    fn deterministic() {
        let t = FrameTrace::new(32, 8, 5, 9);
        assert_eq!(t.frame(2), t.frame(2));
        assert_ne!(t.frame(2), t.frame(3));
    }

    #[test]
    fn consecutive_frames_correlated_distant_less() {
        let t = FrameTrace::new(128, 4, 40, 3);
        let corr = |a: &[f32], b: &[f32]| {
            let (ma, mb) = (
                a.iter().sum::<f32>() / a.len() as f32,
                b.iter().sum::<f32>() / b.len() as f32,
            );
            let mut num = 0.0;
            let mut da = 0.0;
            let mut db = 0.0;
            for i in 0..a.len() {
                num += (a[i] - ma) * (b[i] - mb);
                da += (a[i] - ma).powi(2);
                db += (b[i] - mb).powi(2);
            }
            num / (da.sqrt() * db.sqrt())
        };
        let f0 = t.frame(0);
        let f1 = t.frame(1);
        let f30 = t.frame(30);
        assert!(corr(&f0, &f1) > corr(&f0, &f30) + 0.1);
    }

    #[test]
    fn pooling_reduces_token_variance() {
        let full = FrameTrace::new(64, 16, 5, 7);
        let pooled = FrameTrace::new(64, 16, 5, 7).with_pooling(4);
        let var_of = |frame: &[f32], t: usize, d: usize| {
            // mean variance across token dimension
            let mut acc = 0.0f64;
            for j in 0..d {
                let col: Vec<f64> = (0..t).map(|i| frame[i * d + j] as f64).collect();
                acc += crate::stats::variance(&col);
            }
            acc / d as f64
        };
        let vf = var_of(&full.frame(1), 16, 64);
        let vp = var_of(&pooled.frame(1), 4, 64);
        assert!(vp < vf, "pooling should smooth: {vp} vs {vf}");
    }
}
