//! Synthetic workload substrate.
//!
//! The paper's inputs we cannot ship (video QA datasets, 7B VLM
//! activations) are replaced by calibrated generators:
//!
//! * [`ActivationGen`] — per-matrix neuron-importance traces with the
//!   smoothness statistics of Table 1 (VLM CV ≈ 1.1–4.5, ReLU-LLM
//!   CV ≈ 8–12) and the hot/cold frequency structure of Fig 11.
//! * [`FrameTrace`] — synthetic streaming-video token embeddings for the
//!   runnable models (Fig 16's token-density knob included).
//! * [`DatasetSpec`]/[`AccuracyModel`] — the three evaluation "datasets"
//!   as named accuracy-proxy curves mapping retained importance to task
//!   accuracy (the paper itself uses retained importance as the proxy in
//!   Appendix N).

mod activations;
mod datasets;
mod frames;

pub use activations::{ActivationGen, ActivationKind};
pub use datasets::{AccuracyModel, DatasetSpec};
pub use frames::FrameTrace;
