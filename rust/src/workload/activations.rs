//! Neuron-importance trace generators.
//!
//! Structure per neuron `i`:
//! * a base *activation frequency* `f_i` drawn from a hot/mid/cold
//!   mixture (Fig 11: many neurons neither always-on nor always-off);
//! * per sample, neuron `i` is "active" with probability `f_i` (plus an
//!   input-dependent shared component so co-activation exists);
//! * active neurons draw lognormal magnitudes. VLM traces average `tokens`
//!   independent token draws (the §2.2 smoothing mechanism — this is what
//!   pushes CV down into the 1–4 band); ReLU-LLM traces are single-token
//!   and hard-zero inactive neurons (CV ≈ 8–12, Table 1's OPT-6.7B).

use crate::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ActivationKind {
    /// Gated-activation VLM in the frame-appending phase (smooth).
    SmoothVlm,
    /// ReLU LLM in the decode phase (spiky, hard zeros).
    SpikyRelu,
}

/// Deterministic importance-trace generator for one matrix.
#[derive(Clone, Debug)]
pub struct ActivationGen {
    pub kind: ActivationKind,
    /// Neuron count (matrix rows).
    pub n: usize,
    /// Tokens averaged per sample (VLM frame: e.g. 196; decode: 1).
    pub tokens: usize,
    /// Per-token lognormal sigma.
    pub sigma: f64,
    /// Fractions of hot (f≈1) and cold (f≈0) neurons.
    pub hot_frac: f64,
    pub cold_frac: f64,
    /// Neuron base frequencies (built at construction).
    freq: Vec<f64>,
    /// Precomputed activity logits (ln(f/(1-f))) — `sample` is on the
    /// figure-sweep hot path, so the per-neuron ln() is hoisted here.
    logit: Vec<f64>,
    /// Persistent per-neuron magnitude scale (hot neurons boosted, cold
    /// damped) — what makes hot/cold populations visible through the
    /// sample noise, as in Fig 11.
    base: Vec<f64>,
    seed: u64,
}

impl ActivationGen {
    /// Smooth VLM generator calibrated to Table 1's CV band. `layer_pos`
    /// in [0,1] shifts CV upward toward late layers (Table 1: last layers
    /// have CV 2.5–4.6 vs ~1.1–1.4 early).
    ///
    /// The token-averaged magnitude is sampled *directly* from the
    /// averaged distribution (CLT on T iid lognormals: CV divides by
    /// ~sqrt(T)) rather than drawing T per-token values — O(n) per sample
    /// instead of O(n·T), which matters at paper scale (18944 rows × 196
    /// tokens). `tokens` therefore only shapes the effective smoothness.
    pub fn vlm(n: usize, tokens: usize, layer_pos: f64, seed: u64) -> Self {
        // CV of the *averaged* importance this generator should produce.
        // Fewer tokens per frame -> less averaging -> higher CV (the
        // Fig 16 token-density mechanism), anchored at 196 tokens.
        let target_cv = (0.95 + 1.45 * layer_pos.powi(2)) * (196.0 / tokens.max(1) as f64).sqrt().min(3.0);
        let sigma = (1.0 + target_cv * target_cv).ln().sqrt();
        let mut gen = Self {
            kind: ActivationKind::SmoothVlm,
            n,
            tokens,
            sigma,
            hot_frac: 0.12,
            cold_frac: 0.10,
            freq: Vec::new(),
            logit: Vec::new(),
            base: Vec::new(),
            seed,
        };
        gen.build_population();
        gen
    }

    /// Spiky ReLU-LLM generator (decode phase, single token, hard zeros).
    pub fn relu(n: usize, seed: u64) -> Self {
        let mut gen = Self {
            kind: ActivationKind::SpikyRelu,
            n,
            tokens: 1,
            sigma: 1.9,
            hot_frac: 0.03,
            cold_frac: 0.62,
            freq: Vec::new(),
            logit: Vec::new(),
            base: Vec::new(),
            seed,
        };
        gen.build_population();
        gen
    }

    fn build_population(&mut self) {
        let mut rng = Rng::new(self.seed ^ 0xF00D);
        // Persistent magnitude scale carries ~70% of the log-variance; the
        // per-sample noise carries the rest (split below in `sample`).
        let sigma_b = 0.7 * self.sigma;
        let mu_b = -0.5 * sigma_b * sigma_b;
        let mut freq = Vec::with_capacity(self.n);
        let mut base = Vec::with_capacity(self.n);
        for _ in 0..self.n {
            let u = rng.f64();
            let (f, boost) = if u < self.hot_frac {
                (0.995 + 0.005 * rng.f64(), 2.5)
            } else if u < self.hot_frac + self.cold_frac {
                (0.005 * rng.f64(), 0.4)
            } else {
                // Mid population: Beta-like hump via powered uniform.
                (0.15 + 0.7 * rng.f64().powf(0.8), 1.0)
            };
            freq.push(f);
            base.push(boost * rng.lognormal(mu_b, sigma_b));
        }
        self.logit = freq
            .iter()
            .map(|&f| {
                let f = f.clamp(1e-4, 1.0 - 1e-4);
                (f / (1.0 - f)).ln()
            })
            .collect();
        self.freq = freq;
        self.base = base;
    }

    pub fn frequencies(&self) -> &[f64] {
        &self.freq
    }

    /// Generate the importance vector for sample `idx` (deterministic).
    pub fn sample(&self, idx: u64) -> Vec<f32> {
        let mut rng = Rng::new(self.seed ^ idx.wrapping_mul(0x9E3779B97F4A7C15));
        // Input-dependent global shift: correlates activity across neurons
        // within one sample (drives co-activation + input adaptivity).
        let input_bias = rng.normal() * 0.35;
        let sigma_n = 0.714 * self.sigma; // sample-noise share of variance
        let mu = -0.5 * sigma_n * sigma_n; // mean-1 noise
        let mut out = Vec::with_capacity(self.n);
        for i in 0..self.n {
            // Effective per-token activity probability for this sample.
            let p = 1.0 / (1.0 + (-(self.logit[i] + input_bias)).exp());
            let mut acc = 0.0f64;
            match self.kind {
                ActivationKind::SpikyRelu => {
                    if rng.bool(p) {
                        acc = self.base[i] * rng.lognormal(mu, sigma_n);
                    }
                }
                ActivationKind::SmoothVlm => {
                    // Token-averaged gated activations, sampled from the
                    // averaged distribution directly (see `vlm` docs).
                    // Inactive tokens still contribute small non-zero
                    // magnitudes (SwiGLU/GeLU never hard-zero), so the
                    // activity mix scales the mean, never zeroes it.
                    let mix = p + (1.0 - p) * 0.04;
                    acc = mix * self.base[i] * rng.lognormal(mu, sigma_n);
                }
            }
            out.push(acc as f32);
        }
        out
    }

    /// Batch of samples (calibration sets).
    pub fn samples(&self, count: usize, from: u64) -> Vec<Vec<f32>> {
        (0..count as u64).map(|i| self.sample(from + i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats;

    fn cv_of(gen: &ActivationGen, samples: usize) -> f64 {
        let cvs: Vec<f64> = (0..samples as u64)
            .map(|i| {
                let s = gen.sample(i);
                let v: Vec<f64> = s.iter().map(|&x| x as f64).collect();
                stats::cv(&v)
            })
            .collect();
        stats::mean(&cvs)
    }

    #[test]
    fn vlm_cv_in_table1_band() {
        // Early/mid layers: CV ~1.0–2.0; late layers ~2.5–4.6.
        let early = cv_of(&ActivationGen::vlm(2048, 196, 0.0, 1), 8);
        let late = cv_of(&ActivationGen::vlm(2048, 196, 1.0, 2), 8);
        assert!((0.8..2.2).contains(&early), "early CV {early}");
        assert!((2.0..5.5).contains(&late), "late CV {late}");
        assert!(late > early);
    }

    #[test]
    fn relu_cv_much_higher() {
        let relu = cv_of(&ActivationGen::relu(2048, 3), 8);
        let vlm = cv_of(&ActivationGen::vlm(2048, 196, 0.3, 3), 8);
        assert!(relu > 4.0, "ReLU CV {relu}");
        assert!(relu > 2.5 * vlm, "relu {relu} vs vlm {vlm}");
    }

    #[test]
    fn relu_has_hard_zeros_vlm_does_not() {
        let r = ActivationGen::relu(1024, 5).sample(0);
        let v = ActivationGen::vlm(1024, 64, 0.5, 5).sample(0);
        let zr = r.iter().filter(|&&x| x == 0.0).count();
        let zv = v.iter().filter(|&&x| x == 0.0).count();
        assert!(zr > 300, "ReLU zeros {zr}");
        assert_eq!(zv, 0, "VLM must not hard-zero");
    }

    #[test]
    fn deterministic() {
        let g = ActivationGen::vlm(256, 16, 0.5, 9);
        assert_eq!(g.sample(3), g.sample(3));
        assert_ne!(g.sample(3), g.sample(4));
    }

    #[test]
    fn hot_cold_structure_visible_in_frequency() {
        let g = ActivationGen::vlm(4000, 196, 0.3, 11);
        let samples = g.samples(30, 0);
        let freq = crate::reorder::activation_frequency(&samples, 4000);
        let (hot, cold) = crate::reorder::hot_cold_fractions(&freq);
        // Fig 11: nontrivial hot and cold populations, plus a large middle.
        assert!(hot > 0.02, "hot {hot}");
        assert!(cold > 0.02, "cold {cold}");
        assert!(hot + cold < 0.7, "middle population missing");
    }

    #[test]
    fn input_dependence() {
        // Different samples select measurably different top-halves
        // (input-aware sparsification must matter — Fig 9 ablation).
        let g = ActivationGen::vlm(1024, 196, 0.3, 13);
        let a = g.sample(0);
        let b = g.sample(1);
        let top = |s: &[f32]| {
            let mut idx: Vec<usize> = (0..s.len()).collect();
            idx.sort_by(|&x, &y| s[y].total_cmp(&s[x]));
            idx[..512].iter().copied().collect::<std::collections::HashSet<_>>()
        };
        let overlap = top(&a).intersection(&top(&b)).count();
        assert!(overlap < 490, "overlap {overlap}/512 too high");
        assert!(overlap > 256, "overlap {overlap}/512 too low (no stable hot set)");
    }
}
