//! Shared cross-session hot-chunk RAM cache ([`ChunkCache`]).
//!
//! One instance is owned by the engine core and shared by every session
//! and scheduler worker. The cache is *chunk-granular*: residency is
//! tracked per selection row, but admission and eviction always move
//! whole contiguous runs ([`Chunk`]s), mirroring the paper's chunk-based
//! I/O unit. Admission is frequency-driven: the decode hot path records
//! which rows each step selects (lock-free atomic counters, optionally
//! pre-seeded from the `reorder/` calibration priors), and a maintenance
//! pass — off the critical path — promotes the most frequently selected
//! rows until a global byte budget is filled, evicting whole chunks that
//! fell out of the hot set. Counters decay by half on every maintenance
//! pass, so the admission policy tracks the *recent* hot set.
//!
//! Two serving modes:
//!
//! * **default** (`pricing = false`): the cache never changes *what* is
//!   selected or computed — it serves already-selected rows from RAM.
//!   Selected-chunk sets and decode outputs are bit-identical with the
//!   cache on or off; only the flash `ReadPlan` shrinks. Resident rows
//!   are subtracted from the group's chunk list *before* the I/O planner
//!   shards/fuses it, so the device pool only ever sees misses.
//! * **pricing** (`pricing = true`, opt-in): the paper's §5 cache
//!   semantics — resident rows are priced at (near-)zero by zeroing
//!   their importance before selection and unioning them into the
//!   compute set for free. This is equivalent to giving resident chunks
//!   a near-zero latency estimate in the importance ÷ latency utility
//!   (the selector spends its flash-latency budget elsewhere), but keeps
//!   the selector's chunk enumeration untouched. It changes selection,
//!   so it is off by default.
//!
//! Locking: one `RwLock` per (layer, selection-group) shard plus pure
//! atomics for the frequency tables. The decode hot path takes exactly
//! one shard read lock per group and writes only into caller-provided,
//! pre-reserved arena buffers, so steady-state decode stays
//! allocation-free. Maintenance is guarded by a try-lock flag — at most
//! one maintainer runs at a time, and it materializes admitted rows
//! *outside* the shard write lock.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::RwLock;

use crate::latency::{chunks_from_mask, Chunk};
use crate::model::{decode_row_into, DType};
use crate::reorder::drift_score;

/// Selection groups gather at most this many member matrices (Q/K/V).
pub const MAX_MEMBERS: usize = 3;

/// Row slot marker: row is not resident.
const NONE: u32 = u32::MAX;

/// Scale for virtual observations injected by [`ChunkCache::seed_prior`].
const SEED_OBSERVATIONS: f64 = 1024.0;

/// Static shape of one (layer, selection-group) shard.
#[derive(Clone, Copy, Debug)]
pub struct ShardSpec {
    /// Selection rows in the group (shared by all member matrices).
    pub rows: usize,
    /// f32s per row for each member matrix (0 = member slot unused).
    pub row_f32s: [usize; MAX_MEMBERS],
    /// *Encoded* bytes per row for each member matrix — the width a
    /// resident row occupies in RAM. Equals `row_f32s[m] * 4` for f32
    /// images; quantized images store their on-flash encoding, so the
    /// same byte budget holds 2–4× more rows.
    pub row_enc_bytes: [usize; MAX_MEMBERS],
    /// Flash bytes per row summed over members — the bytes a hit saves.
    pub flash_row_bytes_sum: u64,
}

impl ShardSpec {
    fn row_ram_bytes(&self) -> u64 {
        self.row_enc_bytes.iter().map(|&w| w as u64).sum()
    }
}

/// One resident run of rows with its materialized weights per member,
/// stored in the image's encoded form (dequantized at staging time).
struct Entry {
    chunk: Chunk,
    /// `data[m]` holds `chunk.len * row_enc_bytes[m]` bytes, row-major.
    data: [Vec<u8>; MAX_MEMBERS],
}

struct ShardState {
    /// Row → index into `entries` (`NONE` when not resident).
    slot_of_row: Vec<u32>,
    entries: Vec<Entry>,
    /// Resident RAM bytes in this shard.
    bytes: u64,
    /// Calibrated activation profile (empty until seeded).
    baseline: Vec<f64>,
}

struct CacheShard {
    spec: ShardSpec,
    row_ram_bytes: u64,
    /// Live selection counts, one per row. Lock-free.
    freq: Vec<AtomicU32>,
    state: RwLock<ShardState>,
}

/// Byte-budgeted, chunk-granular RAM cache shared across sessions.
pub struct ChunkCache {
    shards: Vec<CacheShard>,
    groups_per_layer: usize,
    budget_bytes: u64,
    pricing: bool,
    /// Encoding of the resident bytes (the weight image's dtype).
    dtype: DType,
    /// Σ rows × row_ram_bytes over shards — budget-share denominator.
    total_weight: u64,
    maintaining: AtomicBool,
    admissions: AtomicU64,
    evictions: AtomicU64,
    resident_bytes: AtomicU64,
    hit_rows: AtomicU64,
    /// Latest traffic-weighted drift score, stored as f64 bits.
    drift_bits: AtomicU64,
}

impl ChunkCache {
    /// `shards` is laid out layer-major: shard `(layer, group)` lives at
    /// `layer * groups_per_layer + group`. `dtype` is the weight image's
    /// storage dtype — resident rows keep that encoding in RAM and are
    /// dequantized into the caller's f32 arenas at staging time.
    pub fn new(
        budget_bytes: u64,
        pricing: bool,
        groups_per_layer: usize,
        specs: Vec<ShardSpec>,
        dtype: DType,
    ) -> Self {
        assert!(groups_per_layer > 0);
        assert_eq!(specs.len() % groups_per_layer, 0);
        let total_weight = specs
            .iter()
            .map(|s| s.rows as u64 * s.row_ram_bytes())
            .sum();
        let shards = specs
            .into_iter()
            .map(|spec| CacheShard {
                row_ram_bytes: spec.row_ram_bytes(),
                freq: (0..spec.rows).map(|_| AtomicU32::new(0)).collect(),
                state: RwLock::new(ShardState {
                    slot_of_row: vec![NONE; spec.rows],
                    entries: Vec::new(),
                    bytes: 0,
                    baseline: Vec::new(),
                }),
                spec,
            })
            .collect();
        Self {
            shards,
            groups_per_layer,
            budget_bytes,
            pricing,
            dtype,
            total_weight,
            maintaining: AtomicBool::new(false),
            admissions: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            resident_bytes: AtomicU64::new(0),
            hit_rows: AtomicU64::new(0),
            drift_bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    fn shard(&self, layer: usize, group: usize) -> &CacheShard {
        &self.shards[layer * self.groups_per_layer + group]
    }

    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    pub fn pricing(&self) -> bool {
        self.pricing
    }

    pub fn groups_per_layer(&self) -> usize {
        self.groups_per_layer
    }

    pub fn resident_bytes(&self) -> u64 {
        self.resident_bytes.load(Ordering::Relaxed)
    }

    pub fn admissions(&self) -> u64 {
        self.admissions.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    pub fn hit_rows(&self) -> u64 {
        self.hit_rows.load(Ordering::Relaxed)
    }

    /// Latest drift score (traffic-weighted TV distance between the live
    /// hot-set profile and the calibrated baseline; see
    /// [`crate::reorder::drift_score`]).
    pub fn drift(&self) -> f64 {
        f64::from_bits(self.drift_bits.load(Ordering::Relaxed))
    }

    /// Worst-case rows one shard can ever hold under its budget share —
    /// sessions pre-reserve gather capacity from this so the cached hot
    /// path stays allocation-free.
    pub fn max_resident_rows(&self, layer: usize, group: usize) -> usize {
        let sh = self.shard(layer, group);
        if self.total_weight == 0 {
            return 0;
        }
        let share = (self.budget_bytes as u128
            * (sh.spec.rows as u64 * sh.row_ram_bytes) as u128
            / self.total_weight as u128) as u64;
        ((share / sh.row_ram_bytes.max(1)) as usize).min(sh.spec.rows)
    }

    /// Resident rows in one shard (tests/introspection).
    pub fn resident_rows(&self, layer: usize, group: usize) -> usize {
        let st = self.shard(layer, group).state.read().unwrap();
        st.entries.iter().map(|e| e.chunk.len).sum()
    }

    /// Record one decode step's selected chunks for a group. Lock-free;
    /// called from the hot path *before* cache subtraction so frequency
    /// reflects demand, not misses.
    pub fn record_selection(&self, layer: usize, group: usize, chunks: &[Chunk]) {
        let sh = self.shard(layer, group);
        for c in chunks {
            debug_assert!(c.end() <= sh.freq.len());
            for a in &sh.freq[c.start..c.end()] {
                a.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Pricing mode only: zero the importance of resident rows before
    /// selection and return the freed importance mass. Zero importance is
    /// the selector-side equivalent of a near-zero latency estimate: the
    /// importance ÷ latency utility stops paying flash cost for rows the
    /// cache will serve, and the freed budget buys additional chunks.
    pub fn zero_resident(&self, layer: usize, group: usize, imp: &mut [f32]) -> f64 {
        if !self.pricing {
            return 0.0;
        }
        let st = self.shard(layer, group).state.read().unwrap();
        let mut freed = 0.0f64;
        for e in &st.entries {
            for v in &mut imp[e.chunk.start..e.chunk.end()] {
                freed += *v as f64;
                *v = 0.0;
            }
        }
        freed
    }

    /// Subtract resident rows from a chunk list without staging any
    /// data. The decode path records prefetch predictions *after*
    /// [`ChunkCache::prepare`] has subtracted residents, so submit-ahead
    /// reads are already miss-only as of the step that recorded them;
    /// this helper lets a planner additionally re-subtract against
    /// *current* residency (e.g. after a maintenance pass admitted new
    /// rows). Not counted as hits (the rows have not been selected
    /// yet); [`ChunkCache::prepare`] accounts them when selection
    /// actually demands them.
    pub fn subtract_resident(
        &self,
        layer: usize,
        group: usize,
        chunks: &mut Vec<Chunk>,
        tmp: &mut Vec<Chunk>,
    ) {
        let sh = self.shard(layer, group);
        let st = sh.state.read().unwrap();
        if st.entries.is_empty() {
            return;
        }
        tmp.clear();
        for c in chunks.iter() {
            Self::split_runs(&st, c, tmp, None);
        }
        std::mem::swap(chunks, tmp);
    }

    /// Hot-path cache application for one group, under a single shard
    /// read lock. In default mode: subtract resident rows from
    /// `flash_chunks` (run-splitting, via `tmp`) and stage their weights
    /// into `staged_rows`/`staged_data` (ascending row order, matching
    /// the gather cursor). In pricing mode: additionally union resident
    /// rows into `phys_rows`/`selset` (the §5 free-compute union).
    ///
    /// All output buffers are caller-owned arenas; with sufficient
    /// reserved capacity this performs no heap allocation. Returns the
    /// flash bytes served from RAM.
    #[allow(clippy::too_many_arguments)]
    pub fn prepare(
        &self,
        layer: usize,
        group: usize,
        phys_rows: &mut Vec<usize>,
        selset: &mut [bool],
        flash_chunks: &mut Vec<Chunk>,
        tmp: &mut Vec<Chunk>,
        staged_rows: &mut Vec<usize>,
        staged_data: &mut [Vec<f32>; MAX_MEMBERS],
    ) -> u64 {
        staged_rows.clear();
        for v in staged_data.iter_mut() {
            v.clear();
        }
        let sh = self.shard(layer, group);
        let st = sh.state.read().unwrap();
        if st.entries.is_empty() {
            return 0;
        }
        tmp.clear();
        let mut hits = 0u64;
        if self.pricing {
            // Union all resident rows into the compute set for free.
            let before = phys_rows.len();
            for e in &st.entries {
                for r in e.chunk.start..e.chunk.end() {
                    if !selset[r] {
                        selset[r] = true;
                        phys_rows.push(r);
                    }
                }
            }
            if phys_rows.len() != before {
                phys_rows.sort_unstable();
            }
            // Subtract residents from the flash chunks (no staging yet —
            // staging below walks *all* residents in ascending order).
            for c in flash_chunks.iter() {
                Self::split_runs(&st, c, tmp, None);
            }
            std::mem::swap(flash_chunks, tmp);
            for (r, &s) in st.slot_of_row.iter().enumerate() {
                if s != NONE {
                    Self::stage_row(&st, &sh.spec, self.dtype, r, s, staged_rows, staged_data);
                    hits += 1;
                }
            }
        } else {
            // Subtract and stage in one ascending pass over the chunks.
            let mut stage = |r: usize, s: u32| {
                Self::stage_row(&st, &sh.spec, self.dtype, r, s, staged_rows, staged_data);
                hits += 1;
            };
            for c in flash_chunks.iter() {
                Self::split_runs(&st, c, tmp, Some(&mut stage));
            }
            std::mem::swap(flash_chunks, tmp);
        }
        self.hit_rows.fetch_add(hits, Ordering::Relaxed);
        hits * sh.spec.flash_row_bytes_sum
    }

    /// Split one chunk into its non-resident runs (pushed to `out`),
    /// optionally visiting each resident row in ascending order.
    fn split_runs(
        st: &ShardState,
        c: &Chunk,
        out: &mut Vec<Chunk>,
        mut on_hit: Option<&mut dyn FnMut(usize, u32)>,
    ) {
        let mut run_start = c.start;
        let mut run_len = 0usize;
        for (i, &s) in st.slot_of_row[c.start..c.end()].iter().enumerate() {
            let r = c.start + i;
            if s != NONE {
                if run_len > 0 {
                    out.push(Chunk::new(run_start, run_len));
                    run_len = 0;
                }
                if let Some(f) = on_hit.as_deref_mut() {
                    f(r, s);
                }
            } else {
                if run_len == 0 {
                    run_start = r;
                }
                run_len += 1;
            }
        }
        if run_len > 0 {
            out.push(Chunk::new(run_start, run_len));
        }
    }

    /// Dequantize one resident row into the staging arenas. `resize` on
    /// the pre-reserved arenas never reallocates at steady state, so the
    /// cached hot path stays allocation-free for every dtype.
    fn stage_row(
        st: &ShardState,
        spec: &ShardSpec,
        dtype: DType,
        row: usize,
        slot: u32,
        staged_rows: &mut Vec<usize>,
        staged_data: &mut [Vec<f32>; MAX_MEMBERS],
    ) {
        let e = &st.entries[slot as usize];
        let off = row - e.chunk.start;
        for (m, &w) in spec.row_f32s.iter().enumerate() {
            if w > 0 {
                let enc = spec.row_enc_bytes[m];
                let bytes = &e.data[m][off * enc..(off + 1) * enc];
                let start = staged_data[m].len();
                staged_data[m].resize(start + w, 0.0);
                decode_row_into(dtype, bytes, &mut staged_data[m][start..]);
            }
        }
        staged_rows.push(row);
    }

    /// Install a calibrated activation profile for one shard: sets the
    /// drift baseline and injects scaled virtual observations so the
    /// first maintenance pass admits the calibration-hot rows before any
    /// live traffic arrives.
    pub fn seed_prior(&self, layer: usize, group: usize, freq: &[f64]) {
        let sh = self.shard(layer, group);
        assert_eq!(freq.len(), sh.spec.rows);
        let mut st = sh.state.write().unwrap();
        st.baseline.clear();
        st.baseline.extend_from_slice(freq);
        let sum: f64 = freq.iter().sum();
        if sum > 0.0 {
            for (a, &f) in sh.freq.iter().zip(freq) {
                a.store((f / sum * SEED_OBSERVATIONS).round() as u32, Ordering::Relaxed);
            }
        }
    }

    /// Drop all resident entries, frequency counts, and baselines — used
    /// when the physical row space changes (online re-reorder) before
    /// re-seeding with profiles mapped into the new layout.
    pub fn clear_all(&self) {
        for sh in &self.shards {
            let mut st = sh.state.write().unwrap();
            self.resident_bytes.fetch_sub(st.bytes, Ordering::Relaxed);
            st.entries.clear();
            st.slot_of_row.fill(NONE);
            st.bytes = 0;
            st.baseline.clear();
            for a in &sh.freq {
                a.store(0, Ordering::Relaxed);
            }
        }
        self.drift_bits.store(0f64.to_bits(), Ordering::Relaxed);
    }

    /// Snapshot one shard's live selection counts (physical row space).
    pub fn frequency_snapshot(&self, layer: usize, group: usize, out: &mut Vec<f64>) {
        let sh = self.shard(layer, group);
        out.clear();
        out.extend(sh.freq.iter().map(|a| a.load(Ordering::Relaxed) as f64));
    }

    /// Maintenance pass (off the critical path): re-derive the desired
    /// resident set per shard from the decayed frequency counters, evict
    /// whole chunks that fell out of it, admit the runs that entered it
    /// (materialized via `fetch` *outside* the shard write lock), and
    /// recompute the drift score. At most one maintainer runs at a time;
    /// concurrent calls return the last drift score immediately.
    ///
    /// `fetch(layer, group, member, chunk, dst)` must fill `dst` with the
    /// member's *encoded* rows for `chunk` in physical row order
    /// (`chunk.len * row_enc_bytes[member]` bytes), byte-identical to
    /// what a flash read of those rows would return — staging then
    /// decodes exactly like the gather path, so cached rows stay
    /// bit-identical to flash-served ones at every dtype.
    ///
    /// Each shard's byte share of the global budget is proportional to
    /// its total weight footprint, so Σ resident bytes ≤ budget always
    /// holds by construction.
    pub fn maintain<F>(&self, mut fetch: F) -> f64
    where
        F: FnMut(usize, usize, usize, Chunk, &mut [u8]),
    {
        if self.maintaining.swap(true, Ordering::Acquire) {
            return self.drift();
        }
        let mut weighted = 0.0f64;
        let mut weight_sum = 0.0f64;
        for (idx, sh) in self.shards.iter().enumerate() {
            let layer = idx / self.groups_per_layer;
            let group = idx % self.groups_per_layer;
            let rows = sh.spec.rows;
            // Snapshot, then decay by half (new traffic keeps counting).
            let mut snap: Vec<u32> = Vec::with_capacity(rows);
            for a in &sh.freq {
                let v = a.load(Ordering::Relaxed);
                if v > 1 {
                    a.fetch_sub(v / 2, Ordering::Relaxed);
                }
                snap.push(v);
            }
            let traffic: u64 = snap.iter().map(|&v| v as u64).sum();

            // Drift: live profile vs calibrated baseline (uniform when
            // never calibrated — any skew then counts as drift).
            if traffic > 0 {
                let live: Vec<f64> = snap.iter().map(|&v| v as f64).collect();
                let st = sh.state.read().unwrap();
                let d = if st.baseline.iter().sum::<f64>() > 0.0 {
                    drift_score(&st.baseline, &live)
                } else {
                    drift_score(&vec![1.0; rows], &live)
                };
                drop(st);
                weighted += d * traffic as f64;
                weight_sum += traffic as f64;
            }

            // Desired resident set: hottest rows first until this
            // shard's budget share is spent; whole runs only.
            let share = if self.total_weight == 0 {
                0
            } else {
                (self.budget_bytes as u128 * (rows as u64 * sh.row_ram_bytes) as u128
                    / self.total_weight as u128) as u64
            };
            let max_rows = (share / sh.row_ram_bytes.max(1)) as usize;
            let mut order: Vec<usize> = (0..rows).filter(|&r| snap[r] > 0).collect();
            order.sort_unstable_by(|&a, &b| snap[b].cmp(&snap[a]).then(a.cmp(&b)));
            order.truncate(max_rows);
            let mut mask = vec![false; rows];
            for &r in &order {
                mask[r] = true;
            }
            let desired = chunks_from_mask(&mask);

            // Diff against the current residents under a read lock.
            let (to_evict, to_admit): (Vec<Chunk>, Vec<Chunk>) = {
                let st = sh.state.read().unwrap();
                let cur: Vec<Chunk> = st.entries.iter().map(|e| e.chunk).collect();
                (
                    cur.iter().filter(|c| !desired.contains(c)).copied().collect(),
                    desired.iter().filter(|c| !cur.contains(c)).copied().collect(),
                )
            };
            if to_evict.is_empty() && to_admit.is_empty() {
                continue;
            }

            // Materialize admissions outside the lock.
            let mats: Vec<Entry> = to_admit
                .iter()
                .map(|&chunk| {
                    let mut data: [Vec<u8>; MAX_MEMBERS] = Default::default();
                    for (m, &enc) in sh.spec.row_enc_bytes.iter().enumerate() {
                        if enc > 0 {
                            data[m].resize(chunk.len * enc, 0);
                            fetch(layer, group, m, chunk, &mut data[m]);
                        }
                    }
                    Entry { chunk, data }
                })
                .collect();

            // Apply under the write lock; readers see a consistent state.
            let mut guard = sh.state.write().unwrap();
            let st = &mut *guard;
            let old_bytes = st.bytes;
            st.entries.retain(|e| !to_evict.contains(&e.chunk));
            st.entries.extend(mats);
            st.slot_of_row.fill(NONE);
            let mut bytes = 0u64;
            for (i, e) in st.entries.iter().enumerate() {
                for s in &mut st.slot_of_row[e.chunk.start..e.chunk.end()] {
                    *s = i as u32;
                }
                bytes += e.chunk.len as u64 * sh.row_ram_bytes;
            }
            st.bytes = bytes;
            drop(guard);
            debug_assert!(bytes <= share, "shard over budget: {bytes} > {share}");
            self.evictions
                .fetch_add(to_evict.len() as u64, Ordering::Relaxed);
            self.admissions
                .fetch_add(to_admit.len() as u64, Ordering::Relaxed);
            if bytes >= old_bytes {
                self.resident_bytes
                    .fetch_add(bytes - old_bytes, Ordering::Relaxed);
            } else {
                self.resident_bytes
                    .fetch_sub(old_bytes - bytes, Ordering::Relaxed);
            }
        }
        let drift = if weight_sum > 0.0 {
            weighted / weight_sum
        } else {
            self.drift()
        };
        self.drift_bits.store(drift.to_bits(), Ordering::Relaxed);
        self.maintaining.store(false, Ordering::Release);
        drift
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::encode_row;

    /// Deterministic synthetic weights: value depends on every index so
    /// staging bit-identity is meaningful.
    fn fill(layer: usize, group: usize, m: usize, chunk: Chunk, dst: &mut [f32], w: usize) {
        for (i, v) in dst.iter_mut().enumerate() {
            let row = chunk.start + i / w;
            let col = i % w;
            *v = (layer * 1_000_000 + group * 10_000 + m * 1_000 + row * 10 + col) as f32;
        }
    }

    /// The same synthetic weights in their encoded (on-flash) form.
    fn fill_enc(
        dtype: DType,
        layer: usize,
        group: usize,
        m: usize,
        chunk: Chunk,
        dst: &mut [u8],
        w: usize,
    ) {
        let mut rows = vec![0f32; chunk.len * w];
        fill(layer, group, m, chunk, &mut rows, w);
        let enc = dtype.encoded_row_bytes(w);
        for (r, b) in rows.chunks_exact(w).zip(dst.chunks_exact_mut(enc)) {
            encode_row(dtype, r, b);
        }
    }

    fn cache(budget: u64, pricing: bool) -> ChunkCache {
        // 2 layers × 2 groups, 16 rows, two members of width 4 and 2.
        let spec = ShardSpec {
            rows: 16,
            row_f32s: [4, 2, 0],
            row_enc_bytes: [16, 8, 0],
            flash_row_bytes_sum: (4 + 2) * 4,
        };
        ChunkCache::new(budget, pricing, 2, vec![spec; 4], DType::F32)
    }

    fn maintain(c: &ChunkCache) -> f64 {
        let dtype = c.dtype;
        c.maintain(|l, g, m, ch, dst| {
            let w = if m == 0 { 4 } else { 2 };
            fill_enc(dtype, l, g, m, ch, dst, w)
        })
    }

    #[test]
    fn admits_hot_rows_and_serves_bit_identical() {
        let c = cache(1 << 20, false);
        // Rows 4..8 are hot in (layer 1, group 0).
        for _ in 0..10 {
            c.record_selection(1, 0, &[Chunk::new(4, 4)]);
        }
        maintain(&c);
        assert_eq!(c.resident_rows(1, 0), 4);
        assert!(c.resident_bytes() > 0);

        // Selected rows 2..10: residents 4..8 must be subtracted and
        // staged; the pool sees only the miss runs.
        let mut phys: Vec<usize> = (2..10).collect();
        let mut selset = vec![false; 16];
        for &r in &phys {
            selset[r] = true;
        }
        let mut flash = vec![Chunk::new(2, 8)];
        let mut tmp = Vec::new();
        let mut rows = Vec::new();
        let mut data: [Vec<f32>; MAX_MEMBERS] = Default::default();
        let saved = c.prepare(
            1,
            0,
            &mut phys,
            &mut selset,
            &mut flash,
            &mut tmp,
            &mut rows,
            &mut data,
        );
        assert_eq!(saved, 4 * 6 * 4);
        assert_eq!(flash, vec![Chunk::new(2, 2), Chunk::new(8, 2)]);
        assert_eq!(rows, vec![4, 5, 6, 7]);
        assert_eq!(phys, (2..10).collect::<Vec<_>>(), "default mode never touches the compute set");
        let mut want = vec![0.0f32; 4 * 4];
        fill(1, 0, 0, Chunk::new(4, 4), &mut want, 4);
        assert_eq!(data[0], want);
        let mut want1 = vec![0.0f32; 4 * 2];
        fill(1, 0, 1, Chunk::new(4, 4), &mut want1, 2);
        assert_eq!(data[1], want1);
    }

    #[test]
    fn budget_is_never_exceeded_and_evictions_are_whole_chunks() {
        // Budget for ~8 rows total across 4 identical shards → 2 rows per
        // shard share (16 rows × 24 B/row per shard).
        let c = cache(8 * 24, false);
        for _ in 0..5 {
            c.record_selection(0, 0, &[Chunk::new(0, 8)]);
            c.record_selection(1, 1, &[Chunk::new(8, 8)]);
        }
        maintain(&c);
        assert!(c.resident_bytes() <= 8 * 24);
        assert!(c.resident_rows(0, 0) <= 2);

        // Shift the hot set entirely; decayed old rows lose their slots.
        for _ in 0..64 {
            c.record_selection(0, 0, &[Chunk::new(12, 4)]);
        }
        let before = c.evictions();
        maintain(&c);
        assert!(c.resident_bytes() <= 8 * 24);
        assert!(c.evictions() > before);
        // The survivor must be a whole run out of the new hot set.
        let mut snap = Vec::new();
        c.frequency_snapshot(0, 0, &mut snap);
        assert_eq!(c.resident_rows(0, 0), 2);
    }

    #[test]
    fn pricing_mode_zeroes_importance_and_unions_compute() {
        let c = cache(1 << 20, true);
        for _ in 0..10 {
            c.record_selection(0, 1, &[Chunk::new(10, 2)]);
        }
        maintain(&c);
        let mut imp: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let freed = c.zero_resident(0, 1, &mut imp);
        assert_eq!(freed, 10.0 + 11.0);
        assert_eq!(imp[10], 0.0);
        assert_eq!(imp[11], 0.0);

        // Selection picked rows 0..2 only; residents join for free.
        let mut phys = vec![0usize, 1];
        let mut selset = vec![false; 16];
        selset[0] = true;
        selset[1] = true;
        let mut flash = vec![Chunk::new(0, 2)];
        let (mut tmp, mut rows) = (Vec::new(), Vec::new());
        let mut data: [Vec<f32>; MAX_MEMBERS] = Default::default();
        c.prepare(0, 1, &mut phys, &mut selset, &mut flash, &mut tmp, &mut rows, &mut data);
        assert_eq!(phys, vec![0, 1, 10, 11]);
        assert!(selset[10] && selset[11]);
        assert_eq!(flash, vec![Chunk::new(0, 2)], "misses untouched");
        assert_eq!(rows, vec![10, 11]);
    }

    #[test]
    fn seed_prior_admits_before_traffic_and_drift_detects_shift() {
        let c = cache(1 << 20, false);
        let mut prior = vec![0.0f64; 16];
        for r in 0..4 {
            prior[r] = 1.0;
        }
        c.seed_prior(0, 0, &prior);
        maintain(&c);
        assert_eq!(c.resident_rows(0, 0), 4, "prior-hot rows admitted cold");
        assert!(maintain(&c) < 0.2, "traffic matching the prior ≈ no drift");

        // Live traffic moves to a disjoint hot set → drift rises.
        for _ in 0..512 {
            c.record_selection(0, 0, &[Chunk::new(12, 4)]);
        }
        let d = maintain(&c);
        assert!(d > 0.5, "disjoint hot set must score high drift, got {d}");

        c.clear_all();
        assert_eq!(c.resident_bytes(), 0);
        assert_eq!(c.resident_rows(0, 0), 0);
    }

    #[test]
    fn concurrent_maintain_is_single_flight() {
        let c = cache(1 << 20, false);
        c.maintaining.store(true, Ordering::Relaxed);
        // A second maintainer must bail out without touching state.
        let d = maintain(&c);
        assert_eq!(d, 0.0);
        assert_eq!(c.admissions(), 0);
    }

    #[test]
    fn quantized_entries_stretch_budget_and_decode() {
        // Same group shape as `cache()` but int8-encoded: a resident row
        // costs (4+4) + (4+2) = 14 bytes instead of 24, so the same byte
        // budget holds more rows.
        let spec = ShardSpec {
            rows: 16,
            row_f32s: [4, 2, 0],
            row_enc_bytes: [8, 6, 0],
            flash_row_bytes_sum: (4 + 4 + 4 + 2) as u64,
        };
        let c = ChunkCache::new(8 * 24, false, 2, vec![spec; 4], DType::Int8);
        // The f32 cache() with this budget capped each shard at 2 rows;
        // int8 encoding fits 24*8/4 / 14 = 3 rows per shard share.
        assert!(c.max_resident_rows(0, 0) > 2);
        for _ in 0..10 {
            c.record_selection(0, 0, &[Chunk::new(4, 3)]);
        }
        c.maintain(|l, g, m, ch, dst| {
            let w = if m == 0 { 4 } else { 2 };
            fill_enc(DType::Int8, l, g, m, ch, dst, w)
        });
        assert_eq!(c.resident_rows(0, 0), 3);

        // Staged rows dequantize to the synthetic weights within the
        // per-row int8 bound (scale/2).
        let mut phys: Vec<usize> = (4..7).collect();
        let mut selset = vec![false; 16];
        for &r in &phys {
            selset[r] = true;
        }
        let mut flash = vec![Chunk::new(4, 3)];
        let (mut tmp, mut rows) = (Vec::new(), Vec::new());
        let mut data: [Vec<f32>; MAX_MEMBERS] = Default::default();
        c.prepare(0, 0, &mut phys, &mut selset, &mut flash, &mut tmp, &mut rows, &mut data);
        assert!(flash.is_empty(), "all rows resident");
        assert_eq!(rows, vec![4, 5, 6]);
        let mut want = vec![0f32; 3 * 4];
        fill(0, 0, 0, Chunk::new(4, 3), &mut want, 4);
        for (row, got) in data[0].chunks_exact(4).enumerate() {
            let src = &want[row * 4..(row + 1) * 4];
            let max = src.iter().fold(0f32, |m, &v| m.max(v.abs()));
            let bound = max / 127.0 * 0.5 + 1e-6;
            for (&a, &b) in src.iter().zip(got) {
                assert!((a - b).abs() <= bound, "{a} vs {b}");
            }
        }
    }
}
