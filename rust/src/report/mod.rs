//! Report emitters: aligned text tables to stdout + CSV files under
//! `reports/` (one per paper figure/table, consumed by EXPERIMENTS.md).

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// A simple column-aligned table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let line = |cells: &[String], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "{:<w$}", c, w = widths[i] + 2);
            }
            let _ = writeln!(out);
        };
        line(&self.header, &mut out);
        let total: usize = widths.iter().map(|w| w + 2).sum::<usize>();
        let _ = writeln!(out, "{}", "-".repeat(total.min(120)));
        for r in &self.rows {
            line(r, &mut out);
        }
        let _ = ncol;
        out
    }

    /// Write as CSV to `reports/<name>.csv` under `root`.
    pub fn write_csv(&self, root: &Path, name: &str) -> anyhow::Result<PathBuf> {
        let dir = root.join("reports");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{name}.csv"));
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "{}", csv_line(&self.header))?;
        for r in &self.rows {
            writeln!(f, "{}", csv_line(r))?;
        }
        Ok(path)
    }
}

fn csv_line(cells: &[String]) -> String {
    cells
        .iter()
        .map(|c| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        })
        .collect::<Vec<_>>()
        .join(",")
}

/// Format seconds in an adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Format bytes/s adaptively.
pub fn fmt_bw(bps: f64) -> String {
    if bps >= 1e9 {
        format!("{:.2} GB/s", bps / 1e9)
    } else if bps >= 1e6 {
        format!("{:.1} MB/s", bps / 1e6)
    } else {
        format!("{:.0} KB/s", bps / 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo", &["a", "column_b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["100000".into(), "x".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // header and rows start columns at the same offsets
        let col_b = lines[1].find("column_b").unwrap();
        assert_eq!(lines[3].find('2').unwrap(), col_b);
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_written_and_escaped() {
        let mut t = Table::new("x", &["k", "v"]);
        t.row(vec!["a,b".into(), "plain".into()]);
        let dir = std::env::temp_dir().join(format!("nc_report_{}", std::process::id()));
        let path = t.write_csv(&dir, "test_table").unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.contains("\"a,b\",plain"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_secs(2.5), "2.50 s");
        assert_eq!(fmt_secs(0.0025), "2.50 ms");
        assert_eq!(fmt_secs(2.5e-6), "2.50 us");
        assert!(fmt_bw(7.45e9).starts_with("7.45 GB/s"));
        assert!(fmt_bw(3.5e6).starts_with("3.5 MB/s"));
    }
}
