//! The I/O planning layer: the seam between *what to load* (selection
//! masks) and *how it is submitted to the device*.
//!
//! The paper's thesis is that sparsification decisions must be coupled to
//! storage access cost; this module is where the serving side honours the
//! same coupling. An [`IoPlanner`] consumes per-matrix chunked row demands
//! ([`PlanRequest`]s) plus the [`FlashLayout`] and emits a device-aware
//! [`ReadPlan`]:
//!
//! * **cross-matrix batching** — all member matrices of a selection group
//!   (and, with prefetch, a whole layer) land in one plan, so the device
//!   sees one deep command batch instead of several shallow ones;
//! * **adjacent-extent merging** — demands that touch contiguous flash
//!   ranges (e.g. dense reads of back-to-back matrix regions) coalesce
//!   into single large commands, which engage more internal parallelism;
//! * **page alignment** — optional rounding of commands to NAND-page /
//!   `O_DIRECT` boundaries (the payload offsets inside each command are
//!   tracked, so callers still address exact row bytes);
//! * **submission batches** — commands are grouped into queue-depth-sized
//!   batches for backends that bound in-flight commands;
//! * **estimated latency** — `Σ T[bytes(cmd)]` from the profiled
//!   [`LatencyTable`], so planned cost is directly comparable to
//!   [`crate::storage::SimulatedSsd`] service time.
//!
//! Devices consume plans through [`crate::storage::FlashDevice::submit`],
//! whose default implementation shims onto `read_batch`, returning a
//! [`PlanReceipt`]. A plan+receipt pair ([`PlannedRead`]) supports random
//! row access, which is what the engine's gather path and the prefetch
//! buffer are built on.

use std::time::Duration;

use crate::latency::{Chunk, LatencyTable};
use crate::model::{FlashLayout, MatrixId};
use crate::storage::{DeviceProfile, Extent, StripeLayout};

/// One matrix's chunked row demand (physical/reordered row space).
#[derive(Clone, Debug)]
pub struct PlanRequest {
    pub id: MatrixId,
    pub chunks: Vec<Chunk>,
}

impl PlanRequest {
    pub fn new(id: MatrixId, chunks: Vec<Chunk>) -> Self {
        Self { id, chunks }
    }
}

/// How raw per-chunk extents become device commands.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CoalescePolicy {
    /// Merge commands whose flash ranges touch or overlap.
    pub merge_adjacent: bool,
    /// Round commands to this page size (0 = no alignment). Required for
    /// `O_DIRECT` backends; payload offsets remain exact.
    pub page_bytes: usize,
    /// Commands per submission batch (0 = one batch with everything).
    pub max_batch: usize,
}

impl CoalescePolicy {
    /// Default serving policy: merge aggressively, no alignment padding,
    /// single deep submission (analytical simulators model queueing
    /// internally, so splitting only adds fixed costs).
    pub fn contiguous() -> Self {
        Self {
            merge_adjacent: true,
            page_bytes: 0,
            max_batch: 0,
        }
    }

    /// No transformation: one command per chunk, one batch. Reproduces the
    /// legacy per-matrix `read_batch` traffic exactly.
    pub fn passthrough() -> Self {
        Self {
            merge_adjacent: false,
            page_bytes: 0,
            max_batch: 0,
        }
    }

    /// Policy for a direct-I/O real device: page-aligned commands and
    /// queue-depth-sized submission batches.
    ///
    /// Requires a page-aligned [`FlashLayout`] (`align_rows = true`): on
    /// an unaligned layout the planner clamps the last command to the
    /// layout end, which can leave it a non-page-multiple length — an
    /// `O_DIRECT` backend would reject it (as it would every unaligned
    /// row offset such a layout produces).
    pub fn direct_io(profile: &DeviceProfile) -> Self {
        Self {
            merge_adjacent: true,
            page_bytes: profile.page_bytes,
            max_batch: profile.queue_depth.max(1) * 8,
        }
    }
}

impl Default for CoalescePolicy {
    fn default() -> Self {
        Self::contiguous()
    }
}

/// One payload segment of a plan: where a matrix chunk's bytes live inside
/// a command's data.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanSegment {
    pub id: MatrixId,
    pub chunk: Chunk,
    /// Bytes per row of this matrix (from the layout).
    pub row_bytes: usize,
    /// Index into [`ReadPlan::cmds`].
    pub cmd: usize,
    /// Byte offset of the chunk's first row inside the command's data.
    pub offset_in_cmd: usize,
}

impl PlanSegment {
    /// Payload bytes of this segment.
    pub fn len(&self) -> usize {
        self.chunk.len * self.row_bytes
    }

    pub fn is_empty(&self) -> bool {
        self.chunk.len == 0
    }
}

/// A device-aware read plan: sorted, disjoint commands plus the payload
/// segments that map matrix rows into command data.
#[derive(Clone, Debug, Default)]
pub struct ReadPlan {
    cmds: Vec<Extent>,
    segments: Vec<PlanSegment>,
    /// `[start, end)` ranges into `cmds`, one per submission batch.
    batches: Vec<(usize, usize)>,
    /// `Σ T[bytes(cmd)]` under the planning-time latency table (0 when no
    /// table was supplied).
    pub estimated_seconds: f64,
}

impl ReadPlan {
    pub fn cmds(&self) -> &[Extent] {
        &self.cmds
    }

    pub fn segments(&self) -> &[PlanSegment] {
        &self.segments
    }

    pub fn batches(&self) -> &[(usize, usize)] {
        &self.batches
    }

    pub fn num_cmds(&self) -> usize {
        self.cmds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cmds.is_empty()
    }

    /// Bytes the device will actually transfer (includes alignment
    /// padding and any inter-segment gap swallowed by merging).
    pub fn cmd_bytes(&self) -> u64 {
        self.cmds.iter().map(|e| e.len as u64).sum()
    }

    /// Bytes of requested payload (selected rows only).
    pub fn payload_bytes(&self) -> u64 {
        self.segments.iter().map(|s| s.len() as u64).sum()
    }

    /// Reset in place, reusing all buffer capacity (the serving hot path
    /// replans into the same [`ReadPlan`] every token).
    pub fn clear(&mut self) {
        self.cmds.clear();
        self.segments.clear();
        self.batches.clear();
        self.estimated_seconds = 0.0;
    }

    /// Pre-reserve capacity for worst-case command/segment counts so the
    /// hot path never grows these vectors mid-serve.
    pub fn reserve(&mut self, cmds: usize, segments: usize) {
        self.cmds.reserve(cmds);
        self.segments.reserve(segments);
        self.batches.reserve(1);
    }

    /// Structural invariants: commands sorted and disjoint, batches
    /// partition the command list, every segment inside its command.
    pub fn validate(&self) -> anyhow::Result<()> {
        for w in self.cmds.windows(2) {
            anyhow::ensure!(
                w[0].end() <= w[1].offset,
                "commands overlap or unsorted: {:?} then {:?}",
                w[0],
                w[1]
            );
        }
        let mut at = 0usize;
        for &(s, e) in &self.batches {
            anyhow::ensure!(s == at && e >= s, "batches must partition cmds");
            at = e;
        }
        anyhow::ensure!(
            at == self.cmds.len(),
            "batches cover {at} of {} cmds",
            self.cmds.len()
        );
        for seg in &self.segments {
            anyhow::ensure!(seg.cmd < self.cmds.len(), "segment cmd out of range");
            let cmd = &self.cmds[seg.cmd];
            anyhow::ensure!(
                seg.offset_in_cmd + seg.len() <= cmd.len,
                "segment {:?} exceeds command {:?}",
                seg,
                cmd
            );
        }
        Ok(())
    }
}

/// Receipt of a submitted plan: the raw command data plus the device's
/// (virtual or wall-clock) service time.
#[derive(Clone, Debug, Default)]
pub struct PlanReceipt {
    /// Concatenated command data, in command order.
    pub bytes: Vec<u8>,
    /// Total device service time across all submission batches.
    pub service: Duration,
    /// Byte offset of each command's data inside `bytes`.
    pub cmd_offsets: Vec<usize>,
}

impl PlanReceipt {
    /// Reset in place, reusing buffer capacity.
    pub fn clear(&mut self) {
        self.bytes.clear();
        self.cmd_offsets.clear();
        self.service = Duration::ZERO;
    }

    /// Pre-reserve worst-case payload/command capacity.
    pub fn reserve(&mut self, bytes: usize, cmds: usize) {
        self.bytes.reserve(bytes);
        self.cmd_offsets.reserve(cmds);
    }

    /// Clear and pre-size for a command list: `bytes` zeroed to the
    /// summed command length, `cmd_offsets` rebuilt in order. Returns
    /// the total byte count. Shared by every submission path that fills
    /// the data out of band (device shims, pool fan-out, async I/O
    /// tickets); reuses capacity, so it is allocation-free once warm.
    pub fn presize_for(&mut self, cmds: &[Extent]) -> usize {
        self.clear();
        let total: usize = cmds.iter().map(|e| e.len).sum();
        self.bytes.resize(total, 0);
        let mut at = 0usize;
        for e in cmds {
            self.cmd_offsets.push(at);
            at += e.len;
        }
        total
    }
}

/// A plan together with its receipt: supports exact row addressing, which
/// the engine's gather path and prefetch buffer build on. A
/// default-constructed (or [`PlannedRead::clear`]ed) value is "empty" —
/// it covers no rows and the engine's pooled prefetch slots use that
/// state to mean "nothing prefetched".
#[derive(Clone, Debug, Default)]
pub struct PlannedRead {
    pub plan: ReadPlan,
    pub receipt: PlanReceipt,
}

impl PlannedRead {
    pub fn service(&self) -> Duration {
        self.receipt.service
    }

    pub fn is_empty(&self) -> bool {
        self.plan.is_empty()
    }

    /// Reset in place, reusing all buffer capacity (pooled prefetch slots
    /// and the per-stage fresh-read slot cycle through this).
    pub fn clear(&mut self) {
        self.plan.clear();
        self.receipt.clear();
    }

    /// Pre-reserve worst-case capacity (bytes of payload, command and
    /// segment counts) so pooled reads never grow mid-serve.
    pub fn reserve(&mut self, bytes: usize, cmds: usize, segments: usize) {
        self.plan.reserve(cmds, segments);
        self.receipt.reserve(bytes, cmds);
    }

    /// Raw bytes of one payload segment.
    pub fn segment_bytes(&self, i: usize) -> &[u8] {
        let seg = &self.plan.segments[i];
        let base = self.receipt.cmd_offsets[seg.cmd] + seg.offset_in_cmd;
        &self.receipt.bytes[base..base + seg.len()]
    }

    /// Raw bytes of one matrix row, if the plan covered it.
    pub fn row_data(&self, id: MatrixId, row: usize) -> Option<&[u8]> {
        for (i, seg) in self.plan.segments.iter().enumerate() {
            if seg.id == id && seg.chunk.start <= row && row < seg.chunk.end() {
                let bytes = self.segment_bytes(i);
                let off = (row - seg.chunk.start) * seg.row_bytes;
                return Some(&bytes[off..off + seg.row_bytes]);
            }
        }
        None
    }

    /// Whether the plan covered this row.
    pub fn covers(&self, id: MatrixId, row: usize) -> bool {
        self.plan
            .segments
            .iter()
            .any(|s| s.id == id && s.chunk.start <= row && row < s.chunk.end())
    }
}

/// Monotone row-wise cursor over one matrix's segments of a
/// [`PlannedRead`] — the merge-scan partner of an ascending row walk
/// (rows must be queried in non-decreasing order).
///
/// Allocation-free: the planner emits segments sorted by flash offset,
/// and within one matrix flash offset is monotone in row index, so this
/// matrix's segments appear in ascending `chunk.start` order inside the
/// plan's segment list. The cursor simply scans that list, skipping other
/// matrices' segments.
pub struct RowCursor<'a> {
    read: &'a PlannedRead,
    id: MatrixId,
    pos: usize,
    last_row: usize,
}

impl<'a> RowCursor<'a> {
    pub fn new(read: &'a PlannedRead, id: MatrixId) -> Self {
        Self {
            read,
            id,
            pos: 0,
            last_row: 0,
        }
    }

    /// Bytes of `row` if covered. Ascending queries are O(1) amortized; a
    /// backward query rewinds the cursor (correct, just slower).
    pub fn advance_to(&mut self, row: usize) -> Option<&'a [u8]> {
        if row < self.last_row {
            self.pos = 0;
        }
        self.last_row = row;
        let segs = &self.read.plan.segments;
        while self.pos < segs.len() {
            let seg = &segs[self.pos];
            if seg.id != self.id || seg.chunk.end() <= row {
                self.pos += 1;
                continue;
            }
            if seg.chunk.start <= row {
                let bytes = self.read.segment_bytes(self.pos);
                let off = (row - seg.chunk.start) * seg.row_bytes;
                return Some(&bytes[off..off + seg.row_bytes]);
            }
            return None;
        }
        None
    }
}

/// One pool member's slice of a sharded plan: device-local commands plus
/// each command's destination byte offset inside the *logical* receipt.
/// Commands appear in logical (flat-address) order; locally-contiguous
/// pieces with contiguous destinations are merged on insert, so a
/// one-member pool reproduces the logical command list exactly.
#[derive(Clone, Debug, Default)]
pub struct DeviceSubPlan {
    /// Device-local extents, in logical order.
    pub cmds: Vec<Extent>,
    /// Destination offset in the logical receipt's `bytes` per command.
    pub dsts: Vec<usize>,
    /// Flat (pool-address-space) offset per command. Filled only by the
    /// *routed* shard step ([`ShardedPlan::route_from`]); empty for
    /// plans built with [`IoPlanner::shard_into`]. Hedged re-issue needs
    /// the flat address to re-map a straggler's commands onto the other
    /// replicas, so routed plans carry it (`flats.len() == cmds.len()`).
    pub flats: Vec<u64>,
}

impl DeviceSubPlan {
    pub fn is_empty(&self) -> bool {
        self.cmds.is_empty()
    }

    /// Bytes this member will read.
    pub fn bytes(&self) -> usize {
        self.cmds.iter().map(|e| e.len).sum()
    }

    pub fn clear(&mut self) {
        self.cmds.clear();
        self.dsts.clear();
        self.flats.clear();
    }

    pub fn reserve(&mut self, cmds: usize) {
        self.cmds.reserve(cmds);
        self.dsts.reserve(cmds);
    }

    /// Append a piece, merging with the previous one when both the
    /// device-local range and the destination range are contiguous.
    pub fn push_piece(&mut self, local: Extent, dst: usize) {
        if let Some(last) = self.cmds.last_mut() {
            let last_dst = *self.dsts.last().unwrap();
            if last.end() == local.offset && last_dst + last.len == dst {
                last.len += local.len;
                return;
            }
        }
        self.cmds.push(local);
        self.dsts.push(dst);
    }

    /// [`DeviceSubPlan::push_piece`] for the routed shard step: also
    /// records the piece's flat offset, and merges only when the local
    /// range, the destination range **and** the flat range are all
    /// contiguous (replica copies of adjacent blocks need not be
    /// device-locally adjacent).
    pub fn push_piece_routed(&mut self, local: Extent, dst: usize, flat: u64) {
        if let Some(last) = self.cmds.last_mut() {
            let last_dst = *self.dsts.last().unwrap();
            let last_flat = *self.flats.last().unwrap();
            if last.end() == local.offset
                && last_dst + last.len == dst
                && last_flat + last.len as u64 == flat
            {
                last.len += local.len;
                return;
            }
        }
        self.cmds.push(local);
        self.dsts.push(dst);
        self.flats.push(flat);
    }
}

/// A logical [`ReadPlan`] split across the members of a storage pool:
/// one [`DeviceSubPlan`] per member (possibly empty). Built by
/// [`IoPlanner::shard_into`], consumed by
/// [`crate::storage::DevicePool::submit_sharded_into`], which reassembles
/// the logical receipt bit-identically to a single-device submission.
#[derive(Clone, Debug, Default)]
pub struct ShardedPlan {
    pub shards: Vec<DeviceSubPlan>,
    /// Logical bytes covered (== the source plan's `cmd_bytes`).
    total: usize,
}

impl ShardedPlan {
    pub fn total_bytes(&self) -> usize {
        self.total
    }

    /// Reset in place for a pool of `devices` members, reusing all
    /// buffer capacity.
    pub fn clear_for(&mut self, devices: usize) {
        if self.shards.len() != devices {
            self.shards.resize_with(devices, Default::default);
        }
        for s in &mut self.shards {
            s.clear();
        }
        self.total = 0;
    }

    /// Pre-reserve worst-case per-member command capacity.
    pub fn reserve(&mut self, devices: usize, cmds: usize) {
        if self.shards.len() < devices {
            self.shards.resize_with(devices, Default::default);
        }
        for s in &mut self.shards {
            s.reserve(cmds);
        }
    }

    /// Replica-routed shard step: like [`IoPlanner::shard_into`] but
    /// every piece is offered to a chooser together with *all* replicas
    /// that hold it (`(member, device-local extent)` pairs, primary
    /// first), and lands on the member the chooser picks. Sub-plans
    /// carry flat offsets ([`DeviceSubPlan::flats`]) so a straggling
    /// member's commands can later be re-mapped onto the surviving
    /// replicas (hedged reads, failover). With replication 1 the chooser
    /// always sees one option and the result is bit-identical to
    /// `shard_into` apart from the recorded flats.
    pub fn route_from(
        &mut self,
        cmds: &[Extent],
        stripe: &StripeLayout,
        mut choose: impl FnMut(&[(usize, Extent)]) -> usize,
    ) {
        self.clear_for(stripe.devices());
        let mut at = 0usize;
        for cmd in cmds {
            stripe.for_pieces_all(*cmd, |flat, options| {
                let pick = choose(options).min(options.len() - 1);
                let (dev, local) = options[pick];
                self.shards[dev].push_piece_routed(
                    local,
                    at + (flat - cmd.offset) as usize,
                    flat,
                );
            });
            at += cmd.len;
        }
        self.total = at;
    }
}

/// One stream's command span inside a fused plan (fusion working memory).
#[derive(Clone, Copy, Debug)]
struct FuseSpan {
    offset: u64,
    len: usize,
    stream: usize,
    /// Destination byte offset inside the stream's own receipt.
    dst: usize,
}

/// Reusable working memory for the allocation-free
/// [`IoPlanner::fuse_into`] entry point. Lives in the batch driver's
/// arena so cross-stream fusion allocates nothing at steady state.
#[derive(Clone, Debug, Default)]
pub struct FuseScratch {
    spans: Vec<FuseSpan>,
}

impl FuseScratch {
    /// Pre-reserve worst-case span capacity (Σ streams' command counts).
    pub fn reserve(&mut self, spans: usize) {
        self.spans.reserve(spans);
    }
}

/// One subscriber copy of a fused read: `len` bytes at `src` inside the
/// fused receipt land at `dst` inside stream `stream`'s own receipt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FusedCopy {
    pub stream: usize,
    pub src: usize,
    pub dst: usize,
    pub len: usize,
}

/// Several streams' [`ReadPlan`]s fused into one deduplicated device
/// submission: the union command list is read **once** and the per-stream
/// `copies` scatter each subscriber's bytes back into its own receipt,
/// bit-identically to what a solo submission of its plan would have
/// produced. Built by [`IoPlanner::fuse_into`]; consumed by the batch
/// decode driver (sync scatter) and by
/// [`crate::storage::IoTicket::wait_scatter_fused`] (async workers).
#[derive(Clone, Debug, Default)]
pub struct FusedPlan {
    /// Union command list (sorted, disjoint, one submission batch).
    pub plan: ReadPlan,
    /// Subscriber scatter map, in flash-offset order.
    pub copies: Vec<FusedCopy>,
    /// Number of source streams (including ones with empty plans).
    pub streams: usize,
    /// Σ per-stream command bytes — what `streams` solo submissions
    /// would have transferred.
    pub solo_bytes: u64,
}

impl FusedPlan {
    pub fn is_empty(&self) -> bool {
        self.plan.is_empty()
    }

    /// Bytes the fused submission reads once.
    pub fn fused_bytes(&self) -> u64 {
        self.plan.cmd_bytes()
    }

    /// Bytes saved by deduplication: ranges demanded by more than one
    /// stream are read once instead of once per subscriber. (Fusion
    /// merges only touching/overlapping extents and never pads, so the
    /// union is always ≤ the solo total.)
    pub fn shared_bytes(&self) -> u64 {
        self.solo_bytes.saturating_sub(self.plan.cmd_bytes())
    }

    /// Reset in place, reusing all buffer capacity.
    pub fn clear(&mut self) {
        self.plan.clear();
        self.copies.clear();
        self.streams = 0;
        self.solo_bytes = 0;
    }

    /// Pre-reserve worst-case command/copy capacity.
    pub fn reserve(&mut self, cmds: usize) {
        self.plan.reserve(cmds, 0);
        self.copies.reserve(cmds);
    }
}

/// Raw per-chunk span prior to coalescing (planner working memory).
#[derive(Clone, Copy, Debug)]
struct RawSpan {
    offset: u64,
    len: usize,
    id: MatrixId,
    chunk: Chunk,
    row_bytes: usize,
}

/// Reusable planner working memory for the allocation-free
/// [`IoPlanner::plan_refs_into`] entry point.
#[derive(Clone, Debug, Default)]
pub struct PlanScratch {
    raw: Vec<RawSpan>,
}

impl PlanScratch {
    /// Pre-reserve worst-case span capacity.
    pub fn reserve(&mut self, spans: usize) {
        self.raw.reserve(spans);
    }
}

/// Builds [`ReadPlan`]s from per-matrix chunk demands.
#[derive(Clone, Debug, Default)]
pub struct IoPlanner {
    pub policy: CoalescePolicy,
}

impl IoPlanner {
    pub fn new(policy: CoalescePolicy) -> Self {
        Self { policy }
    }

    /// Plan a batch of per-matrix demands against a layout. `table` keys
    /// the latency estimate; pass `None` to skip estimation.
    pub fn plan(
        &self,
        layout: &FlashLayout,
        requests: &[PlanRequest],
        table: Option<&LatencyTable>,
    ) -> ReadPlan {
        let refs: Vec<(MatrixId, &[Chunk])> = requests
            .iter()
            .map(|r| (r.id, r.chunks.as_slice()))
            .collect();
        let mut scratch = PlanScratch::default();
        let mut out = ReadPlan::default();
        self.plan_refs_into(layout, &refs, table, &mut scratch, &mut out);
        out
    }

    /// Allocation-free planning over borrowed chunk demands: clears `out`
    /// and rebuilds it in place, drawing working memory from `scratch`.
    /// Several requests may borrow the same chunk list (the engine's
    /// selection groups do — every member matrix shares the group's
    /// residual demand).
    ///
    /// Demands arrive **miss-only**: RAM-cache subtraction (both the
    /// legacy [`crate::coordinator::HotNeuronCache`] and the shared
    /// [`crate::cache::ChunkCache`]) happens upstream, on the chunk lists
    /// themselves, before planning — so the plan, its sharded sub-plans,
    /// and everything the storage pool sees contain only rows that must
    /// actually come off flash.
    pub fn plan_refs_into(
        &self,
        layout: &FlashLayout,
        requests: &[(MatrixId, &[Chunk])],
        table: Option<&LatencyTable>,
        scratch: &mut PlanScratch,
        out: &mut ReadPlan,
    ) {
        out.clear();
        let raw = &mut scratch.raw;
        raw.clear();
        for &(id, chunks) in requests {
            let row_bytes = layout.row_bytes(id);
            for &chunk in chunks {
                if chunk.len == 0 {
                    continue;
                }
                raw.push(RawSpan {
                    offset: layout.row_offset(id, chunk.start),
                    len: chunk.len * row_bytes,
                    id,
                    chunk,
                    row_bytes,
                });
            }
        }
        // Unstable sort: span offsets are unique (regions are disjoint and
        // chunks within a request don't overlap), and it avoids the stable
        // sort's temporary allocation.
        raw.sort_unstable_by_key(|r| r.offset);

        let page = self.policy.page_bytes as u64;
        let total = layout.total_bytes();
        let align_lo = |o: u64| if page > 0 { o - o % page } else { o };
        let align_hi = |o: u64| {
            if page > 0 {
                (o.div_ceil(page) * page).min(total)
            } else {
                o
            }
        };

        let cmds = &mut out.cmds;
        let segments = &mut out.segments;
        for r in raw.iter() {
            let lo = align_lo(r.offset);
            let hi = align_hi(r.offset + r.len as u64);
            let extend = self.policy.merge_adjacent
                && cmds
                    .last()
                    .map(|c| lo <= c.end())
                    .unwrap_or(false);
            if extend {
                let last = cmds.last_mut().unwrap();
                let new_end = last.end().max(hi);
                last.len = (new_end - last.offset) as usize;
            } else {
                cmds.push(Extent::new(lo, (hi - lo) as usize));
            }
            let cmd = cmds.len() - 1;
            segments.push(PlanSegment {
                id: r.id,
                chunk: r.chunk,
                row_bytes: r.row_bytes,
                cmd,
                offset_in_cmd: (r.offset - cmds[cmd].offset) as usize,
            });
        }

        if !cmds.is_empty() {
            if self.policy.max_batch == 0 {
                out.batches.push((0, cmds.len()));
            } else {
                let mut at = 0;
                while at < cmds.len() {
                    let end = (at + self.policy.max_batch).min(cmds.len());
                    out.batches.push((at, end));
                    at = end;
                }
            }
        }

        out.estimated_seconds = table
            .map(|t| out.cmds.iter().map(|c| t.latency_bytes(c.len)).sum())
            .unwrap_or(0.0);
    }

    /// Convenience: plan one matrix's chunks.
    pub fn plan_chunks(
        &self,
        layout: &FlashLayout,
        id: MatrixId,
        chunks: &[Chunk],
        table: Option<&LatencyTable>,
    ) -> ReadPlan {
        self.plan(layout, &[PlanRequest::new(id, chunks.to_vec())], table)
    }

    /// The fusion step: union/dedup several streams' plans into one
    /// [`FusedPlan`]. Commands that touch or overlap collapse into one
    /// union command, so a flash range demanded by N subscriber streams
    /// is read once; `copies` records, for every original command, where
    /// its bytes sit inside the fused receipt (`src`) and inside the
    /// owning stream's receipt (`dst`). Scattering the fused receipt
    /// through `copies` reproduces each subscriber's solo receipt bytes
    /// bit for bit (same flash ranges, same layout — only the service
    /// time differs, because the device saw one deep batch).
    ///
    /// Allocation-free at steady state: working memory comes from
    /// `scratch`, `out` reuses its capacity. Stream index = position in
    /// `plans`; empty plans contribute nothing but keep their index.
    pub fn fuse_into(
        &self,
        plans: &[&ReadPlan],
        table: Option<&LatencyTable>,
        scratch: &mut FuseScratch,
        out: &mut FusedPlan,
    ) {
        out.clear();
        out.streams = plans.len();
        let spans = &mut scratch.spans;
        spans.clear();
        for (stream, plan) in plans.iter().enumerate() {
            let mut dst = 0usize;
            for c in plan.cmds() {
                if c.len > 0 {
                    spans.push(FuseSpan {
                        offset: c.offset,
                        len: c.len,
                        stream,
                        dst,
                    });
                }
                dst += c.len;
            }
            out.solo_bytes += plan.cmd_bytes();
        }
        spans.sort_unstable_by_key(|s| (s.offset, s.stream));

        // Pass 1: union command list (merge touching/overlapping spans;
        // no padding, so union bytes never exceed the solo total).
        for s in spans.iter() {
            let hi = s.offset + s.len as u64;
            match out.plan.cmds.last_mut() {
                Some(last) if s.offset <= last.end() => {
                    let end = last.end().max(hi);
                    last.len = (end - last.offset) as usize;
                }
                _ => out.plan.cmds.push(Extent::new(s.offset, s.len)),
            }
        }
        if !out.plan.cmds.is_empty() {
            out.plan.batches.push((0, out.plan.cmds.len()));
        }
        out.plan.estimated_seconds = table
            .map(|t| out.plan.cmds.iter().map(|c| t.latency_bytes(c.len)).sum())
            .unwrap_or(0.0);

        // Pass 2: subscriber copies. Spans and union commands are both in
        // flash-offset order and no span straddles a union boundary
        // (merging only ever grows the command a span landed in), so one
        // forward cursor suffices; a command's receipt offset is the
        // prefix sum of the final command lengths before it.
        let mut cmd = 0usize;
        let mut cmd_off = 0usize;
        for s in spans.iter() {
            while out.plan.cmds[cmd].end() < s.offset + s.len as u64 {
                cmd_off += out.plan.cmds[cmd].len;
                cmd += 1;
            }
            let c = &out.plan.cmds[cmd];
            debug_assert!(c.offset <= s.offset && s.offset + s.len as u64 <= c.end());
            out.copies.push(FusedCopy {
                stream: s.stream,
                src: cmd_off + (s.offset - c.offset) as usize,
                dst: s.dst,
                len: s.len,
            });
        }
    }

    /// The shard step: split one logical [`ReadPlan`] into per-member
    /// sub-plans under a pool's [`StripeLayout`]. Every logical command
    /// is cut at stripe boundaries; each piece becomes a device-local
    /// command carrying its destination offset in the logical receipt,
    /// so the pool can reassemble submission results bit-identically to
    /// a single-device submit. Allocation-free at steady state (`out`
    /// reuses its capacity); with a one-member pool the single shard
    /// reproduces the logical command list exactly.
    pub fn shard_into(&self, plan: &ReadPlan, stripe: &StripeLayout, out: &mut ShardedPlan) {
        out.clear_for(stripe.devices());
        let mut at = 0usize;
        for cmd in plan.cmds() {
            stripe.for_pieces(*cmd, |dev, local, flat| {
                out.shards[dev].push_piece(local, at + (flat - cmd.offset) as usize);
            });
            at += cmd.len;
        }
        out.total = at;
    }

    /// Replica-routed [`IoPlanner::shard_into`]: each stripe piece goes
    /// to whichever holding replica `choose` picks (see
    /// [`ShardedPlan::route_from`]). Used by replicated pools to skip
    /// dead members and to spread hot-stripe traffic by load.
    pub fn shard_routed_into(
        &self,
        plan: &ReadPlan,
        stripe: &StripeLayout,
        choose: impl FnMut(&[(usize, Extent)]) -> usize,
        out: &mut ShardedPlan,
    ) {
        out.route_from(plan.cmds(), stripe, choose);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{MatrixKind, ModelSpec};

    fn layout(aligned: bool) -> FlashLayout {
        FlashLayout::build(&ModelSpec::tiny(), aligned)
    }

    fn full_requests(spec: &ModelSpec, layer: usize) -> Vec<PlanRequest> {
        spec.matrices()
            .iter()
            .map(|m| {
                PlanRequest::new(
                    MatrixId::new(layer, m.kind),
                    vec![Chunk::new(0, m.rows)],
                )
            })
            .collect()
    }

    #[test]
    fn dense_layer_merges_into_one_command() {
        let spec = ModelSpec::tiny();
        let l = layout(false);
        let plan = IoPlanner::new(CoalescePolicy::contiguous()).plan(
            &l,
            &full_requests(&spec, 0),
            None,
        );
        plan.validate().unwrap();
        // All seven matrix regions of a layer are packed back-to-back, so
        // they coalesce into a single large command.
        assert_eq!(plan.num_cmds(), 1);
        assert_eq!(plan.segments().len(), 7);
        assert_eq!(plan.cmd_bytes(), plan.payload_bytes());
    }

    #[test]
    fn passthrough_keeps_one_command_per_chunk() {
        let spec = ModelSpec::tiny();
        let l = layout(false);
        let plan = IoPlanner::new(CoalescePolicy::passthrough()).plan(
            &l,
            &full_requests(&spec, 1),
            None,
        );
        plan.validate().unwrap();
        assert_eq!(plan.num_cmds(), 7);
    }

    #[test]
    fn sparse_chunks_stay_disjoint_and_sorted() {
        let l = layout(false);
        let id = MatrixId::new(0, MatrixKind::Gate);
        let chunks = vec![Chunk::new(2, 3), Chunk::new(10, 1), Chunk::new(40, 8)];
        let plan =
            IoPlanner::new(CoalescePolicy::contiguous()).plan_chunks(&l, id, &chunks, None);
        plan.validate().unwrap();
        assert_eq!(plan.num_cmds(), 3);
        let rb = l.row_bytes(id);
        assert_eq!(plan.payload_bytes(), (12 * rb) as u64);
        assert_eq!(plan.cmd_bytes(), (12 * rb) as u64);
    }

    #[test]
    fn page_alignment_pads_commands_not_payload() {
        let l = layout(true); // 4 KiB-aligned rows
        let id = MatrixId::new(0, MatrixKind::Q);
        let plan = IoPlanner::new(CoalescePolicy {
            merge_adjacent: true,
            page_bytes: 4096,
            max_batch: 0,
        })
        .plan_chunks(&l, id, &[Chunk::new(1, 2)], None);
        plan.validate().unwrap();
        for c in plan.cmds() {
            assert_eq!(c.offset % 4096, 0);
            assert_eq!(c.len % 4096, 0);
        }
        assert_eq!(plan.payload_bytes(), (2 * l.row_bytes(id)) as u64);
    }

    #[test]
    fn batches_respect_max_batch() {
        let l = layout(false);
        let id = MatrixId::new(0, MatrixKind::Down);
        let chunks: Vec<Chunk> = (0..10).map(|i| Chunk::new(i * 3, 1)).collect();
        let plan = IoPlanner::new(CoalescePolicy {
            merge_adjacent: false,
            page_bytes: 0,
            max_batch: 4,
        })
        .plan_chunks(&l, id, &chunks, None);
        plan.validate().unwrap();
        assert_eq!(plan.batches(), &[(0, 4), (4, 8), (8, 10)]);
    }

    #[test]
    fn estimate_sums_table_entries() {
        let l = layout(false);
        let id = MatrixId::new(0, MatrixKind::Gate);
        let entries: Vec<f64> = (1..=64).map(|i| 40e-6 + i as f64 * 1e-6).collect();
        let table = LatencyTable::new(1024, entries, l.row_bytes(id));
        let chunks = vec![Chunk::new(0, 2), Chunk::new(8, 4)];
        let plan = IoPlanner::new(CoalescePolicy::contiguous())
            .plan_chunks(&l, id, &chunks, Some(&table));
        let want: f64 = plan
            .cmds()
            .iter()
            .map(|c| table.latency_bytes(c.len))
            .sum();
        assert!((plan.estimated_seconds - want).abs() < 1e-15);
        assert!(plan.estimated_seconds > 0.0);
    }

    #[test]
    fn empty_plan_is_empty() {
        let l = layout(false);
        let plan = IoPlanner::new(CoalescePolicy::contiguous()).plan(&l, &[], None);
        plan.validate().unwrap();
        assert!(plan.is_empty());
        assert_eq!(plan.cmd_bytes(), 0);
    }

    fn fuse(plans: &[&ReadPlan]) -> FusedPlan {
        let planner = IoPlanner::new(CoalescePolicy::contiguous());
        let mut scratch = FuseScratch::default();
        let mut out = FusedPlan::default();
        planner.fuse_into(plans, None, &mut scratch, &mut out);
        out
    }

    #[test]
    fn fuse_single_stream_is_identity() {
        let l = layout(false);
        let id = MatrixId::new(0, MatrixKind::Gate);
        let chunks = vec![Chunk::new(2, 3), Chunk::new(10, 4)];
        let plan =
            IoPlanner::new(CoalescePolicy::contiguous()).plan_chunks(&l, id, &chunks, None);
        let fused = fuse(&[&plan]);
        assert_eq!(fused.streams, 1);
        assert_eq!(fused.plan.cmds(), plan.cmds());
        assert_eq!(fused.fused_bytes(), plan.cmd_bytes());
        assert_eq!(fused.shared_bytes(), 0);
        // The copies tile the stream receipt exactly, in order.
        let mut at = 0usize;
        for c in &fused.copies {
            assert_eq!(c.stream, 0);
            assert_eq!(c.dst, at);
            assert_eq!(c.src, at);
            at += c.len;
        }
        assert_eq!(at as u64, plan.cmd_bytes());
    }

    #[test]
    fn fuse_dedups_overlapping_streams() {
        let l = layout(false);
        let id = MatrixId::new(0, MatrixKind::Q);
        let planner = IoPlanner::new(CoalescePolicy::contiguous());
        // Stream 0 wants rows [0, 8); stream 1 wants rows [4, 12): the
        // union is [0, 12) and rows [4, 8) are shared.
        let a = planner.plan_chunks(&l, id, &[Chunk::new(0, 8)], None);
        let b = planner.plan_chunks(&l, id, &[Chunk::new(4, 8)], None);
        let fused = fuse(&[&a, &b]);
        let rb = l.row_bytes(id) as u64;
        assert_eq!(fused.streams, 2);
        assert_eq!(fused.plan.num_cmds(), 1);
        assert_eq!(fused.fused_bytes(), 12 * rb);
        assert_eq!(fused.solo_bytes, 16 * rb);
        assert_eq!(fused.shared_bytes(), 4 * rb);
        // Subscriber copies cover each stream's whole receipt.
        for (stream, plan) in [(0usize, &a), (1, &b)] {
            let covered: usize = fused
                .copies
                .iter()
                .filter(|c| c.stream == stream)
                .map(|c| c.len)
                .sum();
            assert_eq!(covered as u64, plan.cmd_bytes());
        }
        // Stream 1's copy starts 4 rows into the fused receipt.
        let c1 = fused.copies.iter().find(|c| c.stream == 1).unwrap();
        assert_eq!(c1.src as u64, 4 * rb);
        assert_eq!(c1.dst, 0);
    }

    #[test]
    fn fuse_keeps_disjoint_streams_apart() {
        let l = layout(false);
        let planner = IoPlanner::new(CoalescePolicy::contiguous());
        let a = planner.plan_chunks(
            &l,
            MatrixId::new(0, MatrixKind::Q),
            &[Chunk::new(0, 2)],
            None,
        );
        let b = planner.plan_chunks(
            &l,
            MatrixId::new(1, MatrixKind::Down),
            &[Chunk::new(3, 2)],
            None,
        );
        let fused = fuse(&[&a, &b]);
        assert_eq!(fused.plan.num_cmds(), 2);
        assert_eq!(fused.shared_bytes(), 0);
        assert_eq!(fused.fused_bytes(), a.cmd_bytes() + b.cmd_bytes());
        fused.plan.validate().unwrap();
    }

    #[test]
    fn fuse_handles_empty_members() {
        let l = layout(false);
        let planner = IoPlanner::new(CoalescePolicy::contiguous());
        let a = planner.plan_chunks(
            &l,
            MatrixId::new(0, MatrixKind::Q),
            &[Chunk::new(0, 2)],
            None,
        );
        let empty = ReadPlan::default();
        let fused = fuse(&[&empty, &a, &empty]);
        assert_eq!(fused.streams, 3);
        assert_eq!(fused.fused_bytes(), a.cmd_bytes());
        assert!(fused.copies.iter().all(|c| c.stream == 1));
        let none = fuse(&[&empty, &empty]);
        assert!(none.is_empty());
        assert_eq!(none.fused_bytes(), 0);
    }
}
