//! Flash weight store: on-device layout + chunked row reads.
//!
//! Each (layer, matrix) gets a contiguous region; rows are the selection
//! unit. Chunked selections translate to one extent per chunk — this is
//! where contiguity in *neuron index space* becomes contiguity in *flash
//! address space* (after the offline reorder permutation has been baked
//! into the physical layout).

use std::collections::HashMap;
use std::time::Duration;

use crate::latency::Chunk;
use crate::model::{DType, MatrixKind, ModelSpec};
use crate::plan::{CoalescePolicy, IoPlanner, PlannedRead, RowCursor};
use crate::reorder::Permutation;
use crate::rng::Rng;
use crate::storage::{Extent, FlashDevice};

/// Identifies one weight matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct MatrixId {
    pub layer: usize,
    pub kind: MatrixKind,
}

impl MatrixId {
    pub fn new(layer: usize, kind: MatrixKind) -> Self {
        Self { layer, kind }
    }
}

#[derive(Clone, Copy, Debug)]
struct Region {
    base: u64,
    row_bytes: usize,
    rows: usize,
}

/// Byte layout of all backbone matrices on the flash device.
#[derive(Clone, Debug)]
pub struct FlashLayout {
    regions: HashMap<MatrixId, Region>,
    total_bytes: u64,
    dtype: DType,
    /// Rows aligned up to 4 KiB (for O_DIRECT real-device experiments).
    pub align_rows: bool,
}

impl FlashLayout {
    /// Layout in the spec's historical dtype (fp16 paper models, f32
    /// runnable) — byte-identical to the pre-dtype-knob layouts.
    pub fn build(spec: &ModelSpec, align_rows: bool) -> Self {
        Self::build_with_dtype(spec, align_rows, spec.default_dtype())
    }

    /// Layout with every row stored in `dtype`'s encoded width.
    pub fn build_with_dtype(spec: &ModelSpec, align_rows: bool, dtype: DType) -> Self {
        let mut regions = HashMap::new();
        let mut at = 0u64;
        for layer in 0..spec.layers {
            for m in spec.matrices() {
                let mut row_bytes = dtype.encoded_row_bytes(m.cols);
                if align_rows {
                    row_bytes = row_bytes.div_ceil(4096) * 4096;
                }
                regions.insert(
                    MatrixId::new(layer, m.kind),
                    Region {
                        base: at,
                        row_bytes,
                        rows: m.rows,
                    },
                );
                at += (row_bytes * m.rows) as u64;
            }
        }
        Self {
            regions,
            total_bytes: at,
            dtype,
            align_rows,
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.total_bytes
    }

    /// Storage dtype every region's rows are encoded in.
    pub fn dtype(&self) -> DType {
        self.dtype
    }

    pub fn row_bytes(&self, id: MatrixId) -> usize {
        self.regions[&id].row_bytes
    }

    pub fn rows(&self, id: MatrixId) -> usize {
        self.regions[&id].rows
    }

    /// Byte offset of a row.
    pub fn row_offset(&self, id: MatrixId, row: usize) -> u64 {
        let r = &self.regions[&id];
        debug_assert!(row < r.rows);
        r.base + (row * r.row_bytes) as u64
    }

    /// All matrix regions in flat-address order:
    /// `(id, base, row_bytes, rows)`. Regions pack back-to-back, so the
    /// returned list tiles `[0, total_bytes)`. Used by
    /// [`crate::storage::StripeLayout`] to build row-aligned stripe maps.
    pub fn regions_in_order(&self) -> Vec<(MatrixId, u64, usize, usize)> {
        let mut v: Vec<(MatrixId, u64, usize, usize)> = self
            .regions
            .iter()
            .map(|(id, r)| (*id, r.base, r.row_bytes, r.rows))
            .collect();
        v.sort_by_key(|&(_, base, _, _)| base);
        v
    }

    /// One extent per chunk — a chunk of `len` adjacent rows is a single
    /// contiguous read of `len * row_bytes`.
    pub fn extents_for_chunks(&self, id: MatrixId, chunks: &[Chunk]) -> Vec<Extent> {
        let r = &self.regions[&id];
        chunks
            .iter()
            .map(|c| {
                debug_assert!(c.end() <= r.rows);
                Extent::new(
                    r.base + (c.start * r.row_bytes) as u64,
                    c.len * r.row_bytes,
                )
            })
            .collect()
    }
}

/// The weight store: layout + (for runnable models) deterministic weight
/// generation, offline reorder baking, and gathered-row reads.
pub struct WeightStore {
    pub spec: ModelSpec,
    pub layout: FlashLayout,
    /// Offline reorder permutation per matrix (identity if absent).
    perms: HashMap<MatrixId, Permutation>,
    seed: u64,
}

impl WeightStore {
    pub fn new(spec: ModelSpec, align_rows: bool, seed: u64) -> Self {
        let dtype = spec.default_dtype();
        Self::with_dtype(spec, align_rows, seed, dtype)
    }

    /// Store whose flash image is encoded in `dtype` (per-row scales
    /// inline for int8; see [`encode_row`]).
    pub fn with_dtype(spec: ModelSpec, align_rows: bool, seed: u64, dtype: DType) -> Self {
        let layout = FlashLayout::build_with_dtype(&spec, align_rows, dtype);
        Self {
            spec,
            layout,
            perms: HashMap::new(),
            seed,
        }
    }

    /// Storage dtype of the flash image this store builds and reads.
    pub fn dtype(&self) -> DType {
        self.layout.dtype()
    }

    /// Install an offline reorder permutation for a matrix. Must be set
    /// *before* `build_image` so the physical layout reflects it.
    pub fn set_permutation(&mut self, id: MatrixId, perm: Permutation) {
        assert_eq!(perm.len(), self.layout.rows(id));
        self.perms.insert(id, perm);
    }

    pub fn permutation(&self, id: MatrixId) -> Option<&Permutation> {
        self.perms.get(&id)
    }

    /// Deterministic f32 weights of one matrix in *logical* (unpermuted)
    /// row order: scaled normals, scale = 0.3/sqrt(rows) like the L2
    /// tests.
    pub fn logical_matrix(&self, id: MatrixId) -> Vec<f32> {
        let rows = self.layout.rows(id);
        let cols = self.spec.shape_of(id.kind).cols;
        let mut rng = Rng::new(
            self.seed ^ (id.layer as u64) << 32 ^ (id.kind as u64) << 8,
        );
        let scale = 0.3 / (rows as f64).sqrt();
        (0..rows * cols)
            .map(|_| (rng.normal() * scale) as f32)
            .collect()
    }

    /// Build the full flash image (runnable models): permuted rows written
    /// at their physical offsets, encoded per the store's dtype (f32
    /// little-endian by default — byte-identical to the historical image).
    pub fn build_image(&self) -> Vec<u8> {
        assert!(self.spec.runnable, "paper models are I/O-only");
        let dtype = self.dtype();
        let mut image = vec![0u8; self.layout.total_bytes() as usize];
        for layer in 0..self.spec.layers {
            for m in self.spec.matrices() {
                let id = MatrixId::new(layer, m.kind);
                let w = self.logical_matrix(id);
                let cols = m.cols;
                let enc = dtype.encoded_row_bytes(cols);
                for phys_row in 0..m.rows {
                    let logical = match self.perms.get(&id) {
                        Some(p) => p.old_of(phys_row),
                        None => phys_row,
                    };
                    let src = &w[logical * cols..(logical + 1) * cols];
                    let dst_off = self.layout.row_offset(id, phys_row) as usize;
                    encode_row(dtype, src, &mut image[dst_off..dst_off + enc]);
                }
            }
        }
        image
    }

    /// Read the rows of `chunks` (physical/reordered row space) from the
    /// device, decode to f32, and return (rows-major gathered weights,
    /// I/O service time). Routed through the I/O planning layer: the plan
    /// is built with the contiguous [`CoalescePolicy`] and submitted via
    /// [`FlashDevice::submit`], so this path and the engine's group reads
    /// share one device entry point.
    pub fn read_rows(
        &self,
        device: &dyn FlashDevice,
        id: MatrixId,
        chunks: &[Chunk],
    ) -> anyhow::Result<(Vec<f32>, Duration)> {
        let planner = IoPlanner::new(CoalescePolicy::contiguous());
        let plan = planner.plan_chunks(&self.layout, id, chunks, None);
        let receipt = device.submit(&plan)?;
        let read = PlannedRead { plan, receipt };
        let t = read.service();
        let cols = self.spec.shape_of(id.kind).cols;
        let n_rows: usize = chunks.iter().map(|c| c.len).sum();
        let mut out = Vec::with_capacity(n_rows * cols);
        let mut cursor = RowCursor::new(&read, id);
        for c in chunks {
            for r in c.start..c.end() {
                // A malformed plan must fail the request, not the
                // process: name the matrix and row so the caller can tell
                // which demand the plan missed.
                let row = cursor.advance_to(r).ok_or_else(|| {
                    anyhow::anyhow!(
                        "plan for matrix {:?} (layer {}) does not cover requested row {r}",
                        id.kind,
                        id.layer
                    )
                })?;
                let start = out.len();
                out.resize(start + cols, 0.0);
                decode_row_into(self.dtype(), row, &mut out[start..]);
            }
        }
        Ok((out, t))
    }

    /// Timing-only chunk read (I/O experiments on paper models).
    pub fn read_timing(
        &self,
        device: &dyn FlashDevice,
        id: MatrixId,
        chunks: &[Chunk],
    ) -> anyhow::Result<Duration> {
        let extents = self.layout.extents_for_chunks(id, chunks);
        device.service_time(&extents)
    }
}

/// Decode little-endian f32 values from `bytes` into `dst` (one value per
/// `dst` slot; `bytes` may be longer, e.g. page-padded rows).
pub(crate) fn decode_f32_into(bytes: &[u8], dst: &mut [f32]) {
    for (b, o) in bytes.chunks_exact(4).zip(dst.iter_mut()) {
        *o = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
    }
}

/// Encode one logical f32 row into its on-flash representation. `dst`
/// must be exactly `dtype.encoded_row_bytes(src.len())` long. Int8 rows
/// carry their scale inline: `[f32 LE max_abs/127][cols × i8]`.
pub fn encode_row(dtype: DType, src: &[f32], dst: &mut [u8]) {
    debug_assert_eq!(dst.len(), dtype.encoded_row_bytes(src.len()));
    match dtype {
        DType::F32 => {
            for (&v, b) in src.iter().zip(dst.chunks_exact_mut(4)) {
                b.copy_from_slice(&v.to_le_bytes());
            }
        }
        DType::F16 => {
            for (&v, b) in src.iter().zip(dst.chunks_exact_mut(2)) {
                b.copy_from_slice(&f32_to_f16_bits(v).to_le_bytes());
            }
        }
        DType::Int8 => {
            let max = src.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let scale = if max > 0.0 { max / 127.0 } else { 1.0 };
            dst[..4].copy_from_slice(&scale.to_le_bytes());
            let inv = 1.0 / scale;
            for (&v, b) in src.iter().zip(dst[4..].iter_mut()) {
                *b = ((v * inv).round().clamp(-127.0, 127.0) as i8) as u8;
            }
        }
    }
}

/// Decode one on-flash row back to f32 — the single dequantize-on-gather
/// entry point (fresh reads, async tickets, and cache staging all land
/// here). `bytes` may be longer than the encoded row (page-padded rows);
/// `dst.len()` values are produced.
pub(crate) fn decode_row_into(dtype: DType, bytes: &[u8], dst: &mut [f32]) {
    match dtype {
        DType::F32 => decode_f32_into(bytes, dst),
        DType::F16 => {
            for (b, o) in bytes.chunks_exact(2).zip(dst.iter_mut()) {
                *o = f16_bits_to_f32(u16::from_le_bytes([b[0], b[1]]));
            }
        }
        DType::Int8 => {
            let scale = f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
            for (&b, o) in bytes[4..].iter().zip(dst.iter_mut()) {
                *o = (b as i8) as f32 * scale;
            }
        }
    }
}

/// f32 → IEEE-754 binary16 bits, round-to-nearest-even (no `half` crate;
/// the conversion is pinned by round-trip tests below).
pub(crate) fn f32_to_f16_bits(v: f32) -> u16 {
    let bits = v.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp32 = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp32 == 0xff {
        // Inf / NaN (any NaN keeps a nonzero mantissa).
        return sign | 0x7c00 | if man != 0 { 0x0200 } else { 0 };
    }
    let exp = exp32 - 127 + 15;
    if exp >= 0x1f {
        return sign | 0x7c00; // overflow → ±inf
    }
    if exp <= 0 {
        if exp < -10 {
            return sign; // underflows past subnormals → ±0
        }
        // Subnormal: shift the implicit-1 mantissa into place.
        let man = man | 0x0080_0000;
        let shift = (14 - exp) as u32;
        let man16 = (man >> shift) as u16;
        let rest = man & ((1 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let round_up = rest > half || (rest == half && man16 & 1 == 1);
        return sign | (man16 + round_up as u16);
    }
    let man16 = (man >> 13) as u16;
    let rest = man & 0x1fff;
    let round_up = rest > 0x1000 || (rest == 0x1000 && man16 & 1 == 1);
    // A mantissa carry rolls into the exponent (and into inf) correctly.
    (sign | ((exp as u16) << 10) | man16) + round_up as u16
}

/// IEEE-754 binary16 bits → f32 (exact; every f16 is representable).
pub(crate) fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13)
    } else if exp == 0 {
        if man == 0 {
            sign
        } else {
            // Subnormal: renormalize into an f32 normal.
            let mut e = 113u32; // 127 - 15 + 1
            let mut m = man;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3ff) << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{DeviceProfile, SimulatedSsd};

    #[test]
    fn layout_regions_disjoint_and_packed() {
        let spec = ModelSpec::tiny();
        let layout = FlashLayout::build(&spec, false);
        let mut spans: Vec<(u64, u64)> = Vec::new();
        for layer in 0..spec.layers {
            for m in spec.matrices() {
                let id = MatrixId::new(layer, m.kind);
                let base = layout.row_offset(id, 0);
                let end = base + (layout.rows(id) * layout.row_bytes(id)) as u64;
                spans.push((base, end));
            }
        }
        spans.sort();
        for w in spans.windows(2) {
            assert!(w[0].1 <= w[1].0, "overlap {:?}", w);
        }
        assert_eq!(spans.last().unwrap().1, layout.total_bytes());
    }

    #[test]
    fn layout_total_matches_spec() {
        let spec = ModelSpec::small();
        let layout = FlashLayout::build(&spec, false);
        assert_eq!(layout.total_bytes(), spec.total_bytes());
    }

    #[test]
    fn aligned_layout_pages() {
        let spec = ModelSpec::tiny();
        let layout = FlashLayout::build(&spec, true);
        for layer in 0..spec.layers {
            for m in spec.matrices() {
                let id = MatrixId::new(layer, m.kind);
                assert_eq!(layout.row_bytes(id) % 4096, 0);
                assert_eq!(layout.row_offset(id, 1) % 4096, 0);
            }
        }
    }

    #[test]
    fn extents_merge_chunk_rows() {
        let spec = ModelSpec::tiny();
        let layout = FlashLayout::build(&spec, false);
        let id = MatrixId::new(0, MatrixKind::Down);
        let rb = layout.row_bytes(id);
        let chunks = [Chunk::new(3, 4), Chunk::new(10, 1)];
        let ex = layout.extents_for_chunks(id, &chunks);
        assert_eq!(ex.len(), 2);
        assert_eq!(ex[0].len, 4 * rb);
        assert_eq!(ex[0].offset, layout.row_offset(id, 3));
        assert_eq!(ex[1].len, rb);
    }

    #[test]
    fn image_round_trip_unpermuted() {
        let store = WeightStore::new(ModelSpec::tiny(), false, 42);
        let image = store.build_image();
        let dev = SimulatedSsd::with_image(DeviceProfile::nano(), image, 1);
        let id = MatrixId::new(1, MatrixKind::Gate);
        let logical = store.logical_matrix(id);
        let cols = store.spec.shape_of(MatrixKind::Gate).cols;
        let (rows, _) = store
            .read_rows(&dev, id, &[Chunk::new(5, 3)])
            .unwrap();
        assert_eq!(rows.len(), 3 * cols);
        assert_eq!(&rows[..cols], &logical[5 * cols..6 * cols]);
        assert_eq!(&rows[2 * cols..], &logical[7 * cols..8 * cols]);
    }

    #[test]
    fn image_round_trip_permuted() {
        let mut store = WeightStore::new(ModelSpec::tiny(), false, 42);
        let id = MatrixId::new(0, MatrixKind::Down);
        let n = store.layout.rows(id);
        // Reverse permutation: physical row p holds logical row n-1-p.
        let perm = Permutation::from_fwd((0..n as u32).rev().collect()).unwrap();
        store.set_permutation(id, perm);
        let image = store.build_image();
        let dev = SimulatedSsd::with_image(DeviceProfile::nano(), image, 1);
        let logical = store.logical_matrix(id);
        let cols = store.spec.shape_of(MatrixKind::Down).cols;
        let (rows, _) = store.read_rows(&dev, id, &[Chunk::new(0, 1)]).unwrap();
        assert_eq!(&rows[..], &logical[(n - 1) * cols..n * cols]);
    }

    #[test]
    fn weights_deterministic_per_seed() {
        let a = WeightStore::new(ModelSpec::tiny(), false, 7);
        let b = WeightStore::new(ModelSpec::tiny(), false, 7);
        let c = WeightStore::new(ModelSpec::tiny(), false, 8);
        let id = MatrixId::new(0, MatrixKind::Q);
        assert_eq!(a.logical_matrix(id), b.logical_matrix(id));
        assert_ne!(a.logical_matrix(id), c.logical_matrix(id));
    }

    #[test]
    fn matrices_differ_across_layers_and_kinds() {
        let s = WeightStore::new(ModelSpec::tiny(), false, 7);
        let a = s.logical_matrix(MatrixId::new(0, MatrixKind::Q));
        let b = s.logical_matrix(MatrixId::new(1, MatrixKind::Q));
        let c = s.logical_matrix(MatrixId::new(0, MatrixKind::K));
        assert_ne!(a, b);
        assert_ne!(a[..16], c[..16]);
    }

    #[test]
    fn quantized_layouts_shrink_rows() {
        let spec = ModelSpec::tiny();
        let f32l = FlashLayout::build_with_dtype(&spec, false, DType::F32);
        let f16l = FlashLayout::build_with_dtype(&spec, false, DType::F16);
        let i8l = FlashLayout::build_with_dtype(&spec, false, DType::Int8);
        for layer in 0..spec.layers {
            for m in spec.matrices() {
                let id = MatrixId::new(layer, m.kind);
                assert_eq!(f32l.row_bytes(id), m.cols * 4);
                assert_eq!(f16l.row_bytes(id), m.cols * 2);
                assert_eq!(i8l.row_bytes(id), 4 + m.cols);
            }
        }
        assert!(i8l.total_bytes() < f16l.total_bytes());
        assert!(f16l.total_bytes() < f32l.total_bytes());
        // The default layout is the spec-derived one, byte-identical.
        assert_eq!(
            FlashLayout::build(&spec, false).total_bytes(),
            f32l.total_bytes()
        );
    }

    #[test]
    fn f16_round_trip_and_edge_cases() {
        // Every finite f16 survives f16 → f32 → f16 exactly.
        for h in 0u16..=0xffff {
            let exp = (h >> 10) & 0x1f;
            if exp == 0x1f {
                continue; // inf/NaN handled below
            }
            assert_eq!(f32_to_f16_bits(f16_bits_to_f32(h)), h, "bits {h:#06x}");
        }
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        assert_eq!(f32_to_f16_bits(1e9), 0x7c00); // overflow → inf
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1e-10), 0x0000); // underflow → 0
        let nan = f32_to_f16_bits(f32::NAN);
        assert_eq!(nan & 0x7c00, 0x7c00);
        assert_ne!(nan & 0x03ff, 0);
        assert!(f16_bits_to_f32(0x7e00).is_nan());
        // Round-to-nearest-even at the halfway point: 1 + 2^-11 is
        // exactly between 1.0 and the next f16; even mantissa (1.0) wins.
        assert_eq!(f32_to_f16_bits(1.0 + 2f32.powi(-11)), 0x3c00);
        assert_eq!(f32_to_f16_bits(1.0 + 3.0 * 2f32.powi(-11)), 0x3c02);
    }

    #[test]
    fn encode_decode_round_trip_error_bounds() {
        let store = WeightStore::new(ModelSpec::tiny(), false, 9);
        let id = MatrixId::new(0, MatrixKind::Gate);
        let w = store.logical_matrix(id);
        let cols = store.spec.shape_of(MatrixKind::Gate).cols;
        let row = &w[..cols];
        let mut dec = vec![0f32; cols];

        // f32: bit-exact.
        let mut buf = vec![0u8; DType::F32.encoded_row_bytes(cols)];
        encode_row(DType::F32, row, &mut buf);
        decode_row_into(DType::F32, &buf, &mut dec);
        assert_eq!(row, &dec[..]);

        // fp16: relative error ≤ 2^-11 for normal-range weights.
        let mut buf = vec![0u8; DType::F16.encoded_row_bytes(cols)];
        encode_row(DType::F16, row, &mut buf);
        decode_row_into(DType::F16, &buf, &mut dec);
        for (&a, &b) in row.iter().zip(&dec) {
            // Half-ulp relative for normals, absolute 2^-25 once the
            // value lands in f16's subnormal range.
            let bound = (a.abs() * 2f32.powi(-11)).max(2f32.powi(-25));
            assert!((a - b).abs() <= bound, "{a} vs {b}");
        }

        // int8: absolute error ≤ scale/2 per element, scale stored inline.
        let mut buf = vec![0u8; DType::Int8.encoded_row_bytes(cols)];
        encode_row(DType::Int8, row, &mut buf);
        let scale = f32::from_le_bytes(buf[..4].try_into().unwrap());
        let max = row.iter().fold(0f32, |m, &v| m.max(v.abs()));
        assert!((scale - max / 127.0).abs() < 1e-12);
        decode_row_into(DType::Int8, &buf, &mut dec);
        for (&a, &b) in row.iter().zip(&dec) {
            assert!((a - b).abs() <= scale * 0.5 + 1e-12, "{a} vs {b}");
        }

        // An all-zero row encodes without dividing by zero.
        let zeros = vec![0f32; cols];
        encode_row(DType::Int8, &zeros, &mut buf);
        decode_row_into(DType::Int8, &buf, &mut dec);
        assert!(dec.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn quantized_image_round_trip_through_device() {
        for dtype in [DType::F16, DType::Int8] {
            let store = WeightStore::with_dtype(ModelSpec::tiny(), false, 42, dtype);
            let image = store.build_image();
            assert_eq!(image.len() as u64, store.layout.total_bytes());
            let dev = SimulatedSsd::with_image(DeviceProfile::nano(), image, 1);
            let id = MatrixId::new(1, MatrixKind::Gate);
            let logical = store.logical_matrix(id);
            let cols = store.spec.shape_of(MatrixKind::Gate).cols;
            let (rows, _) = store.read_rows(&dev, id, &[Chunk::new(5, 3)]).unwrap();
            assert_eq!(rows.len(), 3 * cols);
            // Decoded rows match the logical weights to the dtype's bound.
            for (i, r) in (5..8).enumerate() {
                let src = &logical[r * cols..(r + 1) * cols];
                let max = src.iter().fold(0f32, |m, &v| m.max(v.abs()));
                let bound = match dtype {
                    DType::Int8 => max / 127.0 * 0.5 + 1e-12,
                    _ => max * 2f32.powi(-11) + 1e-12,
                };
                for (&a, &b) in src.iter().zip(&rows[i * cols..(i + 1) * cols]) {
                    assert!((a - b).abs() <= bound, "{dtype:?}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn timing_read_on_paper_model() {
        let store = WeightStore::new(ModelSpec::llava_05b(), false, 1);
        let dev = SimulatedSsd::timing_only(
            DeviceProfile::nano(),
            store.layout.total_bytes(),
            3,
        );
        let id = MatrixId::new(10, MatrixKind::Down);
        let t = store
            .read_timing(&dev, id, &[Chunk::new(0, 64), Chunk::new(1000, 64)])
            .unwrap();
        assert!(t > Duration::ZERO);
    }
}
