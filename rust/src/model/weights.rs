//! Flash weight store: on-device layout + chunked row reads.
//!
//! Each (layer, matrix) gets a contiguous region; rows are the selection
//! unit. Chunked selections translate to one extent per chunk — this is
//! where contiguity in *neuron index space* becomes contiguity in *flash
//! address space* (after the offline reorder permutation has been baked
//! into the physical layout).

use std::collections::HashMap;
use std::time::Duration;

use crate::latency::Chunk;
use crate::model::{MatrixKind, ModelSpec};
use crate::plan::{CoalescePolicy, IoPlanner, PlannedRead, RowCursor};
use crate::reorder::Permutation;
use crate::rng::Rng;
use crate::storage::{Extent, FlashDevice};

/// Identifies one weight matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct MatrixId {
    pub layer: usize,
    pub kind: MatrixKind,
}

impl MatrixId {
    pub fn new(layer: usize, kind: MatrixKind) -> Self {
        Self { layer, kind }
    }
}

#[derive(Clone, Copy, Debug)]
struct Region {
    base: u64,
    row_bytes: usize,
    rows: usize,
}

/// Byte layout of all backbone matrices on the flash device.
#[derive(Clone, Debug)]
pub struct FlashLayout {
    regions: HashMap<MatrixId, Region>,
    total_bytes: u64,
    /// Rows aligned up to 4 KiB (for O_DIRECT real-device experiments).
    pub align_rows: bool,
}

impl FlashLayout {
    pub fn build(spec: &ModelSpec, align_rows: bool) -> Self {
        let mut regions = HashMap::new();
        let mut at = 0u64;
        for layer in 0..spec.layers {
            for m in spec.matrices() {
                let mut row_bytes = m.cols * spec.dtype_bytes;
                if align_rows {
                    row_bytes = row_bytes.div_ceil(4096) * 4096;
                }
                regions.insert(
                    MatrixId::new(layer, m.kind),
                    Region {
                        base: at,
                        row_bytes,
                        rows: m.rows,
                    },
                );
                at += (row_bytes * m.rows) as u64;
            }
        }
        Self {
            regions,
            total_bytes: at,
            align_rows,
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.total_bytes
    }

    pub fn row_bytes(&self, id: MatrixId) -> usize {
        self.regions[&id].row_bytes
    }

    pub fn rows(&self, id: MatrixId) -> usize {
        self.regions[&id].rows
    }

    /// Byte offset of a row.
    pub fn row_offset(&self, id: MatrixId, row: usize) -> u64 {
        let r = &self.regions[&id];
        debug_assert!(row < r.rows);
        r.base + (row * r.row_bytes) as u64
    }

    /// All matrix regions in flat-address order:
    /// `(id, base, row_bytes, rows)`. Regions pack back-to-back, so the
    /// returned list tiles `[0, total_bytes)`. Used by
    /// [`crate::storage::StripeLayout`] to build row-aligned stripe maps.
    pub fn regions_in_order(&self) -> Vec<(MatrixId, u64, usize, usize)> {
        let mut v: Vec<(MatrixId, u64, usize, usize)> = self
            .regions
            .iter()
            .map(|(id, r)| (*id, r.base, r.row_bytes, r.rows))
            .collect();
        v.sort_by_key(|&(_, base, _, _)| base);
        v
    }

    /// One extent per chunk — a chunk of `len` adjacent rows is a single
    /// contiguous read of `len * row_bytes`.
    pub fn extents_for_chunks(&self, id: MatrixId, chunks: &[Chunk]) -> Vec<Extent> {
        let r = &self.regions[&id];
        chunks
            .iter()
            .map(|c| {
                debug_assert!(c.end() <= r.rows);
                Extent::new(
                    r.base + (c.start * r.row_bytes) as u64,
                    c.len * r.row_bytes,
                )
            })
            .collect()
    }
}

/// The weight store: layout + (for runnable models) deterministic weight
/// generation, offline reorder baking, and gathered-row reads.
pub struct WeightStore {
    pub spec: ModelSpec,
    pub layout: FlashLayout,
    /// Offline reorder permutation per matrix (identity if absent).
    perms: HashMap<MatrixId, Permutation>,
    seed: u64,
}

impl WeightStore {
    pub fn new(spec: ModelSpec, align_rows: bool, seed: u64) -> Self {
        let layout = FlashLayout::build(&spec, align_rows);
        Self {
            spec,
            layout,
            perms: HashMap::new(),
            seed,
        }
    }

    /// Install an offline reorder permutation for a matrix. Must be set
    /// *before* `build_image` so the physical layout reflects it.
    pub fn set_permutation(&mut self, id: MatrixId, perm: Permutation) {
        assert_eq!(perm.len(), self.layout.rows(id));
        self.perms.insert(id, perm);
    }

    pub fn permutation(&self, id: MatrixId) -> Option<&Permutation> {
        self.perms.get(&id)
    }

    /// Deterministic f32 weights of one matrix in *logical* (unpermuted)
    /// row order: scaled normals, scale = 0.3/sqrt(rows) like the L2
    /// tests.
    pub fn logical_matrix(&self, id: MatrixId) -> Vec<f32> {
        let rows = self.layout.rows(id);
        let cols = self.spec.shape_of(id.kind).cols;
        let mut rng = Rng::new(
            self.seed ^ (id.layer as u64) << 32 ^ (id.kind as u64) << 8,
        );
        let scale = 0.3 / (rows as f64).sqrt();
        (0..rows * cols)
            .map(|_| (rng.normal() * scale) as f32)
            .collect()
    }

    /// Build the full flash image (runnable models): permuted rows written
    /// at their physical offsets, f32 little-endian.
    pub fn build_image(&self) -> Vec<u8> {
        assert!(self.spec.runnable, "paper models are I/O-only");
        let mut image = vec![0u8; self.layout.total_bytes() as usize];
        for layer in 0..self.spec.layers {
            for m in self.spec.matrices() {
                let id = MatrixId::new(layer, m.kind);
                let w = self.logical_matrix(id);
                let cols = m.cols;
                let row_bytes = self.layout.row_bytes(id);
                for phys_row in 0..m.rows {
                    let logical = match self.perms.get(&id) {
                        Some(p) => p.old_of(phys_row),
                        None => phys_row,
                    };
                    let src = &w[logical * cols..(logical + 1) * cols];
                    let dst_off = self.layout.row_offset(id, phys_row) as usize;
                    let dst = &mut image[dst_off..dst_off + cols * 4];
                    for (j, &v) in src.iter().enumerate() {
                        dst[j * 4..j * 4 + 4].copy_from_slice(&v.to_le_bytes());
                    }
                    let _ = row_bytes;
                }
            }
        }
        image
    }

    /// Read the rows of `chunks` (physical/reordered row space) from the
    /// device, decode to f32, and return (rows-major gathered weights,
    /// I/O service time). Routed through the I/O planning layer: the plan
    /// is built with the contiguous [`CoalescePolicy`] and submitted via
    /// [`FlashDevice::submit`], so this path and the engine's group reads
    /// share one device entry point.
    pub fn read_rows(
        &self,
        device: &dyn FlashDevice,
        id: MatrixId,
        chunks: &[Chunk],
    ) -> anyhow::Result<(Vec<f32>, Duration)> {
        let planner = IoPlanner::new(CoalescePolicy::contiguous());
        let plan = planner.plan_chunks(&self.layout, id, chunks, None);
        let receipt = device.submit(&plan)?;
        let read = PlannedRead { plan, receipt };
        let t = read.service();
        let cols = self.spec.shape_of(id.kind).cols;
        let n_rows: usize = chunks.iter().map(|c| c.len).sum();
        let mut out = Vec::with_capacity(n_rows * cols);
        let mut cursor = RowCursor::new(&read, id);
        for c in chunks {
            for r in c.start..c.end() {
                // A malformed plan must fail the request, not the
                // process: name the matrix and row so the caller can tell
                // which demand the plan missed.
                let row = cursor.advance_to(r).ok_or_else(|| {
                    anyhow::anyhow!(
                        "plan for matrix {:?} (layer {}) does not cover requested row {r}",
                        id.kind,
                        id.layer
                    )
                })?;
                decode_f32_row(row, cols, &mut out);
            }
        }
        Ok((out, t))
    }

    /// Timing-only chunk read (I/O experiments on paper models).
    pub fn read_timing(
        &self,
        device: &dyn FlashDevice,
        id: MatrixId,
        chunks: &[Chunk],
    ) -> anyhow::Result<Duration> {
        let extents = self.layout.extents_for_chunks(id, chunks);
        device.service_time(&extents)
    }
}

/// Decode little-endian f32 values from `bytes` into `dst` (one value per
/// `dst` slot; `bytes` may be longer, e.g. page-padded rows).
pub(crate) fn decode_f32_into(bytes: &[u8], dst: &mut [f32]) {
    for (j, o) in dst.iter_mut().enumerate() {
        *o = f32::from_le_bytes(bytes[j * 4..j * 4 + 4].try_into().unwrap());
    }
}

/// Decode `cols` little-endian f32 values from the head of `row`,
/// appending to `out`.
pub(crate) fn decode_f32_row(row: &[u8], cols: usize, out: &mut Vec<f32>) {
    let start = out.len();
    out.resize(start + cols, 0.0);
    decode_f32_into(row, &mut out[start..]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{DeviceProfile, SimulatedSsd};

    #[test]
    fn layout_regions_disjoint_and_packed() {
        let spec = ModelSpec::tiny();
        let layout = FlashLayout::build(&spec, false);
        let mut spans: Vec<(u64, u64)> = Vec::new();
        for layer in 0..spec.layers {
            for m in spec.matrices() {
                let id = MatrixId::new(layer, m.kind);
                let base = layout.row_offset(id, 0);
                let end = base + (layout.rows(id) * layout.row_bytes(id)) as u64;
                spans.push((base, end));
            }
        }
        spans.sort();
        for w in spans.windows(2) {
            assert!(w[0].1 <= w[1].0, "overlap {:?}", w);
        }
        assert_eq!(spans.last().unwrap().1, layout.total_bytes());
    }

    #[test]
    fn layout_total_matches_spec() {
        let spec = ModelSpec::small();
        let layout = FlashLayout::build(&spec, false);
        assert_eq!(layout.total_bytes(), spec.total_bytes());
    }

    #[test]
    fn aligned_layout_pages() {
        let spec = ModelSpec::tiny();
        let layout = FlashLayout::build(&spec, true);
        for layer in 0..spec.layers {
            for m in spec.matrices() {
                let id = MatrixId::new(layer, m.kind);
                assert_eq!(layout.row_bytes(id) % 4096, 0);
                assert_eq!(layout.row_offset(id, 1) % 4096, 0);
            }
        }
    }

    #[test]
    fn extents_merge_chunk_rows() {
        let spec = ModelSpec::tiny();
        let layout = FlashLayout::build(&spec, false);
        let id = MatrixId::new(0, MatrixKind::Down);
        let rb = layout.row_bytes(id);
        let chunks = [Chunk::new(3, 4), Chunk::new(10, 1)];
        let ex = layout.extents_for_chunks(id, &chunks);
        assert_eq!(ex.len(), 2);
        assert_eq!(ex[0].len, 4 * rb);
        assert_eq!(ex[0].offset, layout.row_offset(id, 3));
        assert_eq!(ex[1].len, rb);
    }

    #[test]
    fn image_round_trip_unpermuted() {
        let store = WeightStore::new(ModelSpec::tiny(), false, 42);
        let image = store.build_image();
        let dev = SimulatedSsd::with_image(DeviceProfile::nano(), image, 1);
        let id = MatrixId::new(1, MatrixKind::Gate);
        let logical = store.logical_matrix(id);
        let cols = store.spec.shape_of(MatrixKind::Gate).cols;
        let (rows, _) = store
            .read_rows(&dev, id, &[Chunk::new(5, 3)])
            .unwrap();
        assert_eq!(rows.len(), 3 * cols);
        assert_eq!(&rows[..cols], &logical[5 * cols..6 * cols]);
        assert_eq!(&rows[2 * cols..], &logical[7 * cols..8 * cols]);
    }

    #[test]
    fn image_round_trip_permuted() {
        let mut store = WeightStore::new(ModelSpec::tiny(), false, 42);
        let id = MatrixId::new(0, MatrixKind::Down);
        let n = store.layout.rows(id);
        // Reverse permutation: physical row p holds logical row n-1-p.
        let perm = Permutation::from_fwd((0..n as u32).rev().collect()).unwrap();
        store.set_permutation(id, perm);
        let image = store.build_image();
        let dev = SimulatedSsd::with_image(DeviceProfile::nano(), image, 1);
        let logical = store.logical_matrix(id);
        let cols = store.spec.shape_of(MatrixKind::Down).cols;
        let (rows, _) = store.read_rows(&dev, id, &[Chunk::new(0, 1)]).unwrap();
        assert_eq!(&rows[..], &logical[(n - 1) * cols..n * cols]);
    }

    #[test]
    fn weights_deterministic_per_seed() {
        let a = WeightStore::new(ModelSpec::tiny(), false, 7);
        let b = WeightStore::new(ModelSpec::tiny(), false, 7);
        let c = WeightStore::new(ModelSpec::tiny(), false, 8);
        let id = MatrixId::new(0, MatrixKind::Q);
        assert_eq!(a.logical_matrix(id), b.logical_matrix(id));
        assert_ne!(a.logical_matrix(id), c.logical_matrix(id));
    }

    #[test]
    fn matrices_differ_across_layers_and_kinds() {
        let s = WeightStore::new(ModelSpec::tiny(), false, 7);
        let a = s.logical_matrix(MatrixId::new(0, MatrixKind::Q));
        let b = s.logical_matrix(MatrixId::new(1, MatrixKind::Q));
        let c = s.logical_matrix(MatrixId::new(0, MatrixKind::K));
        assert_ne!(a, b);
        assert_ne!(a[..16], c[..16]);
    }

    #[test]
    fn timing_read_on_paper_model() {
        let store = WeightStore::new(ModelSpec::llava_05b(), false, 1);
        let dev = SimulatedSsd::timing_only(
            DeviceProfile::nano(),
            store.layout.total_bytes(),
            3,
        );
        let id = MatrixId::new(10, MatrixKind::Down);
        let t = store
            .read_timing(&dev, id, &[Chunk::new(0, 64), Chunk::new(1000, 64)])
            .unwrap();
        assert!(t > Duration::ZERO);
    }
}
