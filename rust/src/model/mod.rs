//! Model substrate: specs for the paper's evaluation models (exact matrix
//! shapes) and runnable small models, plus the flash weight store with its
//! on-device layout.

mod spec;
mod weights;

pub use spec::{DType, MatrixKind, MatrixShape, ModelSpec, SelectionGroup};
pub use weights::{encode_row, FlashLayout, MatrixId, WeightStore};

pub(crate) use weights::decode_row_into;
