//! Model specifications.
//!
//! Two families:
//! * **Paper models** (I/O experiments only — weights never materialize):
//!   the five VLMs of §4.1 with their exact projection shapes, matching
//!   Appendix H Table 2 row-by-row. fp16 like the paper.
//! * **Runnable models** (`tiny`, `small`, `base`): the real transformers
//!   compiled to HLO artifacts by the Python layer; f32, dims mirrored
//!   from `python/compile/model.py`.

/// On-flash storage dtype of the weight image. Selection, planning, and
/// the latency table all price chunks at the *encoded* row width; the
/// gather stage decodes every row back to f32 before compute, so outputs
/// differ only by the quantization error of the storage format.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum DType {
    /// 4 bytes/element, bit-identical to the historical layout.
    #[default]
    F32,
    /// IEEE-754 binary16, 2 bytes/element (round-to-nearest-even).
    F16,
    /// Symmetric per-row int8: a leading f32 scale (max-abs / 127)
    /// followed by `cols` signed bytes — `4 + cols` bytes per row.
    Int8,
}

impl DType {
    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F16 => "fp16",
            DType::Int8 => "int8",
        }
    }

    /// Encoded bytes of one `cols`-wide row on flash (the read unit the
    /// planner, the selection table, and the cache budget all price).
    pub fn encoded_row_bytes(&self, cols: usize) -> usize {
        match self {
            DType::F32 => cols * 4,
            DType::F16 => cols * 2,
            DType::Int8 => 4 + cols,
        }
    }
}

impl std::str::FromStr for DType {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "f32" | "fp32" => Ok(DType::F32),
            "f16" | "fp16" => Ok(DType::F16),
            "int8" | "i8" => Ok(DType::Int8),
            other => Err(format!(
                "unknown dtype {other:?} (expected f32, fp16, or int8)"
            )),
        }
    }
}

/// The seven per-layer projection matrices of a (grouped-query) decoder
/// block. Sparsification selects *input rows*; K/V share the Q selection
/// and Up shares Gate's, since they consume the same activations (paper
/// Appendix A).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum MatrixKind {
    Q,
    K,
    V,
    O,
    Gate,
    Up,
    Down,
}

impl MatrixKind {
    pub const ALL: [MatrixKind; 7] = [
        MatrixKind::Q,
        MatrixKind::K,
        MatrixKind::V,
        MatrixKind::O,
        MatrixKind::Gate,
        MatrixKind::Up,
        MatrixKind::Down,
    ];

    /// The matrices with their own activation scoring + selection run
    /// (q, o, gate, down — Appendix A; k/v/up reuse a sibling's mask).
    pub const SCORED: [MatrixKind; 4] = [
        MatrixKind::Q,
        MatrixKind::O,
        MatrixKind::Gate,
        MatrixKind::Down,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            MatrixKind::Q => "q",
            MatrixKind::K => "k",
            MatrixKind::V => "v",
            MatrixKind::O => "o",
            MatrixKind::Gate => "gate",
            MatrixKind::Up => "up",
            MatrixKind::Down => "down",
        }
    }

    /// Which scored matrix provides this matrix's selection mask.
    pub fn mask_source(&self) -> MatrixKind {
        match self {
            MatrixKind::K | MatrixKind::V => MatrixKind::Q,
            MatrixKind::Up => MatrixKind::Gate,
            other => *other,
        }
    }
}

/// Rows × cols of one weight matrix (rows = input/selection dim).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MatrixShape {
    pub kind: MatrixKind,
    pub rows: usize,
    pub cols: usize,
}

/// A group of matrices loaded under one selection mask.
#[derive(Clone, Debug)]
pub struct SelectionGroup {
    /// The matrix whose input activation is scored.
    pub scored: MatrixKind,
    /// All matrices loaded with that mask (includes `scored`).
    pub members: Vec<MatrixKind>,
}

/// A model's dimensions and storage parameters.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    /// Hidden size (input dim of q/k/v/gate/up; output of o/down).
    pub d: usize,
    /// MLP intermediate size (input dim of down).
    pub h: usize,
    /// KV projection width (grouped-query attention).
    pub kv: usize,
    /// Attention heads.
    pub nh: usize,
    pub layers: usize,
    /// Visual tokens per frame.
    pub tokens_per_frame: usize,
    /// KV-cache capacity in slots (runnable models only).
    pub cache_slots: usize,
    /// Bytes per weight element (2 = fp16 paper models, 4 = f32 runnable).
    pub dtype_bytes: usize,
    /// Whether HLO artifacts exist for actual execution.
    pub runnable: bool,
}

impl ModelSpec {
    /// LLaVA-OneVision-Qwen2-7B (Qwen2-7B backbone).
    pub fn llava_7b() -> Self {
        Self::paper("llava-7b", 3584, 18944, 512, 28, 28, 196)
    }

    /// LLaVA-OneVision-Qwen2-0.5B (Qwen2-0.5B backbone).
    pub fn llava_05b() -> Self {
        Self::paper("llava-0.5b", 896, 4864, 128, 14, 24, 196)
    }

    /// Llama-3-VILA1.5-8B (Llama-3-8B backbone).
    pub fn vila_8b() -> Self {
        Self::paper("vila-8b", 4096, 14336, 1024, 32, 32, 196)
    }

    /// NVILA-Lite-2B (Qwen2.5-1.5B backbone).
    pub fn nvila_2b() -> Self {
        Self::paper("nvila-2b", 1536, 8960, 256, 12, 28, 196)
    }

    /// LongVA-7B (Qwen2-7B backbone).
    pub fn longva_7b() -> Self {
        Self::paper("longva-7b", 3584, 18944, 512, 28, 28, 144)
    }

    fn paper(
        name: &str,
        d: usize,
        h: usize,
        kv: usize,
        nh: usize,
        layers: usize,
        tokens: usize,
    ) -> Self {
        Self {
            name: name.into(),
            d,
            h,
            kv,
            nh,
            layers,
            tokens_per_frame: tokens,
            cache_slots: 0,
            dtype_bytes: 2,
            runnable: false,
        }
    }

    /// Runnable models — dims must match `python/compile/model.py`.
    pub fn tiny() -> Self {
        Self::runnable("tiny", 64, 192, 4, 8, 32, 2)
    }

    pub fn small() -> Self {
        Self::runnable("small", 256, 768, 4, 16, 128, 4)
    }

    pub fn base() -> Self {
        Self::runnable("base", 512, 1536, 8, 32, 256, 8)
    }

    fn runnable(name: &str, d: usize, h: usize, nh: usize, t: usize, c: usize, layers: usize) -> Self {
        Self {
            name: name.into(),
            d,
            h,
            kv: d, // runnable models use full multi-head attention
            nh,
            layers,
            tokens_per_frame: t,
            cache_slots: c,
            dtype_bytes: 4,
            runnable: true,
        }
    }

    /// The five paper evaluation models (§4.1 order).
    pub fn paper_models() -> Vec<ModelSpec> {
        vec![
            Self::llava_7b(),
            Self::llava_05b(),
            Self::vila_8b(),
            Self::nvila_2b(),
            Self::longva_7b(),
        ]
    }

    pub fn by_name(name: &str) -> Option<ModelSpec> {
        match name {
            "llava-7b" => Some(Self::llava_7b()),
            "llava-0.5b" => Some(Self::llava_05b()),
            "vila-8b" => Some(Self::vila_8b()),
            "nvila-2b" => Some(Self::nvila_2b()),
            "longva-7b" => Some(Self::longva_7b()),
            "tiny" => Some(Self::tiny()),
            "small" => Some(Self::small()),
            "base" => Some(Self::base()),
            _ => None,
        }
    }

    /// Per-layer matrix shapes (rows = selection dim).
    pub fn matrices(&self) -> Vec<MatrixShape> {
        let m = |kind, rows, cols| MatrixShape { kind, rows, cols };
        vec![
            m(MatrixKind::Q, self.d, self.d),
            m(MatrixKind::K, self.d, self.kv),
            m(MatrixKind::V, self.d, self.kv),
            m(MatrixKind::O, self.d, self.d),
            m(MatrixKind::Gate, self.d, self.h),
            m(MatrixKind::Up, self.d, self.h),
            m(MatrixKind::Down, self.h, self.d),
        ]
    }

    pub fn shape_of(&self, kind: MatrixKind) -> MatrixShape {
        // Allocation-free (the serving path queries shapes per stage).
        let (rows, cols) = match kind {
            MatrixKind::Q => (self.d, self.d),
            MatrixKind::K | MatrixKind::V => (self.d, self.kv),
            MatrixKind::O => (self.d, self.d),
            MatrixKind::Gate | MatrixKind::Up => (self.d, self.h),
            MatrixKind::Down => (self.h, self.d),
        };
        MatrixShape { kind, rows, cols }
    }

    /// Selection groups: q→{q,k,v}, o→{o}, gate→{gate,up}, down→{down}.
    pub fn selection_groups(&self) -> Vec<SelectionGroup> {
        vec![
            SelectionGroup {
                scored: MatrixKind::Q,
                members: vec![MatrixKind::Q, MatrixKind::K, MatrixKind::V],
            },
            SelectionGroup {
                scored: MatrixKind::O,
                members: vec![MatrixKind::O],
            },
            SelectionGroup {
                scored: MatrixKind::Gate,
                members: vec![MatrixKind::Gate, MatrixKind::Up],
            },
            SelectionGroup {
                scored: MatrixKind::Down,
                members: vec![MatrixKind::Down],
            },
        ]
    }

    /// Bytes of one row of `kind` (the flash read unit).
    pub fn row_bytes(&self, kind: MatrixKind) -> usize {
        self.shape_of(kind).cols * self.dtype_bytes
    }

    /// The storage dtype this spec's `dtype_bytes` historically implied:
    /// fp16 paper models, f32 runnable models. Layouts built with it are
    /// byte-identical to the pre-dtype-knob layouts.
    pub fn default_dtype(&self) -> DType {
        if self.dtype_bytes == 2 {
            DType::F16
        } else {
            DType::F32
        }
    }

    /// Total backbone weight bytes.
    pub fn total_bytes(&self) -> u64 {
        let per_layer: usize = self
            .matrices()
            .iter()
            .map(|m| m.rows * m.cols * self.dtype_bytes)
            .sum();
        per_layer as u64 * self.layers as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_shapes_match_table2() {
        // Every (rows, cols) in Appendix H Table 2 must appear in some
        // paper model's matrix inventory.
        use crate::sparsify::tuning::paper_table2;
        let mut all_shapes = std::collections::HashSet::new();
        for m in ModelSpec::paper_models() {
            for s in m.matrices() {
                all_shapes.insert((s.rows, s.cols));
            }
        }
        for e in paper_table2() {
            assert!(
                all_shapes.contains(&(e.rows, e.cols)),
                "Table 2 shape ({}, {}) missing from model inventory",
                e.rows,
                e.cols
            );
        }
    }

    #[test]
    fn llava7b_sizes() {
        let m = ModelSpec::llava_7b();
        // Qwen2-7B MLP weights ~128 MB per... Fig 4 reads 128 MB = one
        // fp16 gate/up matrix (3584*18944*2 = 129.6 MB).
        assert_eq!(m.row_bytes(MatrixKind::Gate), 18944 * 2);
        let gate_bytes = 3584 * 18944 * 2;
        assert!((gate_bytes as f64 - 128e6).abs() < 10e6);
        // ~7B params total (backbone minus embeddings).
        let params = m.total_bytes() / 2;
        assert!((6e9..8e9).contains(&(params as f64)), "params {params}");
    }

    #[test]
    fn runnable_dims_match_python_manifest() {
        // Mirror of python/compile/model.py TINY/SMALL/BASE.
        let t = ModelSpec::tiny();
        assert_eq!((t.d, t.h, t.nh, t.tokens_per_frame, t.cache_slots, t.layers), (64, 192, 4, 8, 32, 2));
        let s = ModelSpec::small();
        assert_eq!((s.d, s.h, s.nh, s.tokens_per_frame, s.cache_slots, s.layers), (256, 768, 4, 16, 128, 4));
    }

    #[test]
    fn mask_sources() {
        assert_eq!(MatrixKind::K.mask_source(), MatrixKind::Q);
        assert_eq!(MatrixKind::V.mask_source(), MatrixKind::Q);
        assert_eq!(MatrixKind::Up.mask_source(), MatrixKind::Gate);
        assert_eq!(MatrixKind::Down.mask_source(), MatrixKind::Down);
    }

    #[test]
    fn selection_groups_cover_all_matrices() {
        let m = ModelSpec::small();
        let mut covered: Vec<MatrixKind> = m
            .selection_groups()
            .iter()
            .flat_map(|g| g.members.clone())
            .collect();
        covered.sort();
        covered.dedup();
        assert_eq!(covered.len(), 7);
    }

    #[test]
    fn by_name_round_trip() {
        for m in ModelSpec::paper_models() {
            assert_eq!(ModelSpec::by_name(&m.name).unwrap().d, m.d);
        }
        assert!(ModelSpec::by_name("gpt-5").is_none());
    }

    #[test]
    fn scored_matrices_are_mask_sources() {
        for k in MatrixKind::ALL {
            assert!(MatrixKind::SCORED.contains(&k.mask_source()));
        }
    }

    #[test]
    fn dtype_parse_and_row_widths() {
        assert_eq!("f32".parse::<DType>().unwrap(), DType::F32);
        assert_eq!("fp16".parse::<DType>().unwrap(), DType::F16);
        assert_eq!("f16".parse::<DType>().unwrap(), DType::F16);
        assert_eq!("int8".parse::<DType>().unwrap(), DType::Int8);
        assert!("bf16".parse::<DType>().is_err());
        assert_eq!(DType::F32.encoded_row_bytes(192), 768);
        assert_eq!(DType::F16.encoded_row_bytes(192), 384);
        assert_eq!(DType::Int8.encoded_row_bytes(192), 196);
        // Spec-derived defaults reproduce the historical layouts.
        assert_eq!(ModelSpec::tiny().default_dtype(), DType::F32);
        assert_eq!(ModelSpec::llava_7b().default_dtype(), DType::F16);
    }
}
