//! Flash storage substrate.
//!
//! The paper's entire mechanism rests on one hardware property: flash read
//! latency is governed by **access contiguity**, not just volume (§2.3,
//! Fig 3/4). We reproduce that property twice over:
//!
//! * [`SimulatedSsd`] — an analytical SSD service-time model with device
//!   profiles calibrated to the paper's published curves (Jetson Orin
//!   Nano + SK Hynix P31, Jetson AGX Orin + Samsung 990 Pro). Used by
//!   every figure/table bench so results are deterministic and
//!   hardware-independent.
//! * [`RealFileDevice`] — a thread-pooled `pread` engine over an actual
//!   file (the paper uses a 6-thread C++ pool with direct I/O), so the
//!   same experiments can run against real storage.
//!
//! [`Profiler`] implements the Appendix-D microbenchmark that builds the
//! `T[s]` lookup table against either backend.
//!
//! [`DevicePool`] stripes the flat weight space across several members
//! (see `pool.rs`), and [`AsyncIoQueue`] supplies per-member I/O worker
//! threads behind bounded submission queues so the engine can overlap
//! wall-clock flash reads with compute (see `async_queue.rs`).

mod async_queue;
mod fault;
mod pool;
mod profile;
mod profiler;
mod real;
mod sim;

use std::time::Duration;

use crate::plan::{PlanReceipt, ReadPlan};

pub use async_queue::{AsyncIoQueue, IoTicket};
pub use fault::{FaultConfig, FaultHandle, FaultInjector};
pub(crate) use fault::dead_member_from_env;
pub use pool::{
    DevicePool, HedgeConfig, PoolHealth, PoolHealthSnapshot, PoolScratch, PoolStats, StripeLayout,
    StripePolicy,
};
pub use profile::DeviceProfile;
pub use profiler::{ProfileConfig, Profiler};
pub use real::RealFileDevice;
pub use sim::SimulatedSsd;

/// Read attempts per member before the pool declares it failed: one
/// initial try plus three retries. Transient injected/firmware errors
/// are absorbed here; only a *persistently* failing member escalates to
/// failover (replica re-route) or a typed [`PoolError`].
pub const READ_ATTEMPTS: usize = 4;

/// Typed pool failure surfaced through `anyhow` (callers can
/// `downcast_ref::<PoolError>()`). Degraded-mode serving relies on these
/// being clean errors: a dead member must never panic or hang a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolError {
    /// A member kept failing after [`READ_ATTEMPTS`] attempts.
    MemberFailed { member: usize },
    /// The request touches bytes whose only replica(s) live on dead
    /// member(s); replication cannot cover it.
    Uncovered { member: usize },
}

impl std::fmt::Display for PoolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PoolError::MemberFailed { member } => {
                write!(f, "pool member {member} failed after {READ_ATTEMPTS} attempts")
            }
            PoolError::Uncovered { member } => write!(
                f,
                "request touches extents only held by dead pool member {member} \
                 (not replica-covered)"
            ),
        }
    }
}

impl std::error::Error for PoolError {}

/// One contiguous byte range on the device.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Extent {
    pub offset: u64,
    pub len: usize,
}

impl Extent {
    pub fn new(offset: u64, len: usize) -> Self {
        Self { offset, len }
    }

    pub fn end(&self) -> u64 {
        self.offset + self.len as u64
    }
}

/// A flash device that can serve batched extent reads.
///
/// `read_batch` returns the bytes (concatenated in request order) plus the
/// *service time* — simulated virtual time for [`SimulatedSsd`], measured
/// wall time for [`RealFileDevice`]. Separating data from timing lets the
/// coordinator account I/O cost precisely in both modes.
pub trait FlashDevice: Send + Sync {
    fn name(&self) -> &str;

    /// Total addressable bytes.
    fn capacity(&self) -> u64;

    /// Whether reported service time is a *virtual* clock (analytical
    /// simulators) rather than measured wall time. A [`DevicePool`]
    /// submits all-virtual-clock members serially — concurrency cannot
    /// change an analytical clock, max-over-members aggregation is exact
    /// either way, and the pooled serving hot path stays
    /// allocation-free (no per-submit thread spawn).
    fn is_virtual_time(&self) -> bool {
        false
    }

    /// Read all extents into `out` (must equal the summed extent length).
    fn read_batch(&self, extents: &[Extent], out: &mut [u8]) -> anyhow::Result<Duration>;

    /// Timing-only read (simulators skip the copy; real devices read into
    /// internal scratch). Used by profiling and I/O-only experiments.
    fn service_time(&self, extents: &[Extent]) -> anyhow::Result<Duration>;

    /// Convenience: allocate and read.
    fn read_batch_vec(&self, extents: &[Extent]) -> anyhow::Result<(Vec<u8>, Duration)> {
        let total: usize = extents.iter().map(|e| e.len).sum();
        let mut out = vec![0u8; total];
        let t = self.read_batch(extents, &mut out)?;
        Ok((out, t))
    }

    /// Submit a planned read. The default implementation shims each
    /// submission batch onto [`FlashDevice::read_batch`], so every backend
    /// (simulated, real-file, profiler probes) supports plans without
    /// further work; native backends may override to drive deeper queues.
    fn submit(&self, plan: &ReadPlan) -> anyhow::Result<PlanReceipt> {
        let mut receipt = PlanReceipt::default();
        self.submit_into(plan, &mut receipt)?;
        Ok(receipt)
    }

    /// Allocation-free [`FlashDevice::submit`]: clears `receipt` and
    /// refills it in place, reusing its buffer capacity. The serving hot
    /// path cycles a pooled receipt through this every token.
    fn submit_into(&self, plan: &ReadPlan, receipt: &mut PlanReceipt) -> anyhow::Result<()> {
        let cmds = plan.cmds();
        receipt.presize_for(cmds);
        let mut cursor = 0usize;
        for &(s, e) in plan.batches() {
            let batch = &cmds[s..e];
            let n: usize = batch.iter().map(|x| x.len).sum();
            receipt.service += self.read_batch(batch, &mut receipt.bytes[cursor..cursor + n])?;
            cursor += n;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extent_end() {
        assert_eq!(Extent::new(100, 28).end(), 128);
    }
}
