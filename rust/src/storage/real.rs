//! Real-file flash backend: thread-pooled positional reads over an actual
//! file, mirroring the paper's 6-thread C++ direct-I/O pool.
//!
//! Notes for honest measurement:
//! * We request `POSIX_FADV_DONTNEED` after reads and `POSIX_FADV_RANDOM`
//!   up front to curb page-cache reuse; true `O_DIRECT` needs aligned
//!   buffers and is enabled when `direct=true` (offsets/lengths must then
//!   be 4 KiB-aligned, which the weight-store layout guarantees when
//!   configured with `align_rows=true`).
//! * Wall-clock service time is returned; on a developer box with a hot
//!   page cache the *absolute* numbers are optimistic, but contiguity
//!   effects (fewer syscalls, kernel readahead) still show.

use std::fs::File;
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::storage::{Extent, FlashDevice};

struct Job {
    extent: Extent,
    /// Destination offset in the shared output buffer.
    dst: usize,
}

/// Thread-pooled positional-read device over a file.
pub struct RealFileDevice {
    file: Arc<File>,
    capacity: u64,
    threads: usize,
    name: String,
    direct: bool,
}

impl RealFileDevice {
    pub fn open(path: &std::path::Path, threads: usize, direct: bool) -> anyhow::Result<Self> {
        use std::os::unix::fs::OpenOptionsExt;
        let mut opts = std::fs::OpenOptions::new();
        opts.read(true);
        if direct {
            opts.custom_flags(libc::O_DIRECT);
        }
        let file = opts.open(path)?;
        let capacity = file.metadata()?.len();
        unsafe {
            libc::posix_fadvise(file.as_raw_fd(), 0, 0, libc::POSIX_FADV_RANDOM);
        }
        Ok(Self {
            file: Arc::new(file),
            capacity,
            threads: threads.max(1),
            name: format!("file:{}", path.display()),
            direct,
        })
    }

    /// Drop this file's pages from the page cache (between trials).
    pub fn drop_cache(&self) {
        unsafe {
            libc::posix_fadvise(self.file.as_raw_fd(), 0, 0, libc::POSIX_FADV_DONTNEED);
        }
    }

    fn pread_into(file: &File, extent: Extent, buf: &mut [u8]) -> anyhow::Result<()> {
        let mut done = 0usize;
        while done < extent.len {
            let rc = unsafe {
                libc::pread(
                    file.as_raw_fd(),
                    buf[done..].as_mut_ptr() as *mut libc::c_void,
                    extent.len - done,
                    (extent.offset as usize + done) as libc::off_t,
                )
            };
            anyhow::ensure!(rc > 0, "pread failed at {:?}: rc={}", extent, rc);
            done += rc as usize;
        }
        Ok(())
    }

    fn run_pool(&self, extents: &[Extent], out: &mut [u8]) -> anyhow::Result<Duration> {
        // Build the job list with destination offsets.
        let mut jobs = Vec::with_capacity(extents.len());
        let mut at = 0usize;
        for &extent in extents {
            anyhow::ensure!(
                extent.end() <= self.capacity,
                "extent {:?} beyond capacity {}",
                extent,
                self.capacity
            );
            jobs.push(Job { extent, dst: at });
            at += extent.len;
        }
        anyhow::ensure!(out.len() == at, "out buffer {} != {}", out.len(), at);

        let nthreads = self.threads.min(jobs.len()).max(1);
        let next = AtomicUsize::new(0);
        let failed = Mutex::new(None::<anyhow::Error>);
        let out_ptr = SendPtr(out.as_mut_ptr());
        let out_len = out.len();
        let start = Instant::now();
        std::thread::scope(|scope| {
            for _ in 0..nthreads {
                let out_ptr = &out_ptr;
                let next = &next;
                let failed = &failed;
                let jobs = &jobs;
                scope.spawn(move || {
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= jobs.len() {
                            return;
                        }
                        let job = &jobs[i];
                        // SAFETY: jobs write to disjoint [dst, dst+len)
                        // ranges of the output buffer.
                        let slice = unsafe {
                            debug_assert!(job.dst + job.extent.len <= out_len);
                            std::slice::from_raw_parts_mut(
                                out_ptr.0.add(job.dst),
                                job.extent.len,
                            )
                        };
                        if let Err(e) = Self::pread_into(&self.file, job.extent, slice) {
                            *failed.lock().unwrap() = Some(e);
                            return;
                        }
                    }
                });
            }
        });
        let elapsed = start.elapsed();
        if let Some(e) = failed.into_inner().unwrap() {
            return Err(e);
        }
        Ok(elapsed)
    }
}

/// Raw pointer wrapper that is Send (disjoint-range writes only).
struct SendPtr(*mut u8);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

impl FlashDevice for RealFileDevice {
    fn name(&self) -> &str {
        &self.name
    }

    fn capacity(&self) -> u64 {
        self.capacity
    }

    fn read_batch(&self, extents: &[Extent], out: &mut [u8]) -> anyhow::Result<Duration> {
        anyhow::ensure!(
            !self.direct || extents.iter().all(|e| e.offset % 4096 == 0 && e.len % 4096 == 0),
            "O_DIRECT requires 4 KiB-aligned extents"
        );
        self.run_pool(extents, out)
    }

    fn service_time(&self, extents: &[Extent]) -> anyhow::Result<Duration> {
        let total: usize = extents.iter().map(|e| e.len).sum();
        let mut scratch = vec![0u8; total];
        let t = self.read_batch(extents, &mut scratch)?;
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmpfile(bytes: &[u8]) -> std::path::PathBuf {
        let path = std::env::temp_dir().join(format!(
            "nc_realdev_test_{}_{}",
            std::process::id(),
            bytes.len()
        ));
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(bytes).unwrap();
        path
    }

    #[test]
    fn reads_correct_bytes() {
        let data: Vec<u8> = (0..4096u32).map(|i| (i % 251) as u8).collect();
        let path = tmpfile(&data);
        let dev = RealFileDevice::open(&path, 4, false).unwrap();
        let extents = [Extent::new(100, 50), Extent::new(2000, 96), Extent::new(0, 10)];
        let (bytes, t) = dev.read_batch_vec(&extents).unwrap();
        assert_eq!(&bytes[..50], &data[100..150]);
        assert_eq!(&bytes[50..146], &data[2000..2096]);
        assert_eq!(&bytes[146..], &data[0..10]);
        assert!(t > Duration::ZERO);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn many_small_extents_parallel() {
        let data = vec![7u8; 1 << 20];
        let path = tmpfile(&data);
        let dev = RealFileDevice::open(&path, 6, false).unwrap();
        let extents: Vec<Extent> = (0..512).map(|i| Extent::new(i * 2048, 1024)).collect();
        let (bytes, _) = dev.read_batch_vec(&extents).unwrap();
        assert_eq!(bytes.len(), 512 * 1024);
        assert!(bytes.iter().all(|&b| b == 7));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn out_of_bounds_rejected() {
        let path = tmpfile(&[0u8; 128]);
        let dev = RealFileDevice::open(&path, 2, false).unwrap();
        assert!(dev.service_time(&[Extent::new(100, 100)]).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn capacity_matches_file() {
        let path = tmpfile(&[1u8; 12345]);
        let dev = RealFileDevice::open(&path, 2, false).unwrap();
        assert_eq!(dev.capacity(), 12345);
        std::fs::remove_file(path).ok();
    }
}
