//! Asynchronous I/O workers: one thread per pool member, fed by bounded
//! submission queues, completing into tickets the engine awaits only at
//! the moment a layer's weights are consumed.
//!
//! This is the wall-clock half of the engine's async pipeline. Members
//! whose service time is a *virtual* clock ([`crate::storage::SimulatedSsd`])
//! never come through here — an analytical clock cannot observe
//! concurrency, so the engine submits them inline and credits overlap
//! analytically (`max(compute, io)` per stage), keeping the latency model
//! exact, deterministic and allocation-free. Pools with wall-clock
//! members ([`crate::storage::RealFileDevice`]) route every sub-plan
//! through these workers instead, so flash reads genuinely proceed while
//! the engine executes kernels.
//!
//! Design:
//! * **Bounded submission queues** — one FIFO per member, capacity =
//!   the engine's I/O queue depth × [`SESSION_SLACK`] (headroom so a
//!   few concurrent sessions, each already bounded to `depth` in-flight
//!   submissions by the engine pipeline window, never block mid-token).
//!   A full queue blocks the submitter — deliberate backpressure.
//! * **Per-member ordering** — a single worker drains each member's
//!   queue in submission order, so one member never reorders commands
//!   relative to the engine's plan sequence.
//! * **Completion tickets** — a submission covering N members returns
//!   one [`IoTicket`]; `wait_scatter` blocks until all N member jobs are
//!   done, scatters their staging bytes into the logical receipt buffer
//!   and reports per-member bytes/service. Workers never touch engine
//!   memory: each job reads into its own pooled staging buffer, so an
//!   abandoned ticket ([`IoTicket::discard`]) is always safe.
//! * **Buffer recycling** — completed job buffers return to a shared
//!   free list, so steady-state submissions reuse capacity instead of
//!   growing fresh vectors per token.
//! * **Hedged tickets** — [`AsyncIoQueue::submit_hedged`] arms each
//!   member job with a deadline from the member's own profiled estimate
//!   and a precomputed replica re-issue plan; a straggling or failing
//!   member's commands are re-read from the other live replicas and the
//!   first completion wins (losers recycle their buffers). Workers also
//!   retry transient read errors and mark persistently-failing members
//!   dead on the shared [`PoolHealth`].

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::plan::{FusedPlan, ShardedPlan};
use crate::storage::{
    DevicePool, Extent, FlashDevice, PoolError, PoolHealth, PoolStats, READ_ATTEMPTS,
};

/// Reusable buffers of one member job (recycled through the free list).
#[derive(Default)]
struct JobBufs {
    /// Device-local commands for this member.
    cmds: Vec<Extent>,
    /// Destination byte offset in the logical receipt per command.
    dsts: Vec<usize>,
    /// Staging buffer the worker reads into.
    staging: Vec<u8>,
}

/// One queued unit of work for one member's worker.
struct Job {
    member: usize,
    bufs: JobBufs,
    ticket: Arc<TicketState>,
    /// Hedged tickets: index of the slot this attempt belongs to
    /// (`None` on plain submissions).
    slot: Option<usize>,
    /// Whether this attempt is a replica re-issue rather than the
    /// original member read.
    hedge: bool,
}

/// Completion state shared between the submitter and the workers.
struct TicketState {
    done: Mutex<TicketDone>,
    cv: Condvar,
}

struct TicketDone {
    /// Slots (member jobs) still unresolved.
    remaining: usize,
    /// Completed jobs: (member, buffers, member service time).
    jobs: Vec<(usize, JobBufs, Duration)>,
    /// First member error, if any (the ticket then fails as a whole).
    error: Option<anyhow::Error>,
    /// Hedged tickets only: per-original-member attempt state (empty on
    /// plain submissions — their fast path is untouched).
    slots: Vec<SlotState>,
}

/// Per-original-member state of a hedged ticket. The waiter
/// ([`IoTicket::wait_done`]) fires hedges; workers resolve slots. A
/// slot resolves when its original read completes, or when every fired
/// hedge part completes (replicas are byte-identical, so either source
/// — or both — writing the receipt is correct).
struct SlotState {
    /// Original member (for error naming).
    member: usize,
    /// Hedge deadline; cleared once the hedge fires.
    deadline: Option<Instant>,
    /// Precomputed replica re-issue: `(target, cmds, dsts)` groups
    /// covering every byte of the original sub-plan. Drained when the
    /// hedge fires; empty = nowhere to hedge to.
    reroutes: Vec<(usize, Vec<Extent>, Vec<usize>)>,
    /// Attempts in flight (original + fired hedge parts).
    outstanding: usize,
    /// Hedge parts fired / completed OK.
    parts: usize,
    parts_done: usize,
    fired: bool,
    resolved: bool,
    /// First error of any attempt (surfaces only if the slot dead-ends).
    err: Option<anyhow::Error>,
}

/// One member's bounded FIFO submission queue.
struct MemberQueue {
    inner: Mutex<VecDeque<Job>>,
    cap: usize,
    not_empty: Condvar,
    not_full: Condvar,
}

/// State shared by the submitter handle and every worker thread.
struct Shared {
    queues: Vec<MemberQueue>,
    /// Recycled job buffers (capacity survives across submissions).
    free: Mutex<Vec<JobBufs>>,
    shutdown: AtomicBool,
    /// Pool health (liveness + fault counters), when attached via
    /// [`AsyncIoQueue::start_with_health`]: workers count retries and
    /// mark persistently-failing members dead; hedged tickets record
    /// hedge / hedge-win counters.
    health: Option<Arc<PoolHealth>>,
}

impl Shared {
    /// Enqueue one job on its member's bounded queue (blocks on
    /// backpressure). Never call while holding a ticket's `done` lock —
    /// workers need that lock to drain the queue.
    fn push(&self, job: Job) {
        let q = &self.queues[job.member];
        let mut inner = q.inner.lock().unwrap();
        while inner.len() >= q.cap {
            inner = q.not_full.wait(inner).unwrap();
        }
        inner.push_back(job);
        q.not_empty.notify_one();
    }
}

/// Completion handle of one sharded submission. One-shot: consume it with
/// [`IoTicket::wait_scatter`] (engine path) or [`IoTicket::discard`]
/// (abandoned submissions, e.g. a session reset mid-pipeline).
pub struct IoTicket {
    state: Arc<TicketState>,
    shared: Arc<Shared>,
}

impl IoTicket {
    fn wait_done(&self) -> std::sync::MutexGuard<'_, TicketDone> {
        let mut done = self.state.done.lock().unwrap();
        if done.slots.is_empty() {
            // Plain ticket: byte-for-byte the original wait.
            while done.remaining > 0 {
                done = self.state.cv.wait(done).unwrap();
            }
            return done;
        }
        // Hedged ticket: the waiter doubles as the hedge trigger —
        // re-issue a straggling or failed member's commands to the other
        // live replicas, and declare a slot failed only once every
        // attempt (original + hedge parts) is spent. Workers resolve
        // slots and recycle loser buffers, so nothing leaks.
        loop {
            // 1) Fire due hedges: deadline missed, or the original
            //    failed with nothing else in flight.
            let now = Instant::now();
            let mut fire: Vec<Job> = Vec::new();
            for s in 0..done.slots.len() {
                let due = {
                    let slot = &done.slots[s];
                    !slot.resolved
                        && !slot.fired
                        && !slot.reroutes.is_empty()
                        && (slot.deadline.is_some_and(|d| d <= now)
                            || (slot.outstanding == 0 && slot.err.is_some()))
                };
                if !due {
                    continue;
                }
                let reroutes = std::mem::take(&mut done.slots[s].reroutes);
                done.slots[s].fired = true;
                done.slots[s].deadline = None;
                done.slots[s].parts = reroutes.len();
                done.slots[s].outstanding += reroutes.len();
                if let Some(h) = &self.shared.health {
                    h.note_hedge();
                }
                for (target, cmds, dsts) in reroutes {
                    if let Some(h) = &self.shared.health {
                        h.add_routed(target, cmds.iter().map(|e| e.len as u64).sum());
                    }
                    let mut bufs = self.shared.free.lock().unwrap().pop().unwrap_or_default();
                    bufs.cmds.clear();
                    bufs.cmds.extend_from_slice(&cmds);
                    bufs.dsts.clear();
                    bufs.dsts.extend_from_slice(&dsts);
                    fire.push(Job {
                        member: target,
                        bufs,
                        ticket: self.state.clone(),
                        slot: Some(s),
                        hedge: true,
                    });
                }
            }
            if !fire.is_empty() {
                // Queue pushes block on backpressure — never while
                // holding the ticket lock (workers need it to complete).
                drop(done);
                for job in fire {
                    self.shared.push(job);
                }
                done = self.state.done.lock().unwrap();
                continue;
            }
            // 2) Declare dead-ended slots failed (every attempt spent,
            //    no hedge left to fire).
            for s in 0..done.slots.len() {
                let dead_end = {
                    let slot = &done.slots[s];
                    !slot.resolved
                        && slot.outstanding == 0
                        && (slot.fired || slot.reroutes.is_empty())
                };
                if dead_end {
                    done.slots[s].resolved = true;
                    done.remaining -= 1;
                    let member = done.slots[s].member;
                    let e = done.slots[s].err.take().unwrap_or_else(|| {
                        anyhow::Error::new(PoolError::MemberFailed { member })
                    });
                    if done.error.is_none() {
                        done.error = Some(e);
                    }
                }
            }
            if done.remaining == 0 {
                return done;
            }
            // 3) Sleep until a completion or the earliest armed deadline.
            let next = done
                .slots
                .iter()
                .filter(|s| !s.resolved && !s.fired && !s.reroutes.is_empty())
                .filter_map(|s| s.deadline)
                .min();
            match next {
                Some(dl) => {
                    let wait = dl.saturating_duration_since(Instant::now());
                    if wait.is_zero() {
                        continue;
                    }
                    let (d, _) = self.state.cv.wait_timeout(done, wait).unwrap();
                    done = d;
                }
                None => done = self.state.cv.wait(done).unwrap(),
            }
        }
    }

    /// Block until every member job completes, scatter each job's staging
    /// bytes into `out` at the recorded destination offsets, accumulate
    /// per-member bytes/service into `stats` (indexed by member; caller
    /// resets), and return the max member service time (the pool's
    /// parallel service, same convention as
    /// [`crate::storage::DevicePool::submit_sharded_into`]).
    pub fn wait_scatter(self, out: &mut [u8], stats: &mut PoolStats) -> anyhow::Result<Duration> {
        let mut done = self.wait_done();
        if let Some(e) = done.error.take() {
            let mut free = self.shared.free.lock().unwrap();
            for (_, bufs, _) in done.jobs.drain(..) {
                free.push(bufs);
            }
            return Err(e);
        }
        let mut max = Duration::ZERO;
        for (m, bufs, service) in done.jobs.drain(..) {
            let mut at = 0usize;
            for (e, &dst) in bufs.cmds.iter().zip(&bufs.dsts) {
                out[dst..dst + e.len].copy_from_slice(&bufs.staging[at..at + e.len]);
                at += e.len;
            }
            if m < stats.bytes.len() {
                stats.bytes[m] += at as u64;
                stats.service[m] += service;
            }
            max = max.max(service);
            // Short critical section per buffer: other sessions' submits
            // pop this free list and must not wait out a whole-layer
            // scatter.
            self.shared.free.lock().unwrap().push(bufs);
        }
        Ok(max)
    }

    /// Fused variant of [`IoTicket::wait_scatter`]: the ticket's
    /// submission was the *union* plan of a [`FusedPlan`], and its bytes
    /// scatter to **N subscriber receipts** at once — each member piece
    /// covers a range of the fused logical receipt, and every subscriber
    /// copy overlapping that range gets its slice written into
    /// `outs[copy.stream]` at the copy's destination offset. Shared
    /// ranges are read once from flash and delivered to every
    /// subscriber; each subscriber's bytes end up bit-identical to a
    /// solo submission of its own plan. Relies on `fused.copies` being
    /// sorted by `src` ([`crate::plan::IoPlanner::fuse_into`] guarantees
    /// this — copies are emitted in flash order) to join pieces and
    /// copies with one forward cursor. Per-member bytes/service land in
    /// `stats` (indexed by member; caller resets); returns the max
    /// member service time.
    pub fn wait_scatter_fused(
        self,
        fused: &FusedPlan,
        outs: &mut [&mut [u8]],
        stats: &mut PoolStats,
    ) -> anyhow::Result<Duration> {
        let mut done = self.wait_done();
        if let Some(e) = done.error.take() {
            let mut free = self.shared.free.lock().unwrap();
            for (_, bufs, _) in done.jobs.drain(..) {
                free.push(bufs);
            }
            return Err(e);
        }
        let mut max = Duration::ZERO;
        for (m, bufs, service) in done.jobs.drain(..) {
            // One member's pieces arrive in ascending fused-receipt
            // order, and `copies` is sorted by `src` (fusion emits it in
            // flash order), so a forward cursor joins the two without
            // rescanning: copies that end before this piece can never
            // match a later piece of the same member.
            let mut from = 0usize;
            let mut at = 0usize;
            for (e, &dst) in bufs.cmds.iter().zip(&bufs.dsts) {
                // This piece holds fused-receipt bytes [dst, dst+len);
                // hand every overlapping subscriber copy its slice.
                let piece = &bufs.staging[at..at + e.len];
                let (p_lo, p_hi) = (dst, dst + e.len);
                while from < fused.copies.len() {
                    let c = &fused.copies[from];
                    if c.src + c.len > p_lo {
                        break;
                    }
                    from += 1;
                }
                for c in &fused.copies[from..] {
                    if c.src >= p_hi {
                        break;
                    }
                    let lo = c.src.max(p_lo);
                    let hi = (c.src + c.len).min(p_hi);
                    if lo < hi {
                        outs[c.stream][c.dst + (lo - c.src)..c.dst + (hi - c.src)]
                            .copy_from_slice(&piece[lo - p_lo..hi - p_lo]);
                    }
                }
                at += e.len;
            }
            if m < stats.bytes.len() {
                stats.bytes[m] += at as u64;
                stats.service[m] += service;
            }
            max = max.max(service);
            self.shared.free.lock().unwrap().push(bufs);
        }
        Ok(max)
    }

    /// Block until every member job completes and drop the data (used
    /// when a submission is abandoned before its layer is reached).
    pub fn discard(self) {
        let mut done = self.wait_done();
        done.error.take();
        let mut free = self.shared.free.lock().unwrap();
        for (_, bufs, _) in done.jobs.drain(..) {
            free.push(bufs);
        }
    }
}

/// Per-member asynchronous I/O workers behind bounded submission queues.
/// Dropping the queue shuts the workers down after they drain any jobs
/// already queued (outstanding tickets still complete).
pub struct AsyncIoQueue {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    depth: usize,
}

/// Queue headroom multiplier: each member queue holds
/// `depth × SESSION_SLACK` jobs, so up to `SESSION_SLACK` concurrent
/// sessions (each bounded to `depth` in-flight submissions by the engine
/// pipeline window) never block in [`AsyncIoQueue::submit`] mid-token.
/// Beyond that, a full queue is deliberate backpressure.
const SESSION_SLACK: usize = 4;

impl AsyncIoQueue {
    /// Spawn one worker per member. `depth` is the per-session in-flight
    /// bound; each member's queue holds `depth × SESSION_SLACK` jobs
    /// (submissions beyond it block the submitter).
    pub fn start(members: Vec<Arc<dyn FlashDevice>>, depth: usize) -> Self {
        Self::start_with_health(members, depth, None)
    }

    /// [`AsyncIoQueue::start`] with a shared [`PoolHealth`] attached:
    /// workers count retries and mark persistently-failing members dead,
    /// and hedged tickets ([`AsyncIoQueue::submit_hedged`]) record the
    /// hedge / hedge-win counters. Pass the owning pool's
    /// [`DevicePool::health`] so inline and async paths share one view
    /// of member liveness.
    pub fn start_with_health(
        members: Vec<Arc<dyn FlashDevice>>,
        depth: usize,
        health: Option<Arc<PoolHealth>>,
    ) -> Self {
        let depth = depth.max(1);
        let cap = depth * SESSION_SLACK;
        let shared = Arc::new(Shared {
            queues: members
                .iter()
                .map(|_| MemberQueue {
                    inner: Mutex::new(VecDeque::with_capacity(cap)),
                    cap,
                    not_empty: Condvar::new(),
                    not_full: Condvar::new(),
                })
                .collect(),
            free: Mutex::new(Vec::new()),
            shutdown: AtomicBool::new(false),
            health,
        });
        let workers = members
            .into_iter()
            .enumerate()
            .map(|(m, member)| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("nc-io-{m}"))
                    .spawn(move || worker_loop(shared, member, m))
                    .expect("spawn async I/O worker")
            })
            .collect();
        Self {
            shared,
            workers,
            depth,
        }
    }

    /// The configured per-session in-flight bound (each member's queue
    /// actually holds `depth × SESSION_SLACK` jobs).
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Number of member workers.
    pub fn members(&self) -> usize {
        self.shared.queues.len()
    }

    /// Submit one sharded plan: each member with a non-empty sub-plan
    /// gets one job (copied out of `sharded`, so the caller's scratch is
    /// free for reuse immediately). Returns the completion ticket.
    /// Blocks only when a member's queue is at capacity.
    pub fn submit(&self, sharded: &ShardedPlan) -> IoTicket {
        let n_jobs = sharded.shards.iter().filter(|s| !s.is_empty()).count();
        let state = Arc::new(TicketState {
            done: Mutex::new(TicketDone {
                remaining: n_jobs,
                jobs: Vec::with_capacity(n_jobs),
                error: None,
                slots: Vec::new(),
            }),
            cv: Condvar::new(),
        });
        for (m, shard) in sharded.shards.iter().enumerate() {
            if shard.is_empty() {
                continue;
            }
            let mut bufs = self.shared.free.lock().unwrap().pop().unwrap_or_default();
            bufs.cmds.clear();
            bufs.cmds.extend_from_slice(&shard.cmds);
            bufs.dsts.clear();
            bufs.dsts.extend_from_slice(&shard.dsts);
            self.shared.push(Job {
                member: m,
                bufs,
                ticket: state.clone(),
                slot: None,
                hedge: false,
            });
        }
        IoTicket {
            state,
            shared: self.shared.clone(),
        }
    }

    /// Hedged submission over a *routed* sharded plan: like
    /// [`AsyncIoQueue::submit`], but each member job carries a hedge
    /// deadline derived from that member's own profiled estimate
    /// ([`DevicePool::hedge_budget`]) plus a precomputed replica
    /// re-issue plan ([`DevicePool::reroute_shard`]). The ticket's
    /// waiter doubles as the hedge trigger: a member that misses its
    /// deadline — or fails outright — gets its commands re-issued to
    /// the other live replicas, and whichever source completes first
    /// resolves the member (replicas are byte-identical, so both
    /// completing is harmless; loser buffers recycle through the free
    /// list, never leak). Falls back to a plain submission when the
    /// pool cannot hedge: no replication, hedging disabled, or an
    /// unrouted plan (no flat offsets to re-map).
    pub fn submit_hedged(&self, sharded: &ShardedPlan, pool: &DevicePool) -> IoTicket {
        if pool.stripe().replication() <= 1
            || !pool.hedge_config().enabled()
            || sharded.shards.iter().any(|s| s.flats.len() != s.cmds.len())
        {
            return self.submit(sharded);
        }
        let now = Instant::now();
        let mut slots = Vec::new();
        let mut jobs = Vec::new();
        for (m, shard) in sharded.shards.iter().enumerate() {
            if shard.is_empty() {
                continue;
            }
            let slot = slots.len();
            slots.push(SlotState {
                member: m,
                deadline: Some(now + pool.hedge_budget(m, shard)),
                reroutes: pool.reroute_shard(shard, m).unwrap_or_default(),
                outstanding: 1,
                parts: 0,
                parts_done: 0,
                fired: false,
                resolved: false,
                err: None,
            });
            let mut bufs = self.shared.free.lock().unwrap().pop().unwrap_or_default();
            bufs.cmds.clear();
            bufs.cmds.extend_from_slice(&shard.cmds);
            bufs.dsts.clear();
            bufs.dsts.extend_from_slice(&shard.dsts);
            jobs.push((m, bufs, slot));
        }
        let state = Arc::new(TicketState {
            done: Mutex::new(TicketDone {
                remaining: slots.len(),
                jobs: Vec::with_capacity(slots.len()),
                error: None,
                slots,
            }),
            cv: Condvar::new(),
        });
        for (m, bufs, slot) in jobs {
            self.shared.push(Job {
                member: m,
                bufs,
                ticket: state.clone(),
                slot: Some(slot),
                hedge: false,
            });
        }
        IoTicket {
            state,
            shared: self.shared.clone(),
        }
    }
}

impl Drop for AsyncIoQueue {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        for q in &self.shared.queues {
            // Wake idle workers so they observe the shutdown flag.
            let _guard = q.inner.lock().unwrap();
            q.not_empty.notify_all();
        }
        for w in self.workers.drain(..) {
            w.join().expect("async I/O worker panicked");
        }
    }
}

/// Worker body: drain the member queue in FIFO order; on shutdown, finish
/// anything already queued, then exit.
fn worker_loop(shared: Arc<Shared>, member: Arc<dyn FlashDevice>, m: usize) {
    loop {
        let job = {
            let q = &shared.queues[m];
            let mut inner = q.inner.lock().unwrap();
            loop {
                if let Some(j) = inner.pop_front() {
                    q.not_full.notify_one();
                    break Some(j);
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    break None;
                }
                inner = q.not_empty.wait(inner).unwrap();
            }
        };
        let Some(mut job) = job else {
            return;
        };
        let total: usize = job.bufs.cmds.iter().map(|e| e.len).sum();
        job.bufs.staging.clear();
        job.bufs.staging.resize(total, 0);
        let result = read_with_retries(
            member.as_ref(),
            shared.health.as_deref(),
            m,
            &job.bufs.cmds,
            &mut job.bufs.staging,
        );
        let mut done = job.ticket.done.lock().unwrap();
        match job.slot {
            None => {
                // Plain ticket: first error wins, notify on completion.
                match result {
                    Ok(service) => done.jobs.push((job.member, job.bufs, service)),
                    Err(e) => {
                        if done.error.is_none() {
                            done.error = Some(e);
                        }
                        shared.free.lock().unwrap().push(job.bufs);
                    }
                }
                done.remaining -= 1;
                if done.remaining == 0 {
                    job.ticket.cv.notify_all();
                }
            }
            Some(s) => {
                // Hedged ticket: resolve the slot on first success
                // (original, or the last hedge part); errors park in the
                // slot for the waiter to judge (it may still hedge).
                done.slots[s].outstanding -= 1;
                match result {
                    Ok(service) => {
                        if done.slots[s].resolved {
                            // Loser of a resolved race: recycle.
                            shared.free.lock().unwrap().push(job.bufs);
                        } else {
                            let win = if job.hedge {
                                done.slots[s].parts_done += 1;
                                done.slots[s].parts_done == done.slots[s].parts
                            } else {
                                true
                            };
                            done.jobs.push((job.member, job.bufs, service));
                            if win {
                                done.slots[s].resolved = true;
                                done.remaining -= 1;
                                if job.hedge {
                                    if let Some(h) = &shared.health {
                                        h.note_hedge_win();
                                    }
                                }
                            }
                        }
                    }
                    Err(e) => {
                        if done.slots[s].err.is_none() {
                            done.slots[s].err = Some(e);
                        }
                        shared.free.lock().unwrap().push(job.bufs);
                    }
                }
                // The waiter also reacts to errors and straggler
                // deadlines, so every hedged completion wakes it.
                job.ticket.cv.notify_all();
            }
        }
    }
}

/// One member read with [`READ_ATTEMPTS`] attempts. Transient failures
/// retry in place (counted on `health` when attached); persistent
/// failure marks the member dead and surfaces a typed
/// [`PoolError::MemberFailed`] naming the member.
fn read_with_retries(
    member: &dyn FlashDevice,
    health: Option<&PoolHealth>,
    m: usize,
    cmds: &[Extent],
    out: &mut [u8],
) -> anyhow::Result<Duration> {
    let mut last: Option<anyhow::Error> = None;
    for attempt in 0..READ_ATTEMPTS {
        match member.read_batch(cmds, out) {
            Ok(d) => return Ok(d),
            Err(e) => {
                if attempt + 1 < READ_ATTEMPTS {
                    if let Some(h) = health {
                        h.note_retry();
                    }
                }
                last = Some(e);
            }
        }
    }
    if let Some(h) = health {
        h.mark_dead(m);
    }
    Err(last.unwrap().context(PoolError::MemberFailed { member: m }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::DeviceSubPlan;
    use crate::storage::{DeviceProfile, SimulatedSsd};

    fn members_with_images(images: Vec<Vec<u8>>) -> Vec<Arc<dyn FlashDevice>> {
        images
            .into_iter()
            .enumerate()
            .map(|(m, img)| {
                Arc::new(SimulatedSsd::with_image(
                    DeviceProfile::nano(),
                    img,
                    m as u64,
                )) as Arc<dyn FlashDevice>
            })
            .collect()
    }

    fn sharded(pieces: &[(usize, Extent, usize)], members: usize) -> ShardedPlan {
        let mut sp = ShardedPlan::default();
        sp.shards = (0..members).map(|_| DeviceSubPlan::default()).collect();
        for &(m, e, dst) in pieces {
            sp.shards[m].cmds.push(e);
            sp.shards[m].dsts.push(dst);
        }
        sp
    }

    #[test]
    fn scatter_reassembles_member_reads() {
        let img0: Vec<u8> = (0..=255u8).cycle().take(4096).collect();
        let img1: Vec<u8> = (0..=255u8).rev().cycle().take(4096).collect();
        let queue = AsyncIoQueue::start(members_with_images(vec![img0.clone(), img1.clone()]), 2);
        assert_eq!(queue.members(), 2);
        assert_eq!(queue.depth(), 2);
        // Interleaved destinations: member 0 fills [0, 8) and [16, 24),
        // member 1 fills [8, 16).
        let sp = sharded(
            &[
                (0, Extent::new(100, 8), 0),
                (1, Extent::new(200, 8), 8),
                (0, Extent::new(300, 8), 16),
            ],
            2,
        );
        let ticket = queue.submit(&sp);
        let mut out = vec![0u8; 24];
        let mut stats = PoolStats::default();
        stats.reset(2);
        let max = ticket.wait_scatter(&mut out, &mut stats).unwrap();
        assert_eq!(&out[0..8], &img0[100..108]);
        assert_eq!(&out[8..16], &img1[200..208]);
        assert_eq!(&out[16..24], &img0[300..308]);
        assert_eq!(stats.bytes, vec![16, 8]);
        assert!(max >= stats.service[0].min(stats.service[1]));
        assert_eq!(max, stats.max_service());
    }

    #[test]
    fn fused_ticket_scatters_to_n_receipts() {
        use crate::plan::{FusedCopy, FusedPlan};
        let img0: Vec<u8> = (0..=255u8).cycle().take(1024).collect();
        let img1: Vec<u8> = (0..=255u8).rev().cycle().take(1024).collect();
        let queue = AsyncIoQueue::start(members_with_images(vec![img0.clone(), img1.clone()]), 2);
        // Fused logical receipt: [0, 16) from member 0, [16, 24) from
        // member 1. Stream 0 subscribes to [0, 16); stream 1 subscribes
        // to [8, 24) — the shared range [8, 16) is read once.
        let sp = sharded(
            &[(0, Extent::new(100, 16), 0), (1, Extent::new(50, 8), 16)],
            2,
        );
        let fused = FusedPlan {
            copies: vec![
                FusedCopy {
                    stream: 0,
                    src: 0,
                    dst: 0,
                    len: 16,
                },
                FusedCopy {
                    stream: 1,
                    src: 8,
                    dst: 0,
                    len: 16,
                },
            ],
            streams: 2,
            solo_bytes: 32,
            ..FusedPlan::default()
        };
        let ticket = queue.submit(&sp);
        let mut out0 = vec![0u8; 16];
        let mut out1 = vec![0u8; 16];
        let mut stats = PoolStats::default();
        stats.reset(2);
        let mut outs: [&mut [u8]; 2] = [&mut out0, &mut out1];
        let max = ticket
            .wait_scatter_fused(&fused, &mut outs, &mut stats)
            .unwrap();
        assert_eq!(&out0[..], &img0[100..116]);
        assert_eq!(&out1[..8], &img0[108..116]);
        assert_eq!(&out1[8..], &img1[50..58]);
        assert_eq!(stats.bytes, vec![16, 8]);
        assert_eq!(max, stats.max_service());
    }

    #[test]
    fn member_errors_fail_the_ticket() {
        let queue = AsyncIoQueue::start(members_with_images(vec![vec![1u8; 64]]), 1);
        // Extent beyond the member's 64-byte capacity.
        let sp = sharded(&[(0, Extent::new(32, 64), 0)], 1);
        let ticket = queue.submit(&sp);
        let mut out = vec![0u8; 64];
        let mut stats = PoolStats::default();
        stats.reset(1);
        assert!(ticket.wait_scatter(&mut out, &mut stats).is_err());
    }

    #[test]
    fn discard_and_shutdown_are_clean() {
        let queue = AsyncIoQueue::start(members_with_images(vec![vec![9u8; 1024]; 3]), 1);
        for _ in 0..4 {
            let sp = sharded(
                &[(0, Extent::new(0, 16), 0), (2, Extent::new(16, 16), 16)],
                3,
            );
            queue.submit(&sp).discard();
        }
        // Buffers were recycled through the free list.
        assert!(!queue.shared.free.lock().unwrap().is_empty());
        drop(queue); // joins workers without deadlock
    }

    #[test]
    fn hedged_ticket_fails_over_to_replica() {
        use crate::model::{ModelSpec, WeightStore};
        use crate::storage::{
            DeviceProfile, FaultConfig, FaultInjector, HedgeConfig, StripeLayout, StripePolicy,
        };
        let store = WeightStore::new(ModelSpec::tiny(), false, 42);
        let image = store.build_image();
        let stripe =
            StripeLayout::build_replicated(&store.layout, 2, StripePolicy::RoundRobin, None, 2);
        let mut pool =
            DevicePool::simulated(&vec![DeviceProfile::nano(); 2], stripe, &image, 7).unwrap();
        // Member 0 is dead: its original job burns its retries and the
        // waiter must hedge the whole sub-plan onto the replica.
        pool.wrap_members(|m, d| {
            if m == 0 {
                Arc::new(FaultInjector::new(
                    d,
                    FaultConfig { dead: true, ..Default::default() },
                )) as Arc<dyn FlashDevice>
            } else {
                d
            }
        });
        let pool = pool.with_hedge(HedgeConfig::default());
        // A replicated (hot) extent: find one via the stripe map, then
        // route it so the sub-plan carries flat offsets.
        let mut hot = None;
        pool.stripe()
            .for_pieces_all(Extent::new(0, image.len()), |flat, options| {
                if options.len() == 2 && options[0].0 == 0 && hot.is_none() {
                    hot = Some(Extent::new(flat, options[0].1.len));
                }
            });
        let hot = hot.expect("replicated stripe has hot pieces on member 0");
        // Force the sub-plan onto the dead member (primary holder), with
        // flat offsets so the waiter can re-map it onto the replica.
        let mut forced = ShardedPlan::default();
        forced.shards = vec![DeviceSubPlan::default(), DeviceSubPlan::default()];
        pool.stripe().for_pieces_all(hot, |flat, options| {
            let (m0, l0) = options[0];
            assert_eq!(m0, 0);
            forced.shards[0].push_piece_routed(l0, (flat - hot.offset) as usize, flat);
        });
        let queue =
            AsyncIoQueue::start_with_health(pool.member_arcs(), 2, Some(pool.health()));
        let ticket = queue.submit_hedged(&forced, &pool);
        let mut out = vec![0u8; hot.len];
        let mut stats = PoolStats::default();
        stats.reset(2);
        ticket.wait_scatter(&mut out, &mut stats).unwrap();
        assert_eq!(
            out.as_slice(),
            &image[hot.offset as usize..hot.end() as usize],
            "hedged bytes must match the flat image"
        );
        let h = pool.health().snapshot();
        assert!(h.hedges >= 1, "hedge must fire");
        assert!(h.hedge_wins >= 1, "replica must win");
        assert!(h.retries >= 1, "dead member burns retries first");
        assert!(h.dead_members.contains(&0), "member 0 marked dead");
        // An uncoverable slot (both replicas dead) fails cleanly with a
        // typed error instead of hanging.
        pool.health().mark_dead(1);
        let ticket = queue.submit_hedged(&forced, &pool);
        let mut out = vec![0u8; hot.len];
        let err = ticket.wait_scatter(&mut out, &mut stats).unwrap_err();
        assert!(err.downcast_ref::<PoolError>().is_some(), "typed pool error: {err:#}");
    }

    #[test]
    fn submission_order_is_preserved_per_member() {
        // One member, queue depth 4: jobs complete in submission order, so
        // sequential tickets observe their own data (no cross-talk).
        let img: Vec<u8> = (0..=255u8).cycle().take(2048).collect();
        let queue = AsyncIoQueue::start(members_with_images(vec![img.clone()]), 4);
        let tickets: Vec<IoTicket> = (0..4usize)
            .map(|i| queue.submit(&sharded(&[(0, Extent::new(i as u64 * 97, 32), 0)], 1)))
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            let mut out = vec![0u8; 32];
            let mut stats = PoolStats::default();
            stats.reset(1);
            t.wait_scatter(&mut out, &mut stats).unwrap();
            let off = i * 97;
            assert_eq!(out.as_slice(), &img[off..off + 32], "ticket {i}");
        }
    }
}
