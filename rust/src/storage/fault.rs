//! Deterministic fault injection for storage-pool members.
//!
//! [`FaultInjector`] wraps any [`FlashDevice`] and misbehaves on
//! command: seeded transient read errors, wall-clock latency spikes
//! (stragglers), and full-member death. Every fault-tolerance behavior
//! in the pool — retries, hedged reads, failover, degraded-mode
//! serving — is exercised through this wrapper, either probabilistically
//! (chaos CI via `NC_FAULT_*` env) or deterministically through a
//! [`FaultHandle`] (`set_dead`, `fail_next`) so tests can aim a fault at
//! an exact read.
//!
//! Latency spikes are *wall-clock sleeps only*: a spiked member stalls
//! the calling thread but never alters the virtual service time it
//! reports, so analytic latency-model assertions stay exact while
//! hedging sees a genuine straggler.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::plan::{PlanReceipt, ReadPlan};
use crate::rng::Rng;
use crate::storage::{Extent, FlashDevice};

/// What and how often to inject. All rates are per read operation.
#[derive(Clone, Debug)]
pub struct FaultConfig {
    /// Probability a read fails with a transient I/O error.
    pub err_rate: f64,
    /// Probability a read stalls for [`FaultConfig::spike`].
    pub spike_rate: f64,
    /// Wall-clock stall injected on a spiked read.
    pub spike: Duration,
    /// Member is dead: every read fails, forever.
    pub dead: bool,
    /// Seed for the injector's private RNG (deterministic sequences).
    pub seed: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self {
            err_rate: 0.0,
            spike_rate: 0.0,
            spike: Duration::from_micros(2000),
            dead: false,
            seed: 0xFA11,
        }
    }
}

impl FaultConfig {
    /// Chaos-mode config from the environment:
    /// `NC_FAULT_ERR_RATE` (transient error probability),
    /// `NC_FAULT_SPIKE` (spike probability),
    /// `NC_FAULT_SPIKE_US` (spike length, default 2000µs),
    /// `NC_FAULT_DEAD` (member index to kill — the caller compares).
    /// Returns `None` when no fault knob is set.
    pub fn from_env() -> Option<Self> {
        let parse = |k: &str| std::env::var(k).ok().and_then(|v| v.parse::<f64>().ok());
        let err_rate = parse("NC_FAULT_ERR_RATE");
        let spike_rate = parse("NC_FAULT_SPIKE");
        let dead_member = dead_member_from_env();
        if err_rate.is_none() && spike_rate.is_none() && dead_member.is_none() {
            return None;
        }
        let spike_us = parse("NC_FAULT_SPIKE_US").unwrap_or(2000.0).max(0.0);
        Some(Self {
            err_rate: err_rate.unwrap_or(0.0).clamp(0.0, 1.0),
            spike_rate: spike_rate.unwrap_or(0.0).clamp(0.0, 1.0),
            spike: Duration::from_micros(spike_us as u64),
            dead: false,
            seed: 0xFA11,
        })
    }
}

/// `NC_FAULT_DEAD`: index of the member to kill at build time.
pub(crate) fn dead_member_from_env() -> Option<usize> {
    std::env::var("NC_FAULT_DEAD").ok().and_then(|v| v.parse::<usize>().ok())
}

/// Shared control surface of a [`FaultInjector`]: tests flip faults on
/// and off mid-run without rebuilding the pool.
#[derive(Clone, Debug, Default)]
pub struct FaultHandle {
    dead: Arc<AtomicBool>,
    /// Fail exactly the next `n` read operations (then behave normally).
    fail_budget: Arc<AtomicU64>,
    /// Total reads the injector has seen (observability for tests).
    reads: Arc<AtomicU64>,
}

impl FaultHandle {
    /// Kill (or revive) the member: while dead every read errors.
    pub fn set_dead(&self, dead: bool) {
        self.dead.store(dead, Ordering::SeqCst);
    }

    pub fn is_dead(&self) -> bool {
        self.dead.load(Ordering::SeqCst)
    }

    /// Fail exactly the next `n` read operations with a transient error.
    pub fn fail_next(&self, n: u64) {
        self.fail_budget.store(n, Ordering::SeqCst);
    }

    /// Reads observed so far.
    pub fn reads(&self) -> u64 {
        self.reads.load(Ordering::SeqCst)
    }
}

/// A [`FlashDevice`] decorator that injects faults per [`FaultConfig`]
/// and [`FaultHandle`] before delegating to the wrapped device.
pub struct FaultInjector {
    inner: Arc<dyn FlashDevice>,
    cfg: FaultConfig,
    handle: FaultHandle,
    rng: Mutex<Rng>,
}

impl FaultInjector {
    pub fn new(inner: Arc<dyn FlashDevice>, cfg: FaultConfig) -> Self {
        let handle = FaultHandle::default();
        handle.set_dead(cfg.dead);
        let rng = Mutex::new(Rng::new(cfg.seed));
        Self { inner, cfg, handle, rng }
    }

    /// The shared control handle (clone it before moving the injector
    /// into a pool).
    pub fn handle(&self) -> FaultHandle {
        self.handle.clone()
    }

    /// Decide the fate of one read operation; sleeps through a spike
    /// in-line. `Err` means the read must fail without touching the
    /// wrapped device.
    fn gate(&self) -> anyhow::Result<()> {
        self.handle.reads.fetch_add(1, Ordering::Relaxed);
        if self.handle.is_dead() {
            anyhow::bail!("injected fault: member {} is dead", self.inner.name());
        }
        // Deterministic targeting first: a primed budget fails the next
        // N reads regardless of rates.
        let mut budget = self.handle.fail_budget.load(Ordering::SeqCst);
        while budget > 0 {
            match self.handle.fail_budget.compare_exchange(
                budget,
                budget - 1,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => anyhow::bail!(
                    "injected fault: transient read error on {}",
                    self.inner.name()
                ),
                Err(b) => budget = b,
            }
        }
        let (err, spike) = {
            let mut rng = self.rng.lock().unwrap();
            (
                self.cfg.err_rate > 0.0 && rng.bool(self.cfg.err_rate),
                self.cfg.spike_rate > 0.0 && rng.bool(self.cfg.spike_rate),
            )
        };
        if spike && !self.cfg.spike.is_zero() {
            std::thread::sleep(self.cfg.spike);
        }
        if err {
            anyhow::bail!("injected fault: transient read error on {}", self.inner.name());
        }
        Ok(())
    }
}

impl FlashDevice for FaultInjector {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn capacity(&self) -> u64 {
        self.inner.capacity()
    }

    fn is_virtual_time(&self) -> bool {
        self.inner.is_virtual_time()
    }

    fn read_batch(&self, extents: &[Extent], out: &mut [u8]) -> anyhow::Result<Duration> {
        self.gate()?;
        self.inner.read_batch(extents, out)
    }

    fn service_time(&self, extents: &[Extent]) -> anyhow::Result<Duration> {
        self.gate()?;
        self.inner.service_time(extents)
    }

    fn submit_into(&self, plan: &ReadPlan, receipt: &mut PlanReceipt) -> anyhow::Result<()> {
        // One gate per submission batch (mirrors the default shim's
        // read_batch granularity) would double-charge `read_batch`'s own
        // gate; delegate so each underlying read is gated exactly once.
        let cmds = plan.cmds();
        receipt.presize_for(cmds);
        let mut cursor = 0usize;
        for &(s, e) in plan.batches() {
            let batch = &cmds[s..e];
            let n: usize = batch.iter().map(|x| x.len).sum();
            receipt.service += self.read_batch(batch, &mut receipt.bytes[cursor..cursor + n])?;
            cursor += n;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{DeviceProfile, SimulatedSsd};

    fn device() -> Arc<dyn FlashDevice> {
        Arc::new(SimulatedSsd::with_image(
            DeviceProfile::nano(),
            vec![7u8; 4096],
            11,
        ))
    }

    #[test]
    fn clean_injector_is_transparent() {
        let inner = device();
        let fi = FaultInjector::new(inner.clone(), FaultConfig::default());
        let e = [Extent::new(0, 64)];
        let (got, _) = fi.read_batch_vec(&e).unwrap();
        let (want, _) = inner.read_batch_vec(&e).unwrap();
        assert_eq!(got, want);
        assert_eq!(fi.handle().reads(), 1);
    }

    #[test]
    fn dead_member_always_errors() {
        let fi = FaultInjector::new(device(), FaultConfig { dead: true, ..Default::default() });
        assert!(fi.read_batch_vec(&[Extent::new(0, 8)]).is_err());
        fi.handle().set_dead(false);
        assert!(fi.read_batch_vec(&[Extent::new(0, 8)]).is_ok());
    }

    #[test]
    fn fail_next_fails_exactly_n_reads() {
        let fi = FaultInjector::new(device(), FaultConfig::default());
        let h = fi.handle();
        h.fail_next(2);
        let e = [Extent::new(0, 8)];
        assert!(fi.read_batch_vec(&e).is_err());
        assert!(fi.read_batch_vec(&e).is_err());
        assert!(fi.read_batch_vec(&e).is_ok());
    }

    #[test]
    fn err_rate_is_deterministic_per_seed() {
        let run = || {
            let fi = FaultInjector::new(
                device(),
                FaultConfig { err_rate: 0.5, seed: 99, ..Default::default() },
            );
            (0..32)
                .map(|_| fi.read_batch_vec(&[Extent::new(0, 8)]).is_ok())
                .collect::<Vec<_>>()
        };
        let a = run();
        assert_eq!(a, run());
        assert!(a.iter().any(|&ok| ok) && a.iter().any(|&ok| !ok));
    }
}
