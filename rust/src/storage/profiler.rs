//! Appendix-D profiling microbenchmark: build the `T[s]` lookup table.
//!
//! For each chunk size `s` (1 KB increments up to the saturation point) we
//! place a throughput-saturating number of chunks at fixed strides, read
//! them repeatedly, and record steady-state per-chunk latency. Fixed
//! overheads (command setup, metadata) amortize out, yielding stable
//! per-size entries (paper: std-dev < 1% of mean).

use crate::latency::LatencyTable;
use crate::storage::{Extent, FlashDevice};

/// Configuration of the profiling sweep.
#[derive(Clone, Debug)]
pub struct ProfileConfig {
    /// Granularity of profiled sizes (paper: 1 KB).
    pub step_bytes: usize,
    /// Largest profiled size (the device's saturation point).
    pub max_bytes: usize,
    /// Chunks per batch (throughput-saturating; Fig 3 shows small counts
    /// suffice).
    pub batch_chunks: usize,
    /// Trials per size; the median is recorded.
    pub trials: usize,
    /// Stride multiplier between chunk starts (>= 1.0 leaves gaps).
    pub stride_factor: f64,
    /// Row size the resulting table is keyed for.
    pub row_bytes: usize,
}

impl Default for ProfileConfig {
    fn default() -> Self {
        Self {
            step_bytes: 1024,
            max_bytes: 384 * 1024,
            batch_chunks: 64,
            trials: 3,
            stride_factor: 2.0,
            row_bytes: 1024,
        }
    }
}

impl ProfileConfig {
    /// Fast coarse profile (bench/e2e defaults): 4 KB steps.
    pub fn coarse(max_bytes: usize, row_bytes: usize) -> Self {
        Self {
            step_bytes: 4096,
            max_bytes,
            batch_chunks: 48,
            trials: 3,
            stride_factor: 2.0,
            row_bytes,
        }
    }
}

/// Builds [`LatencyTable`]s by microbenchmarking a [`FlashDevice`].
pub struct Profiler<'a> {
    device: &'a dyn FlashDevice,
    config: ProfileConfig,
}

impl<'a> Profiler<'a> {
    pub fn new(device: &'a dyn FlashDevice, config: ProfileConfig) -> Self {
        Self { device, config }
    }

    /// Run the sweep and build the lookup table.
    pub fn build_table(&self) -> anyhow::Result<LatencyTable> {
        let c = &self.config;
        anyhow::ensure!(c.step_bytes > 0 && c.max_bytes >= c.step_bytes);
        let nsizes = c.max_bytes / c.step_bytes;
        let mut entries = Vec::with_capacity(nsizes);
        for i in 1..=nsizes {
            let size = i * c.step_bytes;
            entries.push(self.profile_size(size)?);
        }
        // Per-chunk latency is physically non-decreasing in chunk size;
        // enforce monotonicity to strip residual measurement jitter
        // (running max = isotonic fit for a non-decreasing truth).
        let mut run = 0.0f64;
        for e in entries.iter_mut() {
            run = run.max(*e);
            *e = run;
        }
        Ok(LatencyTable::new(c.step_bytes, entries, c.row_bytes))
    }

    /// Steady-state per-chunk latency for one size (median over trials).
    pub fn profile_size(&self, size: usize) -> anyhow::Result<f64> {
        let c = &self.config;
        let stride = ((size as f64 * c.stride_factor) as u64).max(size as u64);
        let span = stride * c.batch_chunks as u64;
        anyhow::ensure!(
            span <= self.device.capacity(),
            "profiling span {span} exceeds device capacity {} (size {size})",
            self.device.capacity()
        );
        let extents: Vec<Extent> = (0..c.batch_chunks)
            .map(|j| Extent::new(j as u64 * stride, size))
            .collect();
        let mut per_chunk: Vec<f64> = Vec::with_capacity(c.trials);
        for _ in 0..c.trials {
            let t = self.device.service_time(&extents)?;
            per_chunk.push(t.as_secs_f64() / c.batch_chunks as f64);
        }
        Ok(crate::stats::median(&per_chunk))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{DeviceProfile, SimulatedSsd};

    fn profiled_table(profile: DeviceProfile) -> LatencyTable {
        let dev = SimulatedSsd::timing_only(profile, 1 << 32, 11);
        let cfg = ProfileConfig {
            step_bytes: 4096,
            max_bytes: 384 * 1024,
            batch_chunks: 64,
            trials: 3,
            stride_factor: 2.0,
            row_bytes: 1024,
        };
        Profiler::new(&dev, cfg).build_table().unwrap()
    }

    #[test]
    fn table_monotone_in_size() {
        let t = profiled_table(DeviceProfile::agx());
        let mut prev = 0.0;
        for kb in (4..=384).step_by(4) {
            let l = t.latency_bytes(kb * 1024);
            assert!(l >= prev * 0.98, "latency dropped at {kb} KB");
            prev = l;
        }
    }

    #[test]
    fn profiled_throughput_matches_analytical_knee() {
        // The profiled table must reproduce the profile's saturation point
        // (within coarse-step tolerance).
        let profile = DeviceProfile::agx();
        let t = profiled_table(profile.clone());
        let sat = t.saturation_bytes(0.99);
        let expect = profile.saturation_bytes(0.99);
        let rel = (sat as f64 - expect as f64).abs() / expect as f64;
        assert!(rel < 0.15, "profiled sat {sat} vs analytical {expect}");
    }

    #[test]
    fn small_chunks_dominated_by_iops_floor() {
        let profile = DeviceProfile::nano();
        let floor = 1.0 / profile.iops_ceiling;
        let t = profiled_table(profile);
        // 4 KB per-chunk latency should be close to the IOPS floor.
        let l = t.latency_bytes(4096);
        assert!(l >= floor * 0.9, "l={l} floor={floor}");
        assert!(l <= floor * 2.0, "l={l} floor={floor}");
    }

    #[test]
    fn stable_across_trials() {
        // Paper: variance < 1% of mean. With jitter_cv=2-4% and median of
        // trials, repeat profiles must agree tightly.
        let a = profiled_table(DeviceProfile::agx());
        let b = profiled_table(DeviceProfile::agx());
        for kb in [4usize, 64, 256] {
            let (la, lb) = (a.latency_bytes(kb * 1024), b.latency_bytes(kb * 1024));
            assert!((la - lb).abs() / la < 0.05, "{kb} KB: {la} vs {lb}");
        }
    }

    #[test]
    fn span_guard() {
        let dev = SimulatedSsd::timing_only(DeviceProfile::nano(), 1 << 20, 1);
        let cfg = ProfileConfig {
            max_bytes: 1 << 20,
            ..Default::default()
        };
        let p = Profiler::new(&dev, cfg);
        assert!(p.profile_size(1 << 19).is_err()); // span exceeds capacity
    }
}
