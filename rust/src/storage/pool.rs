//! Sharded multi-device storage pool.
//!
//! Production edge/serving boxes stripe model weights across several
//! flash devices or NVMe namespaces; once per-device access cost is
//! modeled (the paper's `T[s]`), *inter-device* parallelism is the
//! remaining lever on top of the paper's *intra-device* contiguity
//! model. This module supplies that layer:
//!
//! * [`StripeLayout`] maps the flat weight address space of a
//!   [`FlashLayout`] onto N member devices. Striping is **chunk-granular
//!   and row-aligned**: stripe blocks never split a weight row, and the
//!   unit is sized to the scale of selection chunks (adaptive
//!   `rows/(4·N)` per matrix by default, or an explicit byte size), so a
//!   selected chunk maps to one member in the common case and at most a
//!   handful at the boundaries — never the page-granular shredding of
//!   classic RAID striping, which would destroy the contiguity the
//!   whole system is built around.
//! * [`DevicePool`] owns the members (each a [`FlashDevice`] with its
//!   own profile and `T[s]` table) and serves logical plans: a
//!   [`crate::plan::ShardedPlan`] (built by
//!   [`crate::plan::IoPlanner::shard_into`]) is fanned out across
//!   members and reassembled into the *logical* [`PlanReceipt`] —
//!   byte-identical to a single-device submission. Service time is the
//!   **max over members** (devices work in parallel), and per-member
//!   bytes/latency are reported through [`PoolStats`] so utilization
//!   skew is observable.
//!
//! Fan-out strategy: members whose service time is a *virtual* clock
//! ([`crate::storage::SimulatedSsd`]) are submitted serially — an
//! analytical clock cannot tell the difference, the max-over-members
//! aggregation is exact either way, and the serving hot path stays
//! allocation-free. Pools with any wall-clock member
//! ([`crate::storage::RealFileDevice`]) fan out with
//! `std::thread::scope`, one thread per member with a non-empty
//! sub-plan.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::latency::LatencyTable;
use crate::model::FlashLayout;
use crate::plan::{DeviceSubPlan, PlanReceipt, ReadPlan, ShardedPlan};
use crate::storage::{
    DeviceProfile, Extent, FlashDevice, PoolError, RealFileDevice, SimulatedSsd, READ_ATTEMPTS,
};

/// Hard cap on the stripe replication factor (stack-sized replica
/// option arrays on the routing hot path).
pub const MAX_REPLICAS: usize = 8;

/// How stripe blocks are assigned to pool members.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StripePolicy {
    /// Block `b` of every matrix region goes to member `b % N`. Simple
    /// and balanced by volume, but after a hot–cold reorder every
    /// matrix's hottest rows (the low block indices) pile onto member 0.
    RoundRobin,
    /// Layout-aware: each matrix's hot head (its first `⌈blocks/N⌉`
    /// stripe blocks — the hottest rows once the reorder permutation is
    /// baked in) is co-located on one member, staggered per matrix
    /// (`region_seq % N`), so hot traffic spreads across members while
    /// staying intra-member contiguous. Cold tails round-robin.
    HotAware,
}

/// Chunk-granular mapping of the flat weight address space onto pool
/// members.
///
/// Invariants (property-tested):
/// * blocks tile `[0, total_bytes)` exactly, in flat-address order;
/// * every block boundary is a row boundary of its matrix region (a
///   weight row never straddles members — with `align_rows` layouts
///   this also keeps sharded commands page-aligned);
/// * each member's blocks are assigned disjoint, densely-packed
///   device-local ranges, so member images partition the flat image.
#[derive(Clone, Debug)]
pub struct StripeLayout {
    devices: usize,
    /// Stripe replication factor: hot-head blocks exist on this many
    /// members (1 = no replication).
    replication: usize,
    /// Flat start offset per block, ascending; block `b` ends where
    /// block `b+1` starts (the last ends at `total`).
    starts: Vec<u64>,
    /// Owning (primary) member per block.
    device: Vec<u32>,
    /// Device-local start offset per block (on the primary member).
    local: Vec<u64>,
    /// Prefix index into `copy_dev`/`copy_local`: block `b`'s extra
    /// replica copies are entries `copy_off[b]..copy_off[b+1]`
    /// (`len == num_blocks + 1`; all-equal when replication is 1).
    copy_off: Vec<u32>,
    /// Member holding each extra copy.
    copy_dev: Vec<u32>,
    /// Device-local start offset of each extra copy.
    copy_local: Vec<u64>,
    /// Total bytes assigned to each member, *including* replica copies
    /// (sums to `total_bytes` only when replication is 1).
    device_bytes: Vec<u64>,
    total: u64,
}

impl StripeLayout {
    /// Build a stripe map for `devices` members over `layout`.
    ///
    /// `stripe_bytes = None` sizes blocks adaptively per matrix
    /// (`⌈rows / (4·devices)⌉` rows) so every matrix stripes across all
    /// members regardless of its size; `Some(b)` uses `max(1, b /
    /// row_bytes)` rows per block (production-scale, chunk-granular
    /// units).
    pub fn build(
        layout: &FlashLayout,
        devices: usize,
        policy: StripePolicy,
        stripe_bytes: Option<usize>,
    ) -> Self {
        Self::build_replicated(layout, devices, policy, stripe_bytes, 1)
    }

    /// [`StripeLayout::build`] with hot-stripe replication: each
    /// region's hot head (its first `⌈blocks/N⌉` stripe blocks — the
    /// hottest rows once the reorder permutation is baked in) is stored
    /// on `replication` members, copy `c` on member `(primary + c) % N`.
    /// Replicas hold byte-identical data, so routing a read to any
    /// holder returns the same bytes — replication changes *where* a
    /// byte is read, never the byte. Cold tails stay single-copy.
    /// `replication` is clamped to `[1, min(devices, MAX_REPLICAS)]`;
    /// with 1 this is exactly `build`.
    pub fn build_replicated(
        layout: &FlashLayout,
        devices: usize,
        policy: StripePolicy,
        stripe_bytes: Option<usize>,
        replication: usize,
    ) -> Self {
        let devices = devices.max(1);
        let replication = replication.clamp(1, devices.min(MAX_REPLICAS));
        let mut starts = Vec::new();
        let mut device = Vec::new();
        let mut local = Vec::new();
        let mut copy_off = vec![0u32];
        let mut copy_dev = Vec::new();
        let mut copy_local = Vec::new();
        let mut device_bytes = vec![0u64; devices];
        for (seq, (_id, base, row_bytes, rows)) in
            layout.regions_in_order().into_iter().enumerate()
        {
            let stripe_rows = match stripe_bytes {
                Some(b) => (b / row_bytes).max(1),
                None => rows.div_ceil(devices * 4).max(1),
            };
            let nblocks = rows.div_ceil(stripe_rows);
            let hot = nblocks.div_ceil(devices);
            for b in 0..nblocks {
                let dev = match policy {
                    StripePolicy::RoundRobin => b % devices,
                    StripePolicy::HotAware => {
                        if b < hot {
                            seq % devices
                        } else {
                            (seq + b) % devices
                        }
                    }
                };
                let row0 = b * stripe_rows;
                let nrows = stripe_rows.min(rows - row0);
                let len = (nrows * row_bytes) as u64;
                starts.push(base + (row0 * row_bytes) as u64);
                device.push(dev as u32);
                local.push(device_bytes[dev]);
                device_bytes[dev] += len;
                if replication > 1 && b < hot {
                    for c in 1..replication {
                        let rdev = (dev + c) % devices;
                        copy_dev.push(rdev as u32);
                        copy_local.push(device_bytes[rdev]);
                        device_bytes[rdev] += len;
                    }
                }
                copy_off.push(copy_dev.len() as u32);
            }
        }
        Self {
            devices,
            replication,
            starts,
            device,
            local,
            copy_off,
            copy_dev,
            copy_local,
            device_bytes,
            total: layout.total_bytes(),
        }
    }

    pub fn devices(&self) -> usize {
        self.devices
    }

    /// Configured replication factor (1 = no replication).
    pub fn replication(&self) -> usize {
        self.replication
    }

    pub fn num_blocks(&self) -> usize {
        self.starts.len()
    }

    pub fn total_bytes(&self) -> u64 {
        self.total
    }

    /// Bytes assigned to each member (sums to `total_bytes`).
    pub fn device_bytes(&self) -> &[u64] {
        &self.device_bytes
    }

    fn block_of(&self, offset: u64) -> usize {
        match self.starts.binary_search(&offset) {
            Ok(i) => i,
            Err(i) => i - 1,
        }
    }

    /// Owning member of a flat byte offset.
    pub fn device_of(&self, offset: u64) -> usize {
        self.device[self.block_of(offset)] as usize
    }

    /// Split a flat extent at stripe boundaries, emitting
    /// `(member, device-local extent, flat offset of the piece)` in flat
    /// order. Allocation-free.
    pub fn for_pieces(&self, extent: Extent, mut f: impl FnMut(usize, Extent, u64)) {
        if extent.len == 0 {
            return;
        }
        debug_assert!(extent.end() <= self.total, "extent beyond stripe map");
        let mut off = extent.offset;
        let end = extent.end();
        let mut b = self.block_of(off);
        while off < end {
            let block_end = if b + 1 < self.starts.len() {
                self.starts[b + 1]
            } else {
                self.total
            };
            let take = block_end.min(end) - off;
            let local = self.local[b] + (off - self.starts[b]);
            f(self.device[b] as usize, Extent::new(local, take as usize), off);
            off += take;
            b += 1;
        }
    }

    /// Split a flat extent at stripe boundaries like
    /// [`StripeLayout::for_pieces`], but emit *every* replica holding
    /// each piece: `f(flat offset, options)` where `options` is the
    /// `(member, device-local extent)` list — primary first, then the
    /// copies in placement order. Allocation-free (the option list is a
    /// stack array bounded by [`MAX_REPLICAS`]).
    pub fn for_pieces_all(&self, extent: Extent, mut f: impl FnMut(u64, &[(usize, Extent)])) {
        if extent.len == 0 {
            return;
        }
        debug_assert!(extent.end() <= self.total, "extent beyond stripe map");
        let mut off = extent.offset;
        let end = extent.end();
        let mut b = self.block_of(off);
        let mut opts = [(0usize, Extent::new(0, 0)); MAX_REPLICAS];
        while off < end {
            let block_end = if b + 1 < self.starts.len() {
                self.starts[b + 1]
            } else {
                self.total
            };
            let take = (block_end.min(end) - off) as usize;
            let delta = off - self.starts[b];
            opts[0] = (
                self.device[b] as usize,
                Extent::new(self.local[b] + delta, take),
            );
            let (c0, c1) = (self.copy_off[b] as usize, self.copy_off[b + 1] as usize);
            for (i, c) in (c0..c1).enumerate() {
                opts[1 + i] = (
                    self.copy_dev[c] as usize,
                    Extent::new(self.copy_local[c] + delta, take),
                );
            }
            f(off, &opts[..1 + (c1 - c0)]);
            off += take as u64;
            b += 1;
        }
    }

    /// Whether every byte of `cmds` is held by at least one live member
    /// (`dead[m]` flags dead ones). The degraded-mode coverage check: a
    /// request failing this gets a typed [`PoolError::Uncovered`], never
    /// a panic or a hang.
    pub fn covered_without(&self, cmds: &[Extent], dead: &[bool]) -> bool {
        for c in cmds {
            let mut ok = true;
            self.for_pieces_all(*c, |_, options| {
                if !options
                    .iter()
                    .any(|&(m, _)| !dead.get(m).copied().unwrap_or(false))
                {
                    ok = false;
                }
            });
            if !ok {
                return false;
            }
        }
        true
    }

    /// Partition a flat flash image into per-member images
    /// (device-local address space). Replicated blocks are written to
    /// every holding member, so replicas are byte-identical.
    pub fn shard_image(&self, flat: &[u8]) -> Vec<Vec<u8>> {
        assert_eq!(flat.len() as u64, self.total, "image / layout size mismatch");
        let mut out: Vec<Vec<u8>> = self
            .device_bytes
            .iter()
            .map(|&b| vec![0u8; b as usize])
            .collect();
        for b in 0..self.starts.len() {
            let start = self.starts[b] as usize;
            let end = if b + 1 < self.starts.len() {
                self.starts[b + 1] as usize
            } else {
                flat.len()
            };
            let dev = self.device[b] as usize;
            let local = self.local[b] as usize;
            out[dev][local..local + (end - start)].copy_from_slice(&flat[start..end]);
            for c in self.copy_off[b] as usize..self.copy_off[b + 1] as usize {
                let rdev = self.copy_dev[c] as usize;
                let rlocal = self.copy_local[c] as usize;
                out[rdev][rlocal..rlocal + (end - start)].copy_from_slice(&flat[start..end]);
            }
        }
        out
    }
}

/// Shared, lock-free pool health: per-member liveness, the per-member
/// routed-byte load signal replica routing balances on, and the
/// fault-tolerance counters surfaced through `Metrics`, `/metrics` and
/// the serving summaries. One instance per [`DevicePool`], shared (via
/// [`DevicePool::health`]) with the async I/O workers.
#[derive(Debug)]
pub struct PoolHealth {
    dead: Vec<AtomicBool>,
    routed: Vec<AtomicU64>,
    retries: AtomicU64,
    failovers: AtomicU64,
    hedges: AtomicU64,
    hedge_wins: AtomicU64,
}

impl PoolHealth {
    pub fn new(members: usize) -> Self {
        Self {
            dead: (0..members).map(|_| AtomicBool::new(false)).collect(),
            routed: (0..members).map(|_| AtomicU64::new(0)).collect(),
            retries: AtomicU64::new(0),
            failovers: AtomicU64::new(0),
            hedges: AtomicU64::new(0),
            hedge_wins: AtomicU64::new(0),
        }
    }

    pub fn members(&self) -> usize {
        self.dead.len()
    }

    pub fn is_dead(&self, m: usize) -> bool {
        self.dead[m].load(Ordering::SeqCst)
    }

    pub fn mark_dead(&self, m: usize) {
        self.dead[m].store(true, Ordering::SeqCst);
    }

    pub fn any_dead(&self) -> bool {
        self.dead.iter().any(|d| d.load(Ordering::SeqCst))
    }

    /// Bytes routed to member `m` so far (the load signal).
    pub fn routed(&self, m: usize) -> u64 {
        self.routed[m].load(Ordering::Relaxed)
    }

    pub fn add_routed(&self, m: usize, bytes: u64) {
        self.routed[m].fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn note_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_failover(&self) {
        self.failovers.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_hedge(&self) {
        self.hedges.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_hedge_win(&self) {
        self.hedge_wins.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> PoolHealthSnapshot {
        PoolHealthSnapshot {
            dead_members: (0..self.dead.len()).filter(|&m| self.is_dead(m)).collect(),
            retries: self.retries.load(Ordering::Relaxed),
            failovers: self.failovers.load(Ordering::Relaxed),
            hedges: self.hedges.load(Ordering::Relaxed),
            hedge_wins: self.hedge_wins.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time [`PoolHealth`] view (what `/healthz`, `/metrics` and
/// the serve/redline summaries report).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PoolHealthSnapshot {
    pub dead_members: Vec<usize>,
    pub retries: u64,
    pub failovers: u64,
    pub hedges: u64,
    pub hedge_wins: u64,
}

impl PoolHealthSnapshot {
    pub fn degraded(&self) -> bool {
        !self.dead_members.is_empty()
    }
}

/// Hedged-read tuning. A member whose sub-plan exceeds
/// `factor × Σ T_m[bytes(cmd)]` (its own profiled estimate), floored at
/// `floor`, gets its commands re-issued to the other replicas; the
/// first completion wins. `factor <= 0` disables hedging.
#[derive(Clone, Copy, Debug)]
pub struct HedgeConfig {
    pub factor: f64,
    pub floor: Duration,
}

impl Default for HedgeConfig {
    fn default() -> Self {
        Self {
            factor: 4.0,
            floor: Duration::from_micros(1000),
        }
    }
}

impl HedgeConfig {
    /// `NC_HEDGE_FACTOR` / `NC_HEDGE_FLOOR_US` over the defaults
    /// (factor 4.0, floor 1000µs). `NC_HEDGE_FACTOR=0` disables.
    pub fn from_env() -> Self {
        let mut cfg = Self::default();
        if let Some(f) = std::env::var("NC_HEDGE_FACTOR")
            .ok()
            .and_then(|v| v.parse::<f64>().ok())
        {
            cfg.factor = f;
        }
        if let Some(us) = std::env::var("NC_HEDGE_FLOOR_US")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
        {
            cfg.floor = Duration::from_micros(us);
        }
        cfg
    }

    pub fn enabled(&self) -> bool {
        self.factor > 0.0
    }
}

/// Per-member bytes and service time of pooled submissions. `reset` per
/// submit, `absorb` to accumulate across a call; all buffers reusable
/// (allocation-free at steady state once reserved).
#[derive(Clone, Debug, Default)]
pub struct PoolStats {
    pub bytes: Vec<u64>,
    pub service: Vec<Duration>,
}

impl PoolStats {
    pub fn reset(&mut self, devices: usize) {
        self.bytes.clear();
        self.bytes.resize(devices, 0);
        self.service.clear();
        self.service.resize(devices, Duration::ZERO);
    }

    pub fn reserve(&mut self, devices: usize) {
        self.bytes.reserve(devices);
        self.service.reserve(devices);
    }

    /// Accumulate another submission's stats into this one.
    pub fn absorb(&mut self, other: &PoolStats) {
        if self.bytes.len() < other.bytes.len() {
            self.bytes.resize(other.bytes.len(), 0);
            self.service.resize(other.service.len(), Duration::ZERO);
        }
        for (a, &b) in self.bytes.iter_mut().zip(&other.bytes) {
            *a += b;
        }
        for (a, &b) in self.service.iter_mut().zip(&other.service) {
            *a += b;
        }
    }

    /// Pool service time: the slowest member (devices work in parallel).
    pub fn max_service(&self) -> Duration {
        self.service.iter().max().copied().unwrap_or_default()
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes.iter().sum()
    }

    /// Utilization skew: max member service over mean member service
    /// (1.0 = perfectly balanced; N = one member did all the work).
    pub fn utilization_skew(&self) -> f64 {
        let n = self.service.len();
        if n == 0 {
            return 1.0;
        }
        let max = self.max_service().as_secs_f64();
        let mean = self.service.iter().map(|d| d.as_secs_f64()).sum::<f64>() / n as f64;
        if mean <= 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

/// Reusable working memory for pooled submissions: the sharded plan,
/// per-member staging receipts, the last submission's [`PoolStats`] and
/// a per-call accumulator. Lives in the session's scratch arena so the
/// pooled hot path stays allocation-free.
#[derive(Clone, Debug, Default)]
pub struct PoolScratch {
    pub sharded: ShardedPlan,
    pub staging: Vec<PlanReceipt>,
    /// Stats of the most recent submission.
    pub last: PoolStats,
    /// Accumulated stats across a serving call (reset per call).
    pub accum: PoolStats,
}

impl PoolScratch {
    /// Pre-reserve worst-case capacity: `cmds` commands and `bytes`
    /// staging bytes per member.
    pub fn reserve(&mut self, devices: usize, cmds: usize, bytes: usize) {
        self.sharded.reserve(devices, cmds);
        if self.staging.len() < devices {
            self.staging.resize_with(devices, Default::default);
        }
        for st in &mut self.staging {
            st.reserve(bytes, cmds);
        }
        self.last.reserve(devices);
        self.accum.reserve(devices);
    }
}

/// Raw pointer wrapper that is Send/Sync (disjoint-range writes only).
struct SendPtr(*mut u8);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// A pool of N flash devices behind one flat address space.
///
/// Implements [`FlashDevice`] over the *flat* space (capacity =
/// `StripeLayout::total_bytes`), so planner-backed cold paths
/// ([`crate::model::WeightStore::read_rows`], the profiler) work
/// unchanged; the serving hot path uses [`DevicePool::submit_sharded_into`]
/// with caller-owned scratch instead.
pub struct DevicePool {
    name: String,
    /// `Arc` rather than `Box`: the async I/O workers
    /// ([`crate::storage::AsyncIoQueue`]) hold shared references to the
    /// members they serve, outliving any single submission.
    members: Vec<Arc<dyn FlashDevice>>,
    /// Per-member profiled `T[s]` (absent for members built without one).
    tables: Vec<Option<LatencyTable>>,
    stripe: StripeLayout,
    /// Fan out with scoped threads (any wall-clock member) vs the exact
    /// serial path (all-virtual-clock members; keeps the hot path
    /// allocation-free).
    parallel: bool,
    /// Liveness, routed-load and fault counters, shared with the async
    /// I/O workers.
    health: Arc<PoolHealth>,
    hedge: HedgeConfig,
}

impl DevicePool {
    pub fn new(
        name: &str,
        members: Vec<Box<dyn FlashDevice>>,
        stripe: StripeLayout,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(!members.is_empty(), "pool needs at least one member");
        anyhow::ensure!(
            members.len() == stripe.devices(),
            "pool has {} members but stripe maps {}",
            members.len(),
            stripe.devices()
        );
        for (m, member) in members.iter().enumerate() {
            anyhow::ensure!(
                member.capacity() >= stripe.device_bytes()[m],
                "member {m} ({}) holds {} < assigned {}",
                member.name(),
                member.capacity(),
                stripe.device_bytes()[m]
            );
        }
        let parallel = !members.iter().all(|m| m.is_virtual_time());
        let tables = members.iter().map(|_| None).collect();
        let health = Arc::new(PoolHealth::new(members.len()));
        Ok(Self {
            name: name.to_string(),
            members: members.into_iter().map(Arc::from).collect(),
            tables,
            stripe,
            parallel,
            health,
            hedge: HedgeConfig::default(),
        })
    }

    /// Attach per-member latency tables (one per member, in order).
    pub fn with_tables(mut self, tables: Vec<LatencyTable>) -> Self {
        assert_eq!(tables.len(), self.members.len());
        self.tables = tables.into_iter().map(Some).collect();
        self
    }

    /// Override the hedged-read tuning (default [`HedgeConfig::default`]).
    pub fn with_hedge(mut self, hedge: HedgeConfig) -> Self {
        self.hedge = hedge;
        self
    }

    /// Shared pool-health handle (liveness, load, fault counters).
    pub fn health(&self) -> Arc<PoolHealth> {
        self.health.clone()
    }

    /// The hedged-read tuning in force.
    pub fn hedge_config(&self) -> HedgeConfig {
        self.hedge
    }

    /// Hedge budget of one member sub-plan: `factor × Σ T_m[bytes(cmd)]`
    /// under the member's own profiled table, floored at the configured
    /// minimum (members without a table get the floor).
    pub fn hedge_budget(&self, m: usize, shard: &DeviceSubPlan) -> Duration {
        let est = self
            .member_table(m)
            .map(|t| shard.cmds.iter().map(|c| t.latency_bytes(c.len)).sum::<f64>())
            .unwrap_or(0.0);
        Duration::from_secs_f64((est * self.hedge.factor).max(0.0)).max(self.hedge.floor)
    }

    /// Re-map one *routed* sub-plan onto the other live replicas for a
    /// hedged re-issue: every piece of `shard` (located via its flat
    /// offsets) goes to its least-loaded live holder other than `avoid`.
    /// Returns per-target `(member, device-local cmds, logical dsts)`
    /// groups, or `None` when some piece is held only by `avoid`
    /// (nowhere to hedge to) or the sub-plan carries no flat offsets
    /// (unrouted). Routed-load accounting is *not* updated here — the
    /// caller charges targets if and when the hedge actually fires.
    pub fn reroute_shard(
        &self,
        shard: &DeviceSubPlan,
        avoid: usize,
    ) -> Option<Vec<(usize, Vec<Extent>, Vec<usize>)>> {
        if shard.flats.len() != shard.cmds.len() {
            return None;
        }
        let n = self.members.len();
        let mut tcmds: Vec<Vec<Extent>> = vec![Vec::new(); n];
        let mut tdsts: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut possible = true;
        for i in 0..shard.cmds.len() {
            let flat0 = shard.flats[i];
            let dst0 = shard.dsts[i];
            self.stripe
                .for_pieces_all(Extent::new(flat0, shard.cmds[i].len), |pflat, options| {
                    let mut best: Option<(usize, Extent)> = None;
                    let mut best_load = u64::MAX;
                    for &(om, ol) in options {
                        if om == avoid || self.health.is_dead(om) {
                            continue;
                        }
                        let load = self.health.routed(om);
                        if best.is_none() || load < best_load {
                            best = Some((om, ol));
                            best_load = load;
                        }
                    }
                    match best {
                        Some((om, ol)) => {
                            tcmds[om].push(ol);
                            tdsts[om].push(dst0 + (pflat - flat0) as usize);
                        }
                        None => possible = false,
                    }
                });
        }
        if !possible {
            return None;
        }
        let mut out = Vec::new();
        for t in 0..n {
            if tcmds[t].is_empty() {
                continue;
            }
            out.push((t, std::mem::take(&mut tcmds[t]), std::mem::take(&mut tdsts[t])));
        }
        Some(out)
    }

    /// Replace each member with `wrap(index, member)` — the
    /// fault-injection seam: wrap members in
    /// [`crate::storage::FaultInjector`]s after construction without
    /// rebuilding images or stripe maps. Recomputes the fan-out mode
    /// from the wrapped members.
    pub fn wrap_members(
        &mut self,
        mut wrap: impl FnMut(usize, Arc<dyn FlashDevice>) -> Arc<dyn FlashDevice>,
    ) {
        let members = std::mem::take(&mut self.members);
        self.members = members
            .into_iter()
            .enumerate()
            .map(|(m, d)| wrap(m, d))
            .collect();
        self.parallel = !self.members.iter().all(|m| m.is_virtual_time());
    }

    /// Homogeneous-or-heterogeneous simulated pool: one
    /// [`SimulatedSsd`] member per profile, each backed by its shard of
    /// `image`. Member `m` is seeded `seed ^ (m · φ64)` so member 0 of a
    /// 1-member pool reproduces the historical single-device stream.
    pub fn simulated(
        profiles: &[DeviceProfile],
        stripe: StripeLayout,
        image: &[u8],
        seed: u64,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            profiles.len() == stripe.devices(),
            "{} profiles for {} stripe members",
            profiles.len(),
            stripe.devices()
        );
        let shards = stripe.shard_image(image);
        let members: Vec<Box<dyn FlashDevice>> = shards
            .into_iter()
            .zip(profiles)
            .enumerate()
            .map(|(m, (img, p))| {
                Box::new(SimulatedSsd::with_image(
                    p.clone(),
                    img,
                    seed ^ (m as u64).wrapping_mul(0x9E3779B97F4A7C15),
                )) as Box<dyn FlashDevice>
            })
            .collect();
        Self::new("pool", members, stripe)
    }

    /// Real-storage pool: one backing file per member (each holding that
    /// member's device-local image, e.g. written from
    /// [`StripeLayout::shard_image`]).
    pub fn from_files(
        paths: &[std::path::PathBuf],
        stripe: StripeLayout,
        threads: usize,
        direct: bool,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            paths.len() == stripe.devices(),
            "{} files for {} stripe members",
            paths.len(),
            stripe.devices()
        );
        let members = paths
            .iter()
            .map(|p| {
                RealFileDevice::open(p, threads, direct)
                    .map(|d| Box::new(d) as Box<dyn FlashDevice>)
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Self::new("pool-files", members, stripe)
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    pub fn member(&self, m: usize) -> &dyn FlashDevice {
        self.members[m].as_ref()
    }

    /// Shared handle to one member (what async I/O workers hold).
    pub fn member_arc(&self, m: usize) -> Arc<dyn FlashDevice> {
        self.members[m].clone()
    }

    /// Shared handles to every member, in order.
    pub fn member_arcs(&self) -> Vec<Arc<dyn FlashDevice>> {
        self.members.clone()
    }

    pub fn member_table(&self, m: usize) -> Option<&LatencyTable> {
        self.tables.get(m).and_then(|t| t.as_ref())
    }

    pub fn stripe(&self) -> &StripeLayout {
        &self.stripe
    }

    /// Pool-aware plan estimate: service time is the slowest member, so
    /// the estimate is the max over members of `Σ T_m[bytes(cmd)]` under
    /// each member's own table. 0.0 when no tables are attached.
    pub fn estimate_sharded(&self, sharded: &ShardedPlan) -> f64 {
        let mut worst = 0.0f64;
        for (m, shard) in sharded.shards.iter().enumerate() {
            if let Some(t) = self.member_table(m) {
                let est: f64 = shard.cmds.iter().map(|c| t.latency_bytes(c.len)).sum();
                worst = worst.max(est);
            }
        }
        worst
    }

    /// Whether plans for this pool must go through the replica-routed
    /// shard step: either hot stripes are replicated (there is a routing
    /// choice to make) or a member died (its blocks must be avoided).
    pub fn needs_routing(&self) -> bool {
        self.stripe.replication() > 1 || self.health.any_dead()
    }

    /// Replica-routed shard step bound to this pool's health: each piece
    /// goes to the *live* holding replica with the fewest routed bytes
    /// so far (the same per-member byte accounting `PoolStats`'
    /// utilization skew is derived from), primary on ties. Routed bytes
    /// are accounted as chosen.
    pub fn route_plan(&self, plan: &ReadPlan, out: &mut ShardedPlan) {
        self.route_cmds(plan.cmds(), out);
    }

    fn route_cmds(&self, cmds: &[Extent], out: &mut ShardedPlan) {
        out.route_from(cmds, &self.stripe, |options| self.choose_replica(options));
    }

    /// Pick the least-loaded live holder among `options`; falls back to
    /// the primary when every holder is dead (the read will then fail
    /// with a member error — coverage is checked before routing on the
    /// degraded paths).
    fn choose_replica(&self, options: &[(usize, Extent)]) -> usize {
        let mut pick = 0usize;
        let mut best: Option<u64> = None;
        for (i, &(m, _)) in options.iter().enumerate() {
            if self.health.is_dead(m) {
                continue;
            }
            let load = self.health.routed(m);
            if best.map_or(true, |b| load < b) {
                best = Some(load);
                pick = i;
            }
        }
        let (m, e) = options[pick];
        self.health.add_routed(m, e.len as u64);
        pick
    }

    /// One member read with [`READ_ATTEMPTS`] attempts; transient
    /// failures count as retries, persistent failure surfaces as a
    /// typed [`PoolError::MemberFailed`] naming the member.
    fn read_with_retries(
        member: &dyn FlashDevice,
        health: &PoolHealth,
        m: usize,
        cmds: &[Extent],
        out: &mut [u8],
    ) -> anyhow::Result<Duration> {
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..READ_ATTEMPTS {
            match member.read_batch(cmds, out) {
                Ok(d) => return Ok(d),
                Err(e) => {
                    if attempt + 1 < READ_ATTEMPTS {
                        health.note_retry();
                    }
                    last = Some(e);
                }
            }
        }
        Err(last.unwrap().context(PoolError::MemberFailed { member: m }))
    }

    /// Submit a plan directly to one member with the pool's retry and
    /// liveness accounting — the single-member fast path of
    /// [`crate::coordinator`] engines (bypassing the shard step must not
    /// bypass fault tolerance). Persistent failure marks the member
    /// dead and returns a typed [`PoolError::MemberFailed`].
    pub fn submit_member_into(
        &self,
        m: usize,
        plan: &ReadPlan,
        receipt: &mut PlanReceipt,
    ) -> anyhow::Result<()> {
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..READ_ATTEMPTS {
            match self.members[m].submit_into(plan, receipt) {
                Ok(()) => return Ok(()),
                Err(e) => {
                    if attempt + 1 < READ_ATTEMPTS {
                        self.health.note_retry();
                    }
                    last = Some(e);
                }
            }
        }
        self.health.mark_dead(m);
        Err(last.unwrap().context(PoolError::MemberFailed { member: m }))
    }

    /// Submit a pre-sharded logical plan: fan the per-member sub-plans
    /// out across members, reassemble the *logical* receipt (bytes in
    /// logical command order — bit-identical to a single-device
    /// submission), report service as the max over members, and record
    /// per-member bytes/latency into `stats`.
    ///
    /// Allocation-free at steady state: `staging` receipts and `stats`
    /// vectors reuse their capacity (pool them in a
    /// [`PoolScratch`]). Logical submission batches are not preserved —
    /// each member receives its sub-plan as one deep batch (the serving
    /// coalesce policy submits one batch anyway).
    pub fn submit_sharded_into(
        &self,
        plan: &ReadPlan,
        sharded: &ShardedPlan,
        staging: &mut Vec<PlanReceipt>,
        receipt: &mut PlanReceipt,
        stats: &mut PoolStats,
    ) -> anyhow::Result<()> {
        let n = self.members.len();
        anyhow::ensure!(
            sharded.shards.len() == n,
            "sharded plan has {} shards for {} members",
            sharded.shards.len(),
            n
        );
        let total = receipt.presize_for(plan.cmds());
        anyhow::ensure!(
            sharded.total_bytes() == total,
            "sharded plan covers {} of {} plan bytes",
            sharded.total_bytes(),
            total
        );
        if staging.len() < n {
            staging.resize_with(n, Default::default);
        }
        stats.reset(n);
        // Hedging needs a routing choice (replicas) and flat offsets to
        // re-map a straggler's commands; both exist only on routed plans
        // over replicated stripes, and only wall-clock members can
        // meaningfully straggle.
        let hedged = self.parallel
            && self.hedge.enabled()
            && self.stripe.replication() > 1
            && sharded
                .shards
                .iter()
                .all(|s| s.flats.len() == s.cmds.len());
        let fanned = if hedged {
            self.fan_out_hedged(&sharded.shards, staging, &mut receipt.bytes, stats)
        } else {
            self.fan_out(&sharded.shards, staging, &mut receipt.bytes, stats)
        };
        match fanned {
            Ok(d) => {
                receipt.service = d;
                Ok(())
            }
            Err(e) => self.failover_submit(plan, e, staging, receipt, stats),
        }
    }

    /// Last-resort failover after a member failed all its retries (and,
    /// on hedged paths, its hedges): mark the member dead, and — when
    /// every byte of the plan is still held by live replicas — re-shard
    /// around the corpse and run the fan-out again. Uncoverable plans
    /// get a typed [`PoolError::Uncovered`]; either way the caller sees
    /// a clean result, never a panic or a hang. Cold path: allocates.
    fn failover_submit(
        &self,
        plan: &ReadPlan,
        mut err: anyhow::Error,
        staging: &mut Vec<PlanReceipt>,
        receipt: &mut PlanReceipt,
        stats: &mut PoolStats,
    ) -> anyhow::Result<()> {
        loop {
            let Some(&PoolError::MemberFailed { member }) = err.downcast_ref::<PoolError>()
            else {
                return Err(err);
            };
            self.health.mark_dead(member);
            let dead: Vec<bool> = (0..self.members.len())
                .map(|m| self.health.is_dead(m))
                .collect();
            if dead.iter().all(|&d| d) {
                return Err(err);
            }
            if !self.stripe.covered_without(plan.cmds(), &dead) {
                return Err(err.context(PoolError::Uncovered { member }));
            }
            let mut rerouted = ShardedPlan::default();
            self.route_cmds(plan.cmds(), &mut rerouted);
            self.health.note_failover();
            stats.reset(self.members.len());
            match self.fan_out(&rerouted.shards, staging, &mut receipt.bytes, stats) {
                Ok(d) => {
                    receipt.service = d;
                    return Ok(());
                }
                Err(e) => err = e,
            }
        }
    }

    /// Run every member's sub-plan, scattering the data into the logical
    /// output buffer (`dsts` are disjoint by construction). Returns the
    /// max member service time. Each member read gets [`READ_ATTEMPTS`]
    /// attempts; a persistent member failure surfaces as a clean typed
    /// error naming the member (the first failing member when several
    /// fail) — never a panic, and never a partially-written receipt
    /// reported as success.
    fn fan_out(
        &self,
        shards: &[DeviceSubPlan],
        staging: &mut [PlanReceipt],
        out: &mut [u8],
        stats: &mut PoolStats,
    ) -> anyhow::Result<Duration> {
        let mut max = Duration::ZERO;
        if !self.parallel {
            // Serial exact path: members report virtual clocks, so
            // concurrency cannot change the outcome; max-over-members is
            // computed directly and no thread is spawned (the pooled
            // serving hot path stays allocation-free).
            for (m, shard) in shards.iter().enumerate() {
                if shard.cmds.is_empty() {
                    continue;
                }
                let st = &mut staging[m];
                st.clear();
                let b = shard.bytes();
                st.bytes.resize(b, 0);
                let d = Self::read_with_retries(
                    self.members[m].as_ref(),
                    &self.health,
                    m,
                    &shard.cmds,
                    &mut st.bytes,
                )?;
                let mut sat = 0usize;
                for (e, &dst) in shard.cmds.iter().zip(&shard.dsts) {
                    out[dst..dst + e.len].copy_from_slice(&st.bytes[sat..sat + e.len]);
                    sat += e.len;
                }
                stats.bytes[m] = b as u64;
                stats.service[m] = d;
                max = max.max(d);
            }
            return Ok(max);
        }

        // Wall-clock members: one scoped thread per member with a
        // non-empty sub-plan, each reading into its own staging buffer
        // and scattering to disjoint ranges of the shared output.
        let out_len = out.len();
        let out_ptr = SendPtr(out.as_mut_ptr());
        let mut err: Option<anyhow::Error> = None;
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for (m, (shard, st)) in shards.iter().zip(staging.iter_mut()).enumerate() {
                if shard.cmds.is_empty() {
                    continue;
                }
                let member = &self.members[m];
                let health = &self.health;
                let out_ptr = &out_ptr;
                handles.push((
                    m,
                    scope.spawn(move || -> anyhow::Result<(u64, Duration)> {
                        st.clear();
                        let b = shard.bytes();
                        st.bytes.resize(b, 0);
                        let d = Self::read_with_retries(
                            member.as_ref(),
                            health,
                            m,
                            &shard.cmds,
                            &mut st.bytes,
                        )?;
                        let mut sat = 0usize;
                        for (e, &dst) in shard.cmds.iter().zip(&shard.dsts) {
                            debug_assert!(dst + e.len <= out_len);
                            // SAFETY: members scatter to disjoint
                            // [dst, dst+len) ranges (the shard step
                            // partitions every logical command).
                            let slice = unsafe {
                                std::slice::from_raw_parts_mut(out_ptr.0.add(dst), e.len)
                            };
                            slice.copy_from_slice(&st.bytes[sat..sat + e.len]);
                            sat += e.len;
                        }
                        Ok((b as u64, d))
                    }),
                ));
            }
            for (m, h) in handles {
                match h.join() {
                    Ok(Ok((b, d))) => {
                        stats.bytes[m] = b;
                        stats.service[m] = d;
                        max = max.max(d);
                    }
                    Ok(Err(e)) => {
                        if err.is_none() {
                            err = Some(e);
                        }
                    }
                    Err(_) => {
                        if err.is_none() {
                            err = Some(
                                anyhow::anyhow!("pool member {m} worker thread panicked")
                                    .context(PoolError::MemberFailed { member: m }),
                            );
                        }
                    }
                }
            }
        });
        if let Some(e) = err {
            return Err(e);
        }
        Ok(max)
    }

    /// Hedged wall-clock fan-out over a *routed* sharded plan (every
    /// sub-plan carries flat offsets). Member threads read into their
    /// staging buffers and hand them back over a channel — the parent
    /// scatters. A member that misses its hedge deadline
    /// (`hedge.factor × Σ T_m[bytes(cmd)]`, floored at `hedge.floor`) —
    /// or errors outright — gets its commands re-mapped onto the other
    /// live replicas and re-issued; whichever source completes first
    /// resolves the member (replicas are byte-identical, so both
    /// completing is harmless). Every spawned read is drained before
    /// returning, so loser buffers are reclaimed, not leaked.
    fn fan_out_hedged(
        &self,
        shards: &[DeviceSubPlan],
        staging: &mut [PlanReceipt],
        out: &mut [u8],
        stats: &mut PoolStats,
    ) -> anyhow::Result<Duration> {
        enum Msg {
            Orig {
                m: usize,
                res: anyhow::Result<Duration>,
                buf: Vec<u8>,
            },
            Hedge {
                m: usize,
                target: usize,
                res: anyhow::Result<Duration>,
                buf: Vec<u8>,
                /// `(dst offset in `out`, len)` per command, in order.
                scatter: Vec<(usize, usize)>,
            },
        }

        let n = shards.len();
        let started = Instant::now();
        // Per-member hedge deadline from its own profiled estimate.
        let deadline_for =
            |m: usize, shard: &DeviceSubPlan| -> Instant { started + self.hedge_budget(m, shard) };

        let mut deadline: Vec<Option<Instant>> = vec![None; n];
        let mut orig_pending = vec![false; n];
        let mut resolved = vec![false; n];
        let mut hedged = vec![false; n];
        let mut hedge_parts_left = vec![0usize; n];
        let mut orig_err: Vec<Option<anyhow::Error>> = (0..n).map(|_| None).collect();
        let mut hedge_err: Vec<Option<anyhow::Error>> = (0..n).map(|_| None).collect();
        let mut hedge_service = vec![Duration::ZERO; n];
        let mut hedge_credit: Vec<Vec<(usize, u64)>> = vec![Vec::new(); n];
        let mut err: Option<anyhow::Error> = None;
        let mut max = Duration::ZERO;

        let (tx, rx) = std::sync::mpsc::channel::<Msg>();
        std::thread::scope(|scope| {
            let mut spawned = 0usize;
            let mut received = 0usize;
            let mut to_hedge: Vec<usize> = Vec::new();
            for (m, shard) in shards.iter().enumerate() {
                if shard.cmds.is_empty() {
                    continue;
                }
                let mut buf = std::mem::take(&mut staging[m].bytes);
                buf.clear();
                buf.resize(shard.bytes(), 0);
                deadline[m] = Some(deadline_for(m, shard));
                orig_pending[m] = true;
                let member = &self.members[m];
                let health = &self.health;
                let tx = tx.clone();
                spawned += 1;
                scope.spawn(move || {
                    let res = Self::read_with_retries(
                        member.as_ref(),
                        health,
                        m,
                        &shard.cmds,
                        &mut buf,
                    );
                    tx.send(Msg::Orig { m, res, buf }).ok();
                });
            }

            while received < spawned || !to_hedge.is_empty() {
                // Launch queued hedges: re-map the straggler's commands
                // (via their flat offsets) onto the least-loaded live
                // replicas, one read per target member.
                for m in std::mem::take(&mut to_hedge) {
                    if hedged[m] || resolved[m] {
                        continue;
                    }
                    let shard = &shards[m];
                    let mut tcmds: Vec<Vec<Extent>> = vec![Vec::new(); n];
                    let mut tscatter: Vec<Vec<(usize, usize)>> = vec![Vec::new(); n];
                    let mut possible = true;
                    for i in 0..shard.cmds.len() {
                        let flat0 = shard.flats[i];
                        let dst0 = shard.dsts[i];
                        self.stripe.for_pieces_all(
                            Extent::new(flat0, shard.cmds[i].len),
                            |pflat, options| {
                                let mut best: Option<(usize, Extent)> = None;
                                let mut best_load = u64::MAX;
                                for &(om, ol) in options {
                                    if om == m || self.health.is_dead(om) {
                                        continue;
                                    }
                                    let load = self.health.routed(om);
                                    if best.is_none() || load < best_load {
                                        best = Some((om, ol));
                                        best_load = load;
                                    }
                                }
                                match best {
                                    Some((om, ol)) => {
                                        tcmds[om].push(ol);
                                        tscatter[om]
                                            .push((dst0 + (pflat - flat0) as usize, ol.len));
                                    }
                                    None => possible = false,
                                }
                            },
                        );
                    }
                    if !possible {
                        // Nowhere to hedge to (some piece lives only on
                        // this member) — wait the original out.
                        deadline[m] = None;
                        continue;
                    }
                    hedged[m] = true;
                    self.health.note_hedge();
                    for t in 0..n {
                        if tcmds[t].is_empty() {
                            continue;
                        }
                        let cmds = std::mem::take(&mut tcmds[t]);
                        let scatter = std::mem::take(&mut tscatter[t]);
                        let bytes: usize = cmds.iter().map(|e| e.len).sum();
                        self.health.add_routed(t, bytes as u64);
                        let member = &self.members[t];
                        let health = &self.health;
                        let tx = tx.clone();
                        hedge_parts_left[m] += 1;
                        spawned += 1;
                        scope.spawn(move || {
                            let mut buf = vec![0u8; bytes];
                            let res = Self::read_with_retries(
                                member.as_ref(),
                                health,
                                t,
                                &cmds,
                                &mut buf,
                            );
                            tx.send(Msg::Hedge { m, target: t, res, buf, scatter }).ok();
                        });
                    }
                }
                if received >= spawned {
                    continue;
                }

                // Wait for the next completion, bounded by the earliest
                // pending hedge deadline.
                let now = Instant::now();
                let mut next: Option<Instant> = None;
                for m in 0..n {
                    if orig_pending[m] && !hedged[m] && !resolved[m] {
                        if let Some(dl) = deadline[m] {
                            next = Some(next.map_or(dl, |x: Instant| x.min(dl)));
                        }
                    }
                }
                let msg = match next {
                    Some(dl) if dl <= now => {
                        for m in 0..n {
                            if orig_pending[m]
                                && !hedged[m]
                                && !resolved[m]
                                && deadline[m].is_some_and(|d| d <= now)
                            {
                                to_hedge.push(m);
                            }
                        }
                        continue;
                    }
                    Some(dl) => match rx.recv_timeout(dl - now) {
                        Ok(msg) => msg,
                        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
                    },
                    None => match rx.recv() {
                        Ok(msg) => msg,
                        Err(_) => break,
                    },
                };

                match msg {
                    Msg::Orig { m, res, buf } => {
                        received += 1;
                        orig_pending[m] = false;
                        match res {
                            Ok(d) => {
                                let shard = &shards[m];
                                let mut sat = 0usize;
                                for (e, &dst) in shard.cmds.iter().zip(&shard.dsts) {
                                    out[dst..dst + e.len]
                                        .copy_from_slice(&buf[sat..sat + e.len]);
                                    sat += e.len;
                                }
                                if !resolved[m] {
                                    resolved[m] = true;
                                    stats.bytes[m] += shard.bytes() as u64;
                                    stats.service[m] = d;
                                    max = max.max(d);
                                }
                            }
                            Err(e) => {
                                orig_err[m] = Some(e);
                                if !resolved[m] && !hedged[m] {
                                    // Error failover inside the hedge
                                    // machinery: re-issue immediately.
                                    to_hedge.push(m);
                                }
                            }
                        }
                        // Return the staging buffer (win or lose).
                        staging[m].bytes = buf;
                    }
                    Msg::Hedge { m, target, res, buf, scatter } => {
                        received += 1;
                        match res {
                            Ok(d) => {
                                let mut src = 0usize;
                                for &(dst, len) in &scatter {
                                    out[dst..dst + len]
                                        .copy_from_slice(&buf[src..src + len]);
                                    src += len;
                                }
                                hedge_service[m] = hedge_service[m].max(d);
                                hedge_credit[m].push((target, src as u64));
                                hedge_parts_left[m] -= 1;
                                if hedge_parts_left[m] == 0
                                    && !resolved[m]
                                    && hedge_err[m].is_none()
                                {
                                    resolved[m] = true;
                                    self.health.note_hedge_win();
                                    for &(t, b) in &hedge_credit[m] {
                                        stats.bytes[t] += b;
                                    }
                                    stats.service[m] = hedge_service[m];
                                    max = max.max(hedge_service[m]);
                                }
                            }
                            Err(e) => {
                                hedge_parts_left[m] -= 1;
                                hedge_err[m] = Some(e);
                            }
                        }
                    }
                }
            }
        });
        drop(tx);

        for m in 0..n {
            if shards[m].cmds.is_empty() || resolved[m] {
                continue;
            }
            // Both the original and (if launched) the hedge failed.
            let e = orig_err[m]
                .take()
                .or_else(|| hedge_err[m].take())
                .unwrap_or_else(|| {
                    anyhow::anyhow!("pool member {m} never completed")
                        .context(PoolError::MemberFailed { member: m })
                });
            if err.is_none() {
                err = Some(e);
            }
        }
        if let Some(e) = err {
            return Err(e);
        }
        Ok(max)
    }
}

impl FlashDevice for DevicePool {
    fn name(&self) -> &str {
        &self.name
    }

    fn capacity(&self) -> u64 {
        self.stripe.total_bytes()
    }

    fn is_virtual_time(&self) -> bool {
        self.members.iter().all(|m| m.is_virtual_time())
    }

    /// Flat-space batched read (cold paths; allocates working memory).
    /// Service time is the max over members.
    fn read_batch(&self, extents: &[Extent], out: &mut [u8]) -> anyhow::Result<Duration> {
        let total: usize = extents.iter().map(|e| e.len).sum();
        anyhow::ensure!(out.len() == total, "out buffer {} != {}", out.len(), total);
        if self.members.len() == 1 {
            return Self::read_with_retries(
                self.members[0].as_ref(),
                &self.health,
                0,
                extents,
                out,
            );
        }
        for e in extents {
            anyhow::ensure!(
                e.end() <= self.stripe.total_bytes(),
                "extent {:?} beyond pool capacity {}",
                e,
                self.stripe.total_bytes()
            );
        }
        let n = self.members.len();
        let mut staging: Vec<PlanReceipt> = (0..n).map(|_| PlanReceipt::default()).collect();
        let mut stats = PoolStats::default();
        stats.reset(n);
        if self.needs_routing() {
            // Degraded or replicated pool: route every piece to a live
            // replica (typed error when a piece has no live holder).
            let dead: Vec<bool> = (0..n).map(|m| self.health.is_dead(m)).collect();
            if dead.iter().any(|&d| d) && !self.stripe.covered_without(extents, &dead) {
                let member = dead.iter().position(|&d| d).unwrap_or(0);
                return Err(anyhow::Error::new(PoolError::Uncovered { member }));
            }
            let mut sharded = ShardedPlan::default();
            self.route_cmds(extents, &mut sharded);
            return self.fan_out(&sharded.shards, &mut staging, out, &mut stats);
        }
        let mut shards: Vec<DeviceSubPlan> = (0..n).map(|_| DeviceSubPlan::default()).collect();
        let mut at = 0usize;
        for e in extents {
            self.stripe.for_pieces(*e, |dev, local, flat| {
                shards[dev].push_piece(local, at + (flat - e.offset) as usize);
            });
            at += e.len;
        }
        self.fan_out(&shards, &mut staging, out, &mut stats)
    }

    fn service_time(&self, extents: &[Extent]) -> anyhow::Result<Duration> {
        let total: usize = extents.iter().map(|e| e.len).sum();
        let mut scratch = vec![0u8; total];
        self.read_batch(extents, &mut scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latency::Chunk;
    use crate::model::{MatrixId, MatrixKind, ModelSpec, WeightStore};
    use crate::plan::{CoalescePolicy, IoPlanner, PlanRequest};

    fn store() -> WeightStore {
        WeightStore::new(ModelSpec::tiny(), false, 42)
    }

    fn nano_pool(
        store: &WeightStore,
        image: &[u8],
        devices: usize,
        policy: StripePolicy,
    ) -> DevicePool {
        let stripe = StripeLayout::build(&store.layout, devices, policy, None);
        DevicePool::simulated(&vec![DeviceProfile::nano(); devices], stripe, image, 7).unwrap()
    }

    #[test]
    fn stripe_blocks_tile_and_balance() {
        let s = store();
        for devices in [1usize, 2, 3, 4] {
            let stripe = StripeLayout::build(&s.layout, devices, StripePolicy::RoundRobin, None);
            assert_eq!(
                stripe.device_bytes().iter().sum::<u64>(),
                s.layout.total_bytes()
            );
            assert_eq!(stripe.devices(), devices);
            if devices > 1 {
                // Adaptive striping gives every member a non-trivial share.
                for (m, &b) in stripe.device_bytes().iter().enumerate() {
                    assert!(b > 0, "member {m} got no bytes");
                }
            }
        }
    }

    #[test]
    fn stripe_boundaries_are_row_aligned() {
        let s = store();
        let stripe = StripeLayout::build(&s.layout, 4, StripePolicy::RoundRobin, None);
        for (id, base, row_bytes, rows) in s.layout.regions_in_order() {
            let _ = id;
            let end = base + (rows * row_bytes) as u64;
            for &start in &stripe.starts {
                if start > base && start < end {
                    assert_eq!(
                        ((start - base) as usize) % row_bytes,
                        0,
                        "block boundary splits a row"
                    );
                }
            }
        }
    }

    #[test]
    fn pieces_reassemble_extents() {
        let s = store();
        let stripe = StripeLayout::build(&s.layout, 3, StripePolicy::HotAware, Some(2048));
        let extent = Extent::new(100, 9000);
        let mut covered = 0usize;
        let mut next_flat = extent.offset;
        stripe.for_pieces(extent, |dev, local, flat| {
            assert!(dev < 3);
            assert_eq!(flat, next_flat, "pieces out of order or gapped");
            assert!(local.end() <= stripe.device_bytes()[dev]);
            covered += local.len;
            next_flat += local.len as u64;
        });
        assert_eq!(covered, extent.len);
    }

    #[test]
    fn hot_aware_staggers_region_heads() {
        let s = store();
        let stripe = StripeLayout::build(&s.layout, 4, StripePolicy::HotAware, None);
        let heads: Vec<usize> = s
            .layout
            .regions_in_order()
            .iter()
            .map(|&(_, base, _, _)| stripe.device_of(base))
            .collect();
        // Consecutive matrices' hot heads land on different members.
        assert!(heads.windows(2).any(|w| w[0] != w[1]));
        let distinct: std::collections::HashSet<usize> = heads.iter().copied().collect();
        assert_eq!(distinct.len(), 4, "hot heads should cover all members");
    }

    #[test]
    fn round_robin_piles_heads_on_member_zero() {
        let s = store();
        let stripe = StripeLayout::build(&s.layout, 4, StripePolicy::RoundRobin, None);
        for (_, base, _, _) in s.layout.regions_in_order() {
            assert_eq!(stripe.device_of(base), 0);
        }
    }

    #[test]
    fn pool_read_batch_matches_flat_image() {
        let s = store();
        let image = s.build_image();
        for devices in [1usize, 2, 4] {
            for policy in [StripePolicy::RoundRobin, StripePolicy::HotAware] {
                let pool = nano_pool(&s, &image, devices, policy);
                let extents = [
                    Extent::new(10, 100),
                    Extent::new(5000, 2000),
                    Extent::new(image.len() as u64 - 64, 64),
                ];
                let (bytes, t) = pool.read_batch_vec(&extents).unwrap();
                let mut want = Vec::new();
                for e in &extents {
                    want.extend_from_slice(&image[e.offset as usize..e.end() as usize]);
                }
                assert_eq!(bytes, want, "devices={devices} policy={policy:?}");
                assert!(t > Duration::ZERO);
            }
        }
    }

    #[test]
    fn sharded_submit_reassembles_logical_receipt() {
        let s = store();
        let image = s.build_image();
        let flat = SimulatedSsd::with_image(DeviceProfile::nano(), image.clone(), 5);
        let planner = IoPlanner::new(CoalescePolicy::contiguous());
        let id = MatrixId::new(0, MatrixKind::Gate);
        let requests = vec![PlanRequest::new(
            id,
            vec![Chunk::new(0, 8), Chunk::new(20, 5), Chunk::new(40, 16)],
        )];
        let plan = planner.plan(&s.layout, &requests, None);
        let want = flat.submit(&plan).unwrap();
        for devices in [1usize, 2, 4] {
            let pool = nano_pool(&s, &image, devices, StripePolicy::RoundRobin);
            let mut sharded = ShardedPlan::default();
            planner.shard_into(&plan, pool.stripe(), &mut sharded);
            assert_eq!(sharded.total_bytes() as u64, plan.cmd_bytes());
            let mut receipt = PlanReceipt::default();
            let mut staging = Vec::new();
            let mut stats = PoolStats::default();
            pool.submit_sharded_into(&plan, &sharded, &mut staging, &mut receipt, &mut stats)
                .unwrap();
            assert_eq!(receipt.bytes, want.bytes, "devices={devices}");
            assert_eq!(receipt.cmd_offsets, want.cmd_offsets);
            assert_eq!(stats.total_bytes(), plan.cmd_bytes());
            assert_eq!(receipt.service, stats.max_service());
            if devices == 1 {
                assert_eq!(sharded.shards[0].cmds.as_slice(), plan.cmds());
            }
        }
    }

    #[test]
    fn real_file_pool_round_trips() {
        use std::io::Write;
        let s = store();
        let image = s.build_image();
        let stripe = StripeLayout::build(&s.layout, 2, StripePolicy::RoundRobin, None);
        let shards = stripe.shard_image(&image);
        let paths: Vec<std::path::PathBuf> = shards
            .iter()
            .enumerate()
            .map(|(m, data)| {
                let path = std::env::temp_dir()
                    .join(format!("nc_pool_test_{}_{m}", std::process::id()));
                let mut f = std::fs::File::create(&path).unwrap();
                f.write_all(data).unwrap();
                path
            })
            .collect();
        let pool = DevicePool::from_files(&paths, stripe, 2, false).unwrap();
        assert!(!pool.is_virtual_time(), "file pool is wall-clock");
        let extents = [Extent::new(3, 50), Extent::new(9000, 3000)];
        let (bytes, _) = pool.read_batch_vec(&extents).unwrap();
        let mut want = Vec::new();
        for e in &extents {
            want.extend_from_slice(&image[e.offset as usize..e.end() as usize]);
        }
        assert_eq!(bytes, want);
        // The planned path reassembles identically through the parallel
        // fan-out too.
        let planner = IoPlanner::new(CoalescePolicy::contiguous());
        let id = MatrixId::new(1, MatrixKind::Down);
        let plan = planner.plan_chunks(&s.layout, id, &[Chunk::new(2, 30)], None);
        let mut sharded = ShardedPlan::default();
        planner.shard_into(&plan, pool.stripe(), &mut sharded);
        let mut receipt = PlanReceipt::default();
        let mut staging = Vec::new();
        let mut stats = PoolStats::default();
        pool.submit_sharded_into(&plan, &sharded, &mut staging, &mut receipt, &mut stats)
            .unwrap();
        let flat = SimulatedSsd::with_image(DeviceProfile::nano(), image.clone(), 5);
        assert_eq!(receipt.bytes, flat.submit(&plan).unwrap().bytes);
        for p in paths {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn estimate_sharded_is_max_over_members() {
        use crate::storage::{ProfileConfig, Profiler};
        let s = store();
        let image = s.build_image();
        let stripe = StripeLayout::build(&s.layout, 2, StripePolicy::RoundRobin, None);
        let profiles = vec![DeviceProfile::nano(), DeviceProfile::agx()];
        let pool = DevicePool::simulated(&profiles, stripe, &image, 9).unwrap();
        let planner = IoPlanner::new(CoalescePolicy::contiguous());
        let id = MatrixId::new(0, MatrixKind::Down);
        let plan = planner.plan_chunks(&s.layout, id, &[Chunk::new(0, 64)], None);
        let mut sharded = ShardedPlan::default();
        planner.shard_into(&plan, pool.stripe(), &mut sharded);
        // No tables attached -> no estimate.
        assert_eq!(pool.estimate_sharded(&sharded), 0.0);
        // With per-member tables: the slowest member's Σ T_m.
        let tables: Vec<LatencyTable> = profiles
            .iter()
            .map(|p| {
                let probe = SimulatedSsd::timing_only(p.clone(), 1 << 40, 5);
                Profiler::new(&probe, ProfileConfig::coarse(p.saturation_bytes(0.99), 1024))
                    .build_table()
                    .unwrap()
            })
            .collect();
        let pool = pool.with_tables(tables.clone());
        assert_eq!(pool.member_table(0).unwrap().max_bytes(), tables[0].max_bytes());
        let want = (0..2)
            .map(|m| {
                sharded.shards[m]
                    .cmds
                    .iter()
                    .map(|c| tables[m].latency_bytes(c.len))
                    .sum::<f64>()
            })
            .fold(0.0f64, f64::max);
        let got = pool.estimate_sharded(&sharded);
        assert!(got > 0.0);
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }

    #[test]
    fn pool_stats_accounting() {
        let mut a = PoolStats::default();
        a.reset(2);
        a.bytes[0] = 100;
        a.service[0] = Duration::from_millis(4);
        a.service[1] = Duration::from_millis(1);
        assert_eq!(a.max_service(), Duration::from_millis(4));
        assert!((a.utilization_skew() - 1.6).abs() < 1e-9);
        let mut b = PoolStats::default();
        b.reset(2);
        b.bytes[1] = 50;
        b.service[1] = Duration::from_millis(3);
        a.absorb(&b);
        assert_eq!(a.bytes, vec![100, 50]);
        assert_eq!(a.service[1], Duration::from_millis(4));
    }

    #[test]
    fn member_capacity_checked() {
        let s = store();
        let stripe = StripeLayout::build(&s.layout, 2, StripePolicy::RoundRobin, None);
        let members: Vec<Box<dyn FlashDevice>> = (0..2)
            .map(|m| {
                Box::new(SimulatedSsd::timing_only(DeviceProfile::nano(), 16, m))
                    as Box<dyn FlashDevice>
            })
            .collect();
        assert!(DevicePool::new("tiny-pool", members, stripe).is_err());
    }

    #[test]
    fn utilization_skew_never_nan() {
        // Empty pool (no members yet) and zero-byte / zero-service
        // submissions must report a defined, neutral skew of 1.0.
        let empty = PoolStats::default();
        assert_eq!(empty.utilization_skew(), 1.0);
        let mut zero = PoolStats::default();
        zero.reset(4);
        let skew = zero.utilization_skew();
        assert!(!skew.is_nan(), "skew must be defined for zero-byte submissions");
        assert_eq!(skew, 1.0);
    }

    #[test]
    fn replicated_stripe_layout_invariants() {
        let s = store();
        for devices in [2usize, 3, 4] {
            for policy in [StripePolicy::RoundRobin, StripePolicy::HotAware] {
                let r1 = StripeLayout::build(&s.layout, devices, policy, None);
                let r2 = StripeLayout::build_replicated(&s.layout, devices, policy, None, 2);
                assert_eq!(r2.replication(), 2);
                // Primary placement is untouched by replication.
                assert_eq!(r1.starts, r2.starts);
                assert_eq!(r1.device, r2.device);
                // Replicas add bytes beyond the flat total.
                let extra: u64 = r2.device_bytes().iter().sum::<u64>() - s.layout.total_bytes();
                assert!(extra > 0, "replication must place extra copies");
                // Every piece is held by its primary plus (for hot
                // blocks) a distinct second member, each within bounds.
                let whole = Extent::new(0, s.layout.total_bytes() as usize);
                let mut hot_pieces = 0usize;
                r2.for_pieces_all(whole, |_, options| {
                    assert!(!options.is_empty() && options.len() <= 2);
                    let mut seen = std::collections::HashSet::new();
                    for &(m, local) in options {
                        assert!(m < devices);
                        assert!(local.end() <= r2.device_bytes()[m]);
                        assert!(seen.insert(m), "copies on distinct members");
                    }
                    if options.len() == 2 {
                        hot_pieces += 1;
                    }
                });
                assert!(hot_pieces > 0, "hot heads must be replicated");
            }
        }
    }

    #[test]
    fn replicated_shard_image_copies_are_identical() {
        let s = store();
        let image = s.build_image();
        let stripe = StripeLayout::build_replicated(
            &s.layout,
            4,
            StripePolicy::HotAware,
            None,
            2,
        );
        let shards = stripe.shard_image(&image);
        let whole = Extent::new(0, image.len());
        stripe.for_pieces_all(whole, |flat, options| {
            let want = &image[flat as usize..flat as usize + options[0].1.len];
            for &(m, local) in options {
                assert_eq!(
                    &shards[m][local.offset as usize..local.end() as usize],
                    want,
                    "replica bytes must be identical"
                );
            }
        });
    }

    #[test]
    fn quantized_images_shard_losslessly() {
        // Striping is dtype-agnostic byte plumbing: an fp16/int8 encoded
        // image (scales inline in each row) shards and reassembles with
        // every flat byte at its mapped member-local address.
        for dtype in [crate::model::DType::F16, crate::model::DType::Int8] {
            let s = WeightStore::with_dtype(ModelSpec::tiny(), false, 42, dtype);
            let image = s.build_image();
            let stripe = StripeLayout::build(&s.layout, 3, StripePolicy::RoundRobin, None);
            let shards = stripe.shard_image(&image);
            let whole = Extent::new(0, image.len());
            stripe.for_pieces_all(whole, |flat, options| {
                let want = &image[flat as usize..flat as usize + options[0].1.len];
                for &(m, local) in options {
                    assert_eq!(
                        &shards[m][local.offset as usize..local.end() as usize],
                        want,
                        "{dtype:?} shard bytes diverged from the flat image"
                    );
                }
            });
        }
    }

    #[test]
    fn replication_one_covered_only_without_dead_members() {
        let s = store();
        let stripe = StripeLayout::build(&s.layout, 4, StripePolicy::RoundRobin, None);
        let whole = [Extent::new(0, s.layout.total_bytes() as usize)];
        assert!(stripe.covered_without(&whole, &[false, false, false, false]));
        assert!(!stripe.covered_without(&whole, &[false, true, false, false]));
        // Replication 2: any single death keeps hot heads covered...
        let stripe2 =
            StripeLayout::build_replicated(&s.layout, 4, StripePolicy::RoundRobin, None, 2);
        let mut hot_extent = None;
        stripe2.for_pieces_all(whole[0], |flat, options| {
            if options.len() == 2 && hot_extent.is_none() {
                hot_extent = Some(Extent::new(flat, options[0].1.len));
            }
        });
        let hot = [hot_extent.expect("replicated stripe has hot pieces")];
        for dead in 0..4 {
            let mut flags = [false; 4];
            flags[dead] = true;
            assert!(stripe2.covered_without(&hot, &flags), "hot piece survives {dead}");
        }
        // ...while a whole-space scan still needs every member (cold
        // tails are single-copy).
        assert!(!stripe2.covered_without(&whole, &[true, false, false, false]));
    }

    #[test]
    fn routed_sharding_reassembles_identically() {
        let s = store();
        let image = s.build_image();
        let flat = SimulatedSsd::with_image(DeviceProfile::nano(), image.clone(), 5);
        let planner = IoPlanner::new(CoalescePolicy::contiguous());
        let id = MatrixId::new(0, MatrixKind::Gate);
        let requests = vec![PlanRequest::new(
            id,
            vec![Chunk::new(0, 8), Chunk::new(20, 5), Chunk::new(40, 16)],
        )];
        let plan = planner.plan(&s.layout, &requests, None);
        let want = flat.submit(&plan).unwrap();
        for devices in [2usize, 4] {
            let stripe = StripeLayout::build_replicated(
                &s.layout,
                devices,
                StripePolicy::HotAware,
                None,
                2,
            );
            let pool = DevicePool::simulated(
                &vec![DeviceProfile::nano(); devices],
                stripe,
                &image,
                7,
            )
            .unwrap();
            assert!(pool.needs_routing());
            let mut sharded = ShardedPlan::default();
            pool.route_plan(&plan, &mut sharded);
            assert_eq!(sharded.total_bytes() as u64, plan.cmd_bytes());
            let mut receipt = PlanReceipt::default();
            let mut staging = Vec::new();
            let mut stats = PoolStats::default();
            pool.submit_sharded_into(&plan, &sharded, &mut staging, &mut receipt, &mut stats)
                .unwrap();
            assert_eq!(receipt.bytes, want.bytes, "devices={devices}");
            assert_eq!(receipt.cmd_offsets, want.cmd_offsets);
        }
    }

    #[test]
    fn dead_member_fails_over_to_replica() {
        use crate::storage::{FaultConfig, FaultInjector};
        let s = store();
        let image = s.build_image();
        let stripe =
            StripeLayout::build_replicated(&s.layout, 2, StripePolicy::RoundRobin, None, 2);
        // Healthy reference pool with the same stripe.
        let healthy = DevicePool::simulated(
            &vec![DeviceProfile::nano(); 2],
            stripe.clone(),
            &image,
            7,
        )
        .unwrap();
        let mut pool = DevicePool::simulated(
            &vec![DeviceProfile::nano(); 2],
            stripe,
            &image,
            7,
        )
        .unwrap();
        pool.wrap_members(|m, d| {
            if m == 1 {
                Arc::new(FaultInjector::new(d, FaultConfig { dead: true, ..Default::default() }))
            } else {
                d
            }
        });
        let planner = IoPlanner::new(CoalescePolicy::contiguous());
        let id = MatrixId::new(0, MatrixKind::Gate);
        // The whole hot half of the matrix: spans both members' hot
        // blocks (so the dead member is actually hit) while staying
        // replica-covered.
        let rows = ModelSpec::tiny()
            .matrices()
            .iter()
            .find(|m| m.kind == MatrixKind::Gate)
            .unwrap()
            .rows;
        let plan = planner.plan_chunks(&s.layout, id, &[Chunk::new(0, rows / 2)], None);
        let mut sharded = ShardedPlan::default();
        planner.shard_into(&plan, pool.stripe(), &mut sharded);
        let mut receipt = PlanReceipt::default();
        let mut staging = Vec::new();
        let mut stats = PoolStats::default();
        pool.submit_sharded_into(&plan, &sharded, &mut staging, &mut receipt, &mut stats)
            .unwrap();
        let mut want = PlanReceipt::default();
        let mut wstag = Vec::new();
        let mut wstats = PoolStats::default();
        healthy
            .submit_sharded_into(&plan, &sharded, &mut wstag, &mut want, &mut wstats)
            .unwrap();
        assert_eq!(receipt.bytes, want.bytes, "failover must be bit-identical");
        let h = pool.health().snapshot();
        assert_eq!(h.dead_members, vec![1]);
        assert!(h.failovers >= 1, "failover counter must tick");
        assert!(h.retries >= 1, "retries precede failover");
    }
}
