//! Sharded multi-device storage pool.
//!
//! Production edge/serving boxes stripe model weights across several
//! flash devices or NVMe namespaces; once per-device access cost is
//! modeled (the paper's `T[s]`), *inter-device* parallelism is the
//! remaining lever on top of the paper's *intra-device* contiguity
//! model. This module supplies that layer:
//!
//! * [`StripeLayout`] maps the flat weight address space of a
//!   [`FlashLayout`] onto N member devices. Striping is **chunk-granular
//!   and row-aligned**: stripe blocks never split a weight row, and the
//!   unit is sized to the scale of selection chunks (adaptive
//!   `rows/(4·N)` per matrix by default, or an explicit byte size), so a
//!   selected chunk maps to one member in the common case and at most a
//!   handful at the boundaries — never the page-granular shredding of
//!   classic RAID striping, which would destroy the contiguity the
//!   whole system is built around.
//! * [`DevicePool`] owns the members (each a [`FlashDevice`] with its
//!   own profile and `T[s]` table) and serves logical plans: a
//!   [`crate::plan::ShardedPlan`] (built by
//!   [`crate::plan::IoPlanner::shard_into`]) is fanned out across
//!   members and reassembled into the *logical* [`PlanReceipt`] —
//!   byte-identical to a single-device submission. Service time is the
//!   **max over members** (devices work in parallel), and per-member
//!   bytes/latency are reported through [`PoolStats`] so utilization
//!   skew is observable.
//!
//! Fan-out strategy: members whose service time is a *virtual* clock
//! ([`crate::storage::SimulatedSsd`]) are submitted serially — an
//! analytical clock cannot tell the difference, the max-over-members
//! aggregation is exact either way, and the serving hot path stays
//! allocation-free. Pools with any wall-clock member
//! ([`crate::storage::RealFileDevice`]) fan out with
//! `std::thread::scope`, one thread per member with a non-empty
//! sub-plan.

use std::sync::Arc;
use std::time::Duration;

use crate::latency::LatencyTable;
use crate::model::FlashLayout;
use crate::plan::{DeviceSubPlan, PlanReceipt, ReadPlan, ShardedPlan};
use crate::storage::{DeviceProfile, Extent, FlashDevice, RealFileDevice, SimulatedSsd};

/// How stripe blocks are assigned to pool members.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StripePolicy {
    /// Block `b` of every matrix region goes to member `b % N`. Simple
    /// and balanced by volume, but after a hot–cold reorder every
    /// matrix's hottest rows (the low block indices) pile onto member 0.
    RoundRobin,
    /// Layout-aware: each matrix's hot head (its first `⌈blocks/N⌉`
    /// stripe blocks — the hottest rows once the reorder permutation is
    /// baked in) is co-located on one member, staggered per matrix
    /// (`region_seq % N`), so hot traffic spreads across members while
    /// staying intra-member contiguous. Cold tails round-robin.
    HotAware,
}

/// Chunk-granular mapping of the flat weight address space onto pool
/// members.
///
/// Invariants (property-tested):
/// * blocks tile `[0, total_bytes)` exactly, in flat-address order;
/// * every block boundary is a row boundary of its matrix region (a
///   weight row never straddles members — with `align_rows` layouts
///   this also keeps sharded commands page-aligned);
/// * each member's blocks are assigned disjoint, densely-packed
///   device-local ranges, so member images partition the flat image.
#[derive(Clone, Debug)]
pub struct StripeLayout {
    devices: usize,
    /// Flat start offset per block, ascending; block `b` ends where
    /// block `b+1` starts (the last ends at `total`).
    starts: Vec<u64>,
    /// Owning member per block.
    device: Vec<u32>,
    /// Device-local start offset per block.
    local: Vec<u64>,
    /// Total bytes assigned to each member.
    device_bytes: Vec<u64>,
    total: u64,
}

impl StripeLayout {
    /// Build a stripe map for `devices` members over `layout`.
    ///
    /// `stripe_bytes = None` sizes blocks adaptively per matrix
    /// (`⌈rows / (4·devices)⌉` rows) so every matrix stripes across all
    /// members regardless of its size; `Some(b)` uses `max(1, b /
    /// row_bytes)` rows per block (production-scale, chunk-granular
    /// units).
    pub fn build(
        layout: &FlashLayout,
        devices: usize,
        policy: StripePolicy,
        stripe_bytes: Option<usize>,
    ) -> Self {
        let devices = devices.max(1);
        let mut starts = Vec::new();
        let mut device = Vec::new();
        let mut local = Vec::new();
        let mut device_bytes = vec![0u64; devices];
        for (seq, (_id, base, row_bytes, rows)) in
            layout.regions_in_order().into_iter().enumerate()
        {
            let stripe_rows = match stripe_bytes {
                Some(b) => (b / row_bytes).max(1),
                None => rows.div_ceil(devices * 4).max(1),
            };
            let nblocks = rows.div_ceil(stripe_rows);
            let hot = nblocks.div_ceil(devices);
            for b in 0..nblocks {
                let dev = match policy {
                    StripePolicy::RoundRobin => b % devices,
                    StripePolicy::HotAware => {
                        if b < hot {
                            seq % devices
                        } else {
                            (seq + b) % devices
                        }
                    }
                };
                let row0 = b * stripe_rows;
                let nrows = stripe_rows.min(rows - row0);
                let len = (nrows * row_bytes) as u64;
                starts.push(base + (row0 * row_bytes) as u64);
                device.push(dev as u32);
                local.push(device_bytes[dev]);
                device_bytes[dev] += len;
            }
        }
        Self {
            devices,
            starts,
            device,
            local,
            device_bytes,
            total: layout.total_bytes(),
        }
    }

    pub fn devices(&self) -> usize {
        self.devices
    }

    pub fn num_blocks(&self) -> usize {
        self.starts.len()
    }

    pub fn total_bytes(&self) -> u64 {
        self.total
    }

    /// Bytes assigned to each member (sums to `total_bytes`).
    pub fn device_bytes(&self) -> &[u64] {
        &self.device_bytes
    }

    fn block_of(&self, offset: u64) -> usize {
        match self.starts.binary_search(&offset) {
            Ok(i) => i,
            Err(i) => i - 1,
        }
    }

    /// Owning member of a flat byte offset.
    pub fn device_of(&self, offset: u64) -> usize {
        self.device[self.block_of(offset)] as usize
    }

    /// Split a flat extent at stripe boundaries, emitting
    /// `(member, device-local extent, flat offset of the piece)` in flat
    /// order. Allocation-free.
    pub fn for_pieces(&self, extent: Extent, mut f: impl FnMut(usize, Extent, u64)) {
        if extent.len == 0 {
            return;
        }
        debug_assert!(extent.end() <= self.total, "extent beyond stripe map");
        let mut off = extent.offset;
        let end = extent.end();
        let mut b = self.block_of(off);
        while off < end {
            let block_end = if b + 1 < self.starts.len() {
                self.starts[b + 1]
            } else {
                self.total
            };
            let take = block_end.min(end) - off;
            let local = self.local[b] + (off - self.starts[b]);
            f(self.device[b] as usize, Extent::new(local, take as usize), off);
            off += take;
            b += 1;
        }
    }

    /// Partition a flat flash image into per-member images
    /// (device-local address space).
    pub fn shard_image(&self, flat: &[u8]) -> Vec<Vec<u8>> {
        assert_eq!(flat.len() as u64, self.total, "image / layout size mismatch");
        let mut out: Vec<Vec<u8>> = self
            .device_bytes
            .iter()
            .map(|&b| vec![0u8; b as usize])
            .collect();
        for b in 0..self.starts.len() {
            let start = self.starts[b] as usize;
            let end = if b + 1 < self.starts.len() {
                self.starts[b + 1] as usize
            } else {
                flat.len()
            };
            let dev = self.device[b] as usize;
            let local = self.local[b] as usize;
            out[dev][local..local + (end - start)].copy_from_slice(&flat[start..end]);
        }
        out
    }
}

/// Per-member bytes and service time of pooled submissions. `reset` per
/// submit, `absorb` to accumulate across a call; all buffers reusable
/// (allocation-free at steady state once reserved).
#[derive(Clone, Debug, Default)]
pub struct PoolStats {
    pub bytes: Vec<u64>,
    pub service: Vec<Duration>,
}

impl PoolStats {
    pub fn reset(&mut self, devices: usize) {
        self.bytes.clear();
        self.bytes.resize(devices, 0);
        self.service.clear();
        self.service.resize(devices, Duration::ZERO);
    }

    pub fn reserve(&mut self, devices: usize) {
        self.bytes.reserve(devices);
        self.service.reserve(devices);
    }

    /// Accumulate another submission's stats into this one.
    pub fn absorb(&mut self, other: &PoolStats) {
        if self.bytes.len() < other.bytes.len() {
            self.bytes.resize(other.bytes.len(), 0);
            self.service.resize(other.service.len(), Duration::ZERO);
        }
        for (a, &b) in self.bytes.iter_mut().zip(&other.bytes) {
            *a += b;
        }
        for (a, &b) in self.service.iter_mut().zip(&other.service) {
            *a += b;
        }
    }

    /// Pool service time: the slowest member (devices work in parallel).
    pub fn max_service(&self) -> Duration {
        self.service.iter().max().copied().unwrap_or_default()
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes.iter().sum()
    }

    /// Utilization skew: max member service over mean member service
    /// (1.0 = perfectly balanced; N = one member did all the work).
    pub fn utilization_skew(&self) -> f64 {
        let n = self.service.len();
        if n == 0 {
            return 1.0;
        }
        let max = self.max_service().as_secs_f64();
        let mean = self.service.iter().map(|d| d.as_secs_f64()).sum::<f64>() / n as f64;
        if mean <= 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

/// Reusable working memory for pooled submissions: the sharded plan,
/// per-member staging receipts, the last submission's [`PoolStats`] and
/// a per-call accumulator. Lives in the session's scratch arena so the
/// pooled hot path stays allocation-free.
#[derive(Clone, Debug, Default)]
pub struct PoolScratch {
    pub sharded: ShardedPlan,
    pub staging: Vec<PlanReceipt>,
    /// Stats of the most recent submission.
    pub last: PoolStats,
    /// Accumulated stats across a serving call (reset per call).
    pub accum: PoolStats,
}

impl PoolScratch {
    /// Pre-reserve worst-case capacity: `cmds` commands and `bytes`
    /// staging bytes per member.
    pub fn reserve(&mut self, devices: usize, cmds: usize, bytes: usize) {
        self.sharded.reserve(devices, cmds);
        if self.staging.len() < devices {
            self.staging.resize_with(devices, Default::default);
        }
        for st in &mut self.staging {
            st.reserve(bytes, cmds);
        }
        self.last.reserve(devices);
        self.accum.reserve(devices);
    }
}

/// Raw pointer wrapper that is Send/Sync (disjoint-range writes only).
struct SendPtr(*mut u8);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// A pool of N flash devices behind one flat address space.
///
/// Implements [`FlashDevice`] over the *flat* space (capacity =
/// `StripeLayout::total_bytes`), so planner-backed cold paths
/// ([`crate::model::WeightStore::read_rows`], the profiler) work
/// unchanged; the serving hot path uses [`DevicePool::submit_sharded_into`]
/// with caller-owned scratch instead.
pub struct DevicePool {
    name: String,
    /// `Arc` rather than `Box`: the async I/O workers
    /// ([`crate::storage::AsyncIoQueue`]) hold shared references to the
    /// members they serve, outliving any single submission.
    members: Vec<Arc<dyn FlashDevice>>,
    /// Per-member profiled `T[s]` (absent for members built without one).
    tables: Vec<Option<LatencyTable>>,
    stripe: StripeLayout,
    /// Fan out with scoped threads (any wall-clock member) vs the exact
    /// serial path (all-virtual-clock members; keeps the hot path
    /// allocation-free).
    parallel: bool,
}

impl DevicePool {
    pub fn new(
        name: &str,
        members: Vec<Box<dyn FlashDevice>>,
        stripe: StripeLayout,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(!members.is_empty(), "pool needs at least one member");
        anyhow::ensure!(
            members.len() == stripe.devices(),
            "pool has {} members but stripe maps {}",
            members.len(),
            stripe.devices()
        );
        for (m, member) in members.iter().enumerate() {
            anyhow::ensure!(
                member.capacity() >= stripe.device_bytes()[m],
                "member {m} ({}) holds {} < assigned {}",
                member.name(),
                member.capacity(),
                stripe.device_bytes()[m]
            );
        }
        let parallel = !members.iter().all(|m| m.is_virtual_time());
        let tables = members.iter().map(|_| None).collect();
        Ok(Self {
            name: name.to_string(),
            members: members.into_iter().map(Arc::from).collect(),
            tables,
            stripe,
            parallel,
        })
    }

    /// Attach per-member latency tables (one per member, in order).
    pub fn with_tables(mut self, tables: Vec<LatencyTable>) -> Self {
        assert_eq!(tables.len(), self.members.len());
        self.tables = tables.into_iter().map(Some).collect();
        self
    }

    /// Homogeneous-or-heterogeneous simulated pool: one
    /// [`SimulatedSsd`] member per profile, each backed by its shard of
    /// `image`. Member `m` is seeded `seed ^ (m · φ64)` so member 0 of a
    /// 1-member pool reproduces the historical single-device stream.
    pub fn simulated(
        profiles: &[DeviceProfile],
        stripe: StripeLayout,
        image: &[u8],
        seed: u64,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            profiles.len() == stripe.devices(),
            "{} profiles for {} stripe members",
            profiles.len(),
            stripe.devices()
        );
        let shards = stripe.shard_image(image);
        let members: Vec<Box<dyn FlashDevice>> = shards
            .into_iter()
            .zip(profiles)
            .enumerate()
            .map(|(m, (img, p))| {
                Box::new(SimulatedSsd::with_image(
                    p.clone(),
                    img,
                    seed ^ (m as u64).wrapping_mul(0x9E3779B97F4A7C15),
                )) as Box<dyn FlashDevice>
            })
            .collect();
        Self::new("pool", members, stripe)
    }

    /// Real-storage pool: one backing file per member (each holding that
    /// member's device-local image, e.g. written from
    /// [`StripeLayout::shard_image`]).
    pub fn from_files(
        paths: &[std::path::PathBuf],
        stripe: StripeLayout,
        threads: usize,
        direct: bool,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            paths.len() == stripe.devices(),
            "{} files for {} stripe members",
            paths.len(),
            stripe.devices()
        );
        let members = paths
            .iter()
            .map(|p| {
                RealFileDevice::open(p, threads, direct)
                    .map(|d| Box::new(d) as Box<dyn FlashDevice>)
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Self::new("pool-files", members, stripe)
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    pub fn member(&self, m: usize) -> &dyn FlashDevice {
        self.members[m].as_ref()
    }

    /// Shared handle to one member (what async I/O workers hold).
    pub fn member_arc(&self, m: usize) -> Arc<dyn FlashDevice> {
        self.members[m].clone()
    }

    /// Shared handles to every member, in order.
    pub fn member_arcs(&self) -> Vec<Arc<dyn FlashDevice>> {
        self.members.clone()
    }

    pub fn member_table(&self, m: usize) -> Option<&LatencyTable> {
        self.tables.get(m).and_then(|t| t.as_ref())
    }

    pub fn stripe(&self) -> &StripeLayout {
        &self.stripe
    }

    /// Pool-aware plan estimate: service time is the slowest member, so
    /// the estimate is the max over members of `Σ T_m[bytes(cmd)]` under
    /// each member's own table. 0.0 when no tables are attached.
    pub fn estimate_sharded(&self, sharded: &ShardedPlan) -> f64 {
        let mut worst = 0.0f64;
        for (m, shard) in sharded.shards.iter().enumerate() {
            if let Some(t) = self.member_table(m) {
                let est: f64 = shard.cmds.iter().map(|c| t.latency_bytes(c.len)).sum();
                worst = worst.max(est);
            }
        }
        worst
    }

    /// Submit a pre-sharded logical plan: fan the per-member sub-plans
    /// out across members, reassemble the *logical* receipt (bytes in
    /// logical command order — bit-identical to a single-device
    /// submission), report service as the max over members, and record
    /// per-member bytes/latency into `stats`.
    ///
    /// Allocation-free at steady state: `staging` receipts and `stats`
    /// vectors reuse their capacity (pool them in a
    /// [`PoolScratch`]). Logical submission batches are not preserved —
    /// each member receives its sub-plan as one deep batch (the serving
    /// coalesce policy submits one batch anyway).
    pub fn submit_sharded_into(
        &self,
        plan: &ReadPlan,
        sharded: &ShardedPlan,
        staging: &mut Vec<PlanReceipt>,
        receipt: &mut PlanReceipt,
        stats: &mut PoolStats,
    ) -> anyhow::Result<()> {
        let n = self.members.len();
        anyhow::ensure!(
            sharded.shards.len() == n,
            "sharded plan has {} shards for {} members",
            sharded.shards.len(),
            n
        );
        let total = receipt.presize_for(plan.cmds());
        anyhow::ensure!(
            sharded.total_bytes() == total,
            "sharded plan covers {} of {} plan bytes",
            sharded.total_bytes(),
            total
        );
        if staging.len() < n {
            staging.resize_with(n, Default::default);
        }
        stats.reset(n);
        receipt.service = self.fan_out(&sharded.shards, staging, &mut receipt.bytes, stats)?;
        Ok(())
    }

    /// Run every member's sub-plan, scattering the data into the logical
    /// output buffer (`dsts` are disjoint by construction). Returns the
    /// max member service time.
    fn fan_out(
        &self,
        shards: &[DeviceSubPlan],
        staging: &mut [PlanReceipt],
        out: &mut [u8],
        stats: &mut PoolStats,
    ) -> anyhow::Result<Duration> {
        let mut max = Duration::ZERO;
        if !self.parallel {
            // Serial exact path: members report virtual clocks, so
            // concurrency cannot change the outcome; max-over-members is
            // computed directly and no thread is spawned (the pooled
            // serving hot path stays allocation-free).
            for (m, shard) in shards.iter().enumerate() {
                if shard.cmds.is_empty() {
                    continue;
                }
                let st = &mut staging[m];
                st.clear();
                let b = shard.bytes();
                st.bytes.resize(b, 0);
                let d = self.members[m].read_batch(&shard.cmds, &mut st.bytes)?;
                let mut sat = 0usize;
                for (e, &dst) in shard.cmds.iter().zip(&shard.dsts) {
                    out[dst..dst + e.len].copy_from_slice(&st.bytes[sat..sat + e.len]);
                    sat += e.len;
                }
                stats.bytes[m] = b as u64;
                stats.service[m] = d;
                max = max.max(d);
            }
            return Ok(max);
        }

        // Wall-clock members: one scoped thread per member with a
        // non-empty sub-plan, each reading into its own staging buffer
        // and scattering to disjoint ranges of the shared output.
        let out_len = out.len();
        let out_ptr = SendPtr(out.as_mut_ptr());
        let mut err: Option<anyhow::Error> = None;
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for (m, (shard, st)) in shards.iter().zip(staging.iter_mut()).enumerate() {
                if shard.cmds.is_empty() {
                    continue;
                }
                let member = &self.members[m];
                let out_ptr = &out_ptr;
                handles.push((
                    m,
                    scope.spawn(move || -> anyhow::Result<(u64, Duration)> {
                        st.clear();
                        let b = shard.bytes();
                        st.bytes.resize(b, 0);
                        let d = member.read_batch(&shard.cmds, &mut st.bytes)?;
                        let mut sat = 0usize;
                        for (e, &dst) in shard.cmds.iter().zip(&shard.dsts) {
                            debug_assert!(dst + e.len <= out_len);
                            // SAFETY: members scatter to disjoint
                            // [dst, dst+len) ranges (the shard step
                            // partitions every logical command).
                            let slice = unsafe {
                                std::slice::from_raw_parts_mut(out_ptr.0.add(dst), e.len)
                            };
                            slice.copy_from_slice(&st.bytes[sat..sat + e.len]);
                            sat += e.len;
                        }
                        Ok((b as u64, d))
                    }),
                ));
            }
            for (m, h) in handles {
                match h.join().expect("pool member thread panicked") {
                    Ok((b, d)) => {
                        stats.bytes[m] = b;
                        stats.service[m] = d;
                        max = max.max(d);
                    }
                    Err(e) => err = Some(e),
                }
            }
        });
        if let Some(e) = err {
            return Err(e);
        }
        Ok(max)
    }
}

impl FlashDevice for DevicePool {
    fn name(&self) -> &str {
        &self.name
    }

    fn capacity(&self) -> u64 {
        self.stripe.total_bytes()
    }

    fn is_virtual_time(&self) -> bool {
        self.members.iter().all(|m| m.is_virtual_time())
    }

    /// Flat-space batched read (cold paths; allocates working memory).
    /// Service time is the max over members.
    fn read_batch(&self, extents: &[Extent], out: &mut [u8]) -> anyhow::Result<Duration> {
        let total: usize = extents.iter().map(|e| e.len).sum();
        anyhow::ensure!(out.len() == total, "out buffer {} != {}", out.len(), total);
        if self.members.len() == 1 {
            return self.members[0].read_batch(extents, out);
        }
        for e in extents {
            anyhow::ensure!(
                e.end() <= self.stripe.total_bytes(),
                "extent {:?} beyond pool capacity {}",
                e,
                self.stripe.total_bytes()
            );
        }
        let n = self.members.len();
        let mut shards: Vec<DeviceSubPlan> = (0..n).map(|_| DeviceSubPlan::default()).collect();
        let mut at = 0usize;
        for e in extents {
            self.stripe.for_pieces(*e, |dev, local, flat| {
                shards[dev].push_piece(local, at + (flat - e.offset) as usize);
            });
            at += e.len;
        }
        let mut staging: Vec<PlanReceipt> = (0..n).map(|_| PlanReceipt::default()).collect();
        let mut stats = PoolStats::default();
        stats.reset(n);
        self.fan_out(&shards, &mut staging, out, &mut stats)
    }

    fn service_time(&self, extents: &[Extent]) -> anyhow::Result<Duration> {
        let total: usize = extents.iter().map(|e| e.len).sum();
        let mut scratch = vec![0u8; total];
        self.read_batch(extents, &mut scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latency::Chunk;
    use crate::model::{MatrixId, MatrixKind, ModelSpec, WeightStore};
    use crate::plan::{CoalescePolicy, IoPlanner, PlanRequest};

    fn store() -> WeightStore {
        WeightStore::new(ModelSpec::tiny(), false, 42)
    }

    fn nano_pool(
        store: &WeightStore,
        image: &[u8],
        devices: usize,
        policy: StripePolicy,
    ) -> DevicePool {
        let stripe = StripeLayout::build(&store.layout, devices, policy, None);
        DevicePool::simulated(&vec![DeviceProfile::nano(); devices], stripe, image, 7).unwrap()
    }

    #[test]
    fn stripe_blocks_tile_and_balance() {
        let s = store();
        for devices in [1usize, 2, 3, 4] {
            let stripe = StripeLayout::build(&s.layout, devices, StripePolicy::RoundRobin, None);
            assert_eq!(
                stripe.device_bytes().iter().sum::<u64>(),
                s.layout.total_bytes()
            );
            assert_eq!(stripe.devices(), devices);
            if devices > 1 {
                // Adaptive striping gives every member a non-trivial share.
                for (m, &b) in stripe.device_bytes().iter().enumerate() {
                    assert!(b > 0, "member {m} got no bytes");
                }
            }
        }
    }

    #[test]
    fn stripe_boundaries_are_row_aligned() {
        let s = store();
        let stripe = StripeLayout::build(&s.layout, 4, StripePolicy::RoundRobin, None);
        for (id, base, row_bytes, rows) in s.layout.regions_in_order() {
            let _ = id;
            let end = base + (rows * row_bytes) as u64;
            for &start in &stripe.starts {
                if start > base && start < end {
                    assert_eq!(
                        ((start - base) as usize) % row_bytes,
                        0,
                        "block boundary splits a row"
                    );
                }
            }
        }
    }

    #[test]
    fn pieces_reassemble_extents() {
        let s = store();
        let stripe = StripeLayout::build(&s.layout, 3, StripePolicy::HotAware, Some(2048));
        let extent = Extent::new(100, 9000);
        let mut covered = 0usize;
        let mut next_flat = extent.offset;
        stripe.for_pieces(extent, |dev, local, flat| {
            assert!(dev < 3);
            assert_eq!(flat, next_flat, "pieces out of order or gapped");
            assert!(local.end() <= stripe.device_bytes()[dev]);
            covered += local.len;
            next_flat += local.len as u64;
        });
        assert_eq!(covered, extent.len);
    }

    #[test]
    fn hot_aware_staggers_region_heads() {
        let s = store();
        let stripe = StripeLayout::build(&s.layout, 4, StripePolicy::HotAware, None);
        let heads: Vec<usize> = s
            .layout
            .regions_in_order()
            .iter()
            .map(|&(_, base, _, _)| stripe.device_of(base))
            .collect();
        // Consecutive matrices' hot heads land on different members.
        assert!(heads.windows(2).any(|w| w[0] != w[1]));
        let distinct: std::collections::HashSet<usize> = heads.iter().copied().collect();
        assert_eq!(distinct.len(), 4, "hot heads should cover all members");
    }

    #[test]
    fn round_robin_piles_heads_on_member_zero() {
        let s = store();
        let stripe = StripeLayout::build(&s.layout, 4, StripePolicy::RoundRobin, None);
        for (_, base, _, _) in s.layout.regions_in_order() {
            assert_eq!(stripe.device_of(base), 0);
        }
    }

    #[test]
    fn pool_read_batch_matches_flat_image() {
        let s = store();
        let image = s.build_image();
        for devices in [1usize, 2, 4] {
            for policy in [StripePolicy::RoundRobin, StripePolicy::HotAware] {
                let pool = nano_pool(&s, &image, devices, policy);
                let extents = [
                    Extent::new(10, 100),
                    Extent::new(5000, 2000),
                    Extent::new(image.len() as u64 - 64, 64),
                ];
                let (bytes, t) = pool.read_batch_vec(&extents).unwrap();
                let mut want = Vec::new();
                for e in &extents {
                    want.extend_from_slice(&image[e.offset as usize..e.end() as usize]);
                }
                assert_eq!(bytes, want, "devices={devices} policy={policy:?}");
                assert!(t > Duration::ZERO);
            }
        }
    }

    #[test]
    fn sharded_submit_reassembles_logical_receipt() {
        let s = store();
        let image = s.build_image();
        let flat = SimulatedSsd::with_image(DeviceProfile::nano(), image.clone(), 5);
        let planner = IoPlanner::new(CoalescePolicy::contiguous());
        let id = MatrixId::new(0, MatrixKind::Gate);
        let requests = vec![PlanRequest::new(
            id,
            vec![Chunk::new(0, 8), Chunk::new(20, 5), Chunk::new(40, 16)],
        )];
        let plan = planner.plan(&s.layout, &requests, None);
        let want = flat.submit(&plan).unwrap();
        for devices in [1usize, 2, 4] {
            let pool = nano_pool(&s, &image, devices, StripePolicy::RoundRobin);
            let mut sharded = ShardedPlan::default();
            planner.shard_into(&plan, pool.stripe(), &mut sharded);
            assert_eq!(sharded.total_bytes() as u64, plan.cmd_bytes());
            let mut receipt = PlanReceipt::default();
            let mut staging = Vec::new();
            let mut stats = PoolStats::default();
            pool.submit_sharded_into(&plan, &sharded, &mut staging, &mut receipt, &mut stats)
                .unwrap();
            assert_eq!(receipt.bytes, want.bytes, "devices={devices}");
            assert_eq!(receipt.cmd_offsets, want.cmd_offsets);
            assert_eq!(stats.total_bytes(), plan.cmd_bytes());
            assert_eq!(receipt.service, stats.max_service());
            if devices == 1 {
                assert_eq!(sharded.shards[0].cmds.as_slice(), plan.cmds());
            }
        }
    }

    #[test]
    fn real_file_pool_round_trips() {
        use std::io::Write;
        let s = store();
        let image = s.build_image();
        let stripe = StripeLayout::build(&s.layout, 2, StripePolicy::RoundRobin, None);
        let shards = stripe.shard_image(&image);
        let paths: Vec<std::path::PathBuf> = shards
            .iter()
            .enumerate()
            .map(|(m, data)| {
                let path = std::env::temp_dir()
                    .join(format!("nc_pool_test_{}_{m}", std::process::id()));
                let mut f = std::fs::File::create(&path).unwrap();
                f.write_all(data).unwrap();
                path
            })
            .collect();
        let pool = DevicePool::from_files(&paths, stripe, 2, false).unwrap();
        assert!(!pool.is_virtual_time(), "file pool is wall-clock");
        let extents = [Extent::new(3, 50), Extent::new(9000, 3000)];
        let (bytes, _) = pool.read_batch_vec(&extents).unwrap();
        let mut want = Vec::new();
        for e in &extents {
            want.extend_from_slice(&image[e.offset as usize..e.end() as usize]);
        }
        assert_eq!(bytes, want);
        // The planned path reassembles identically through the parallel
        // fan-out too.
        let planner = IoPlanner::new(CoalescePolicy::contiguous());
        let id = MatrixId::new(1, MatrixKind::Down);
        let plan = planner.plan_chunks(&s.layout, id, &[Chunk::new(2, 30)], None);
        let mut sharded = ShardedPlan::default();
        planner.shard_into(&plan, pool.stripe(), &mut sharded);
        let mut receipt = PlanReceipt::default();
        let mut staging = Vec::new();
        let mut stats = PoolStats::default();
        pool.submit_sharded_into(&plan, &sharded, &mut staging, &mut receipt, &mut stats)
            .unwrap();
        let flat = SimulatedSsd::with_image(DeviceProfile::nano(), image.clone(), 5);
        assert_eq!(receipt.bytes, flat.submit(&plan).unwrap().bytes);
        for p in paths {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn estimate_sharded_is_max_over_members() {
        use crate::storage::{ProfileConfig, Profiler};
        let s = store();
        let image = s.build_image();
        let stripe = StripeLayout::build(&s.layout, 2, StripePolicy::RoundRobin, None);
        let profiles = vec![DeviceProfile::nano(), DeviceProfile::agx()];
        let pool = DevicePool::simulated(&profiles, stripe, &image, 9).unwrap();
        let planner = IoPlanner::new(CoalescePolicy::contiguous());
        let id = MatrixId::new(0, MatrixKind::Down);
        let plan = planner.plan_chunks(&s.layout, id, &[Chunk::new(0, 64)], None);
        let mut sharded = ShardedPlan::default();
        planner.shard_into(&plan, pool.stripe(), &mut sharded);
        // No tables attached -> no estimate.
        assert_eq!(pool.estimate_sharded(&sharded), 0.0);
        // With per-member tables: the slowest member's Σ T_m.
        let tables: Vec<LatencyTable> = profiles
            .iter()
            .map(|p| {
                let probe = SimulatedSsd::timing_only(p.clone(), 1 << 40, 5);
                Profiler::new(&probe, ProfileConfig::coarse(p.saturation_bytes(0.99), 1024))
                    .build_table()
                    .unwrap()
            })
            .collect();
        let pool = pool.with_tables(tables.clone());
        assert_eq!(pool.member_table(0).unwrap().max_bytes(), tables[0].max_bytes());
        let want = (0..2)
            .map(|m| {
                sharded.shards[m]
                    .cmds
                    .iter()
                    .map(|c| tables[m].latency_bytes(c.len))
                    .sum::<f64>()
            })
            .fold(0.0f64, f64::max);
        let got = pool.estimate_sharded(&sharded);
        assert!(got > 0.0);
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }

    #[test]
    fn pool_stats_accounting() {
        let mut a = PoolStats::default();
        a.reset(2);
        a.bytes[0] = 100;
        a.service[0] = Duration::from_millis(4);
        a.service[1] = Duration::from_millis(1);
        assert_eq!(a.max_service(), Duration::from_millis(4));
        assert!((a.utilization_skew() - 1.6).abs() < 1e-9);
        let mut b = PoolStats::default();
        b.reset(2);
        b.bytes[1] = 50;
        b.service[1] = Duration::from_millis(3);
        a.absorb(&b);
        assert_eq!(a.bytes, vec![100, 50]);
        assert_eq!(a.service[1], Duration::from_millis(4));
    }

    #[test]
    fn member_capacity_checked() {
        let s = store();
        let stripe = StripeLayout::build(&s.layout, 2, StripePolicy::RoundRobin, None);
        let members: Vec<Box<dyn FlashDevice>> = (0..2)
            .map(|m| {
                Box::new(SimulatedSsd::timing_only(DeviceProfile::nano(), 16, m))
                    as Box<dyn FlashDevice>
            })
            .collect();
        assert!(DevicePool::new("tiny-pool", members, stripe).is_err());
    }
}
