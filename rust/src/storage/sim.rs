//! Event-level analytical SSD simulator.
//!
//! Service time for a batch of read commands is the max of three
//! bottlenecks (volume, IOPS, queue/latency), lifted by a pattern-mixing
//! penalty and multiplicative lognormal jitter. The *latency model* of
//! §3.1 is profiled against this simulator exactly as the paper profiles
//! its SSDs, so model-vs-"real" validation (Fig 5) is a meaningful
//! comparison here too: the lookup table is built from isolated
//! uniform-size batches while real patterns interleave sizes and hit the
//! mixing penalty + queue interactions the table never saw.

use std::sync::Mutex;
use std::time::Duration;

use crate::rng::Rng;
use crate::storage::{DeviceProfile, Extent, FlashDevice};

/// Deterministic simulated SSD, optionally backed by an in-RAM flash image
/// so reads return real bytes (the weight store uses this).
pub struct SimulatedSsd {
    profile: DeviceProfile,
    image: Option<Vec<u8>>,
    capacity: u64,
    rng: Mutex<Rng>,
}

impl SimulatedSsd {
    /// Timing-only device (no backing data) with `capacity` bytes.
    pub fn timing_only(profile: DeviceProfile, capacity: u64, seed: u64) -> Self {
        Self {
            profile,
            image: None,
            capacity,
            rng: Mutex::new(Rng::new(seed)),
        }
    }

    /// Device backed by a flash image (reads return its bytes).
    pub fn with_image(profile: DeviceProfile, image: Vec<u8>, seed: u64) -> Self {
        let capacity = image.len() as u64;
        Self {
            profile,
            image: Some(image),
            capacity,
            rng: Mutex::new(Rng::new(seed)),
        }
    }

    pub fn profile(&self) -> &DeviceProfile {
        &self.profile
    }

    /// Deterministic service-time model for a command batch.
    ///
    /// Returns seconds. Exposed (in addition to the trait methods) for
    /// analytical tests and the figure benches.
    pub fn model_service_seconds(&self, extents: &[Extent], jitter: f64) -> f64 {
        if extents.is_empty() {
            return 0.0;
        }
        let p = &self.profile;
        let n = extents.len() as f64;

        // Bandwidth bound: each command only engages `parallelism(s)` of
        // the flash channels, so small commands pay a throughput penalty
        // even at saturating queue depth (the Fig 4a ramp).
        let mut bw_time = 0.0f64;
        let mut cmd_lat = 0.0f64; // summed per-command service latency
        for e in extents {
            let b = p.page_round(e.len) as f64;
            bw_time += b / (p.peak_bw * p.parallelism(e.len));
            cmd_lat += p.cmd_overhead + b / p.peak_bw;
        }
        let iops_time = n / p.iops_ceiling;
        let effective_qd = (p.queue_depth as f64).min(n);
        let queue_time = cmd_lat / effective_qd;
        let base = bw_time.max(iops_time).max(queue_time);

        // Pattern-mixing penalty: interleaved chunk sizes invoke
        // pattern-dependent controller/queue behaviour (§3.1). Quantified
        // as normalized entropy over log2 size classes.
        let mix = size_mix_entropy(extents);
        base * (1.0 + p.mix_penalty * mix) * jitter
    }

    fn jitter(&self) -> f64 {
        let cv = self.profile.jitter_cv;
        if cv <= 0.0 {
            return 1.0;
        }
        // Lognormal with mean 1: sigma^2 = ln(1+cv^2).
        let sigma = (1.0 + cv * cv).ln().sqrt();
        let mu = -0.5 * sigma * sigma;
        self.rng.lock().unwrap().lognormal(mu, sigma)
    }

    fn check_extents(&self, extents: &[Extent]) -> anyhow::Result<()> {
        for e in extents {
            anyhow::ensure!(
                e.end() <= self.capacity,
                "extent {:?} beyond capacity {}",
                e,
                self.capacity
            );
        }
        Ok(())
    }
}

/// Normalized entropy (0..=1) of the batch's log2 chunk-size classes.
/// 0 for uniform sizes, →1 for maximally mixed patterns.
fn size_mix_entropy(extents: &[Extent]) -> f64 {
    if extents.len() < 2 {
        return 0.0;
    }
    let mut counts = [0u32; 40];
    for e in extents {
        let class = (usize::BITS - e.len.max(1).leading_zeros()) as usize;
        counts[class.min(39)] += 1;
    }
    let n = extents.len() as f64;
    let mut h = 0.0;
    let mut classes = 0;
    for &c in &counts {
        if c > 0 {
            classes += 1;
            let p = c as f64 / n;
            h -= p * p.log2();
        }
    }
    if classes <= 1 {
        0.0
    } else {
        h / (classes as f64).log2()
    }
}

impl FlashDevice for SimulatedSsd {
    fn name(&self) -> &str {
        &self.profile.name
    }

    fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Service times are a deterministic analytical model, not wall
    /// clock: pool fan-out may run members serially without changing any
    /// outcome.
    fn is_virtual_time(&self) -> bool {
        true
    }

    fn read_batch(&self, extents: &[Extent], out: &mut [u8]) -> anyhow::Result<Duration> {
        self.check_extents(extents)?;
        let total: usize = extents.iter().map(|e| e.len).sum();
        anyhow::ensure!(out.len() == total, "out buffer {} != {}", out.len(), total);
        if let Some(image) = &self.image {
            let mut at = 0;
            for e in extents {
                out[at..at + e.len]
                    .copy_from_slice(&image[e.offset as usize..e.offset as usize + e.len]);
                at += e.len;
            }
        }
        let secs = self.model_service_seconds(extents, self.jitter());
        Ok(Duration::from_secs_f64(secs))
    }

    fn service_time(&self, extents: &[Extent]) -> anyhow::Result<Duration> {
        self.check_extents(extents)?;
        let secs = self.model_service_seconds(extents, self.jitter());
        Ok(Duration::from_secs_f64(secs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> SimulatedSsd {
        SimulatedSsd::timing_only(DeviceProfile::agx(), 1 << 32, 42)
    }

    fn uniform(n: usize, size: usize, stride: u64) -> Vec<Extent> {
        (0..n)
            .map(|i| Extent::new(i as u64 * stride, size))
            .collect()
    }

    #[test]
    fn empty_batch_is_free() {
        assert_eq!(dev().model_service_seconds(&[], 1.0), 0.0);
    }

    #[test]
    fn contiguous_beats_scattered_at_same_volume() {
        let d = dev();
        // 128 chunks of 256 KB vs 8192 chunks of 4 KB: same 32 MB volume.
        let big = uniform(128, 256 * 1024, 1 << 20);
        let small = uniform(8192, 4096, 1 << 14);
        let t_big = d.model_service_seconds(&big, 1.0);
        let t_small = d.model_service_seconds(&small, 1.0);
        assert!(
            t_small > 3.0 * t_big,
            "scattered {t_small} vs contiguous {t_big}"
        );
    }

    #[test]
    fn large_read_hits_peak_bandwidth() {
        let d = dev();
        let e = uniform(64, 1 << 20, 1 << 21); // 64 x 1 MB
        let t = d.model_service_seconds(&e, 1.0);
        let bw = 64.0 * (1 << 20) as f64 / t;
        assert!(bw > 0.9 * d.profile().peak_bw, "bw {bw}");
    }

    #[test]
    fn small_reads_are_iops_bound() {
        let d = dev();
        let e = uniform(10_000, 4096, 8192);
        let t = d.model_service_seconds(&e, 1.0);
        let iops = 10_000.0 / t;
        assert!(
            iops < d.profile().iops_ceiling * 1.01,
            "iops {iops} above ceiling"
        );
    }

    #[test]
    fn throughput_saturates_with_request_count() {
        // Fig 3: throughput stabilizes once request count exceeds a small
        // threshold.
        let d = dev();
        let size = 64 * 1024;
        let tput = |n: usize| {
            let e = uniform(n, size, (size * 2) as u64);
            n as f64 * size as f64 / d.model_service_seconds(&e, 1.0)
        };
        let t1 = tput(1);
        let t64 = tput(64);
        let t256 = tput(256);
        assert!(t64 > t1, "concurrency should help");
        assert!((t256 - t64).abs() / t64 < 0.05, "should be stable: {t64} vs {t256}");
    }

    #[test]
    fn mixing_sizes_costs_more_than_uniform() {
        let d = dev();
        // 64 x 64 KB uniform vs same volume split into mixed sizes.
        let uni = uniform(64, 64 * 1024, 1 << 18);
        let mut mixed = Vec::new();
        for i in 0..32 {
            mixed.push(Extent::new(i * (1 << 18), 96 * 1024));
            mixed.push(Extent::new(i * (1 << 18) + (1 << 17), 32 * 1024));
        }
        let t_uni = d.model_service_seconds(&uni, 1.0);
        let t_mix = d.model_service_seconds(&mixed, 1.0);
        assert!(t_mix > t_uni, "mixed {t_mix} <= uniform {t_uni}");
    }

    #[test]
    fn jitter_is_small_and_mean_one() {
        let d = dev();
        let e = uniform(32, 64 * 1024, 1 << 18);
        let times: Vec<f64> = (0..500)
            .map(|_| d.service_time(&e).unwrap().as_secs_f64())
            .collect();
        let m = crate::stats::mean(&times);
        let noiseless = d.model_service_seconds(&e, 1.0);
        assert!((m / noiseless - 1.0).abs() < 0.02);
        assert!(crate::stats::cv(&times) < 0.05);
    }

    #[test]
    fn reads_return_image_bytes() {
        let image: Vec<u8> = (0..=255u8).cycle().take(1 << 16).collect();
        let d = SimulatedSsd::with_image(DeviceProfile::nano(), image.clone(), 7);
        let extents = [Extent::new(10, 4), Extent::new(300, 3)];
        let (bytes, _) = d.read_batch_vec(&extents).unwrap();
        assert_eq!(bytes, vec![10, 11, 12, 13, 44, 45, 46]);
    }

    #[test]
    fn out_of_bounds_rejected() {
        let d = SimulatedSsd::timing_only(DeviceProfile::nano(), 1024, 1);
        assert!(d.service_time(&[Extent::new(1000, 100)]).is_err());
        assert!(d.service_time(&[Extent::new(0, 1024)]).is_ok());
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || SimulatedSsd::timing_only(DeviceProfile::agx(), 1 << 30, 99);
        let e = uniform(100, 16 * 1024, 1 << 16);
        let a: Vec<_> = {
            let d = mk();
            (0..10).map(|_| d.service_time(&e).unwrap()).collect()
        };
        let b: Vec<_> = {
            let d = mk();
            (0..10).map(|_| d.service_time(&e).unwrap()).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn entropy_zero_for_uniform_sizes() {
        assert_eq!(size_mix_entropy(&uniform(16, 8192, 16384)), 0.0);
    }

    #[test]
    fn entropy_positive_for_mixed() {
        let mut e = uniform(8, 4096, 1 << 16);
        e.extend(uniform(8, 128 * 1024, 1 << 20));
        assert!(size_mix_entropy(&e) > 0.5);
    }
}
