//! Device profiles: the handful of parameters that shape an SSD's
//! throughput-vs-contiguity curve.
//!
//! The model is a three-way bottleneck (roofline) over a batch of read
//! commands. The dominant term is the **internal-parallelism ramp**: a
//! command of `s` bytes stripes across NAND channels/planes and engages
//! `1 − exp(−s/chan_ramp)` of peak bandwidth, which reproduces the
//! overhead-bound → bandwidth-bound transition of Fig 4a with 99% of
//! peak exactly at the paper's measured saturation points (Appendix D:
//! 348 KB on Nano, 236 KB on AGX; <100 KB on the MacBook used by
//! LLM-in-a-Flash, Appendix L). Two further bounds: a host-side IOPS
//! ceiling (Jetson routes NVMe interrupts to a single core — [8, 42]),
//! binding only for tiny commands, and a queue/latency bound governing
//! small request counts (Fig 3's rise-then-stabilize behaviour).
//!
//!   throughput(s) = min(peak_bw·(1−e^{−s/ramp}), iops·s, qd·s/(t_cmd+s/bw))

/// Parameters of the analytical SSD service-time model.
#[derive(Clone, Debug)]
pub struct DeviceProfile {
    pub name: String,
    /// Peak sequential read bandwidth, bytes/s.
    pub peak_bw: f64,
    /// Per-command fixed overhead (controller + NAND + completion), s.
    pub cmd_overhead: f64,
    /// Host-side command completion ceiling, commands/s (single-core
    /// interrupt routing on Jetson).
    pub iops_ceiling: f64,
    /// Effective command concurrency (paper: 6-thread I/O pool).
    pub queue_depth: usize,
    /// Lognormal service-time jitter coefficient of variation.
    pub jitter_cv: f64,
    /// Pattern-dependent controller penalty for mixed chunk sizes — the
    /// source of the proportional model-vs-real bias in Fig 5.
    pub mix_penalty: f64,
    /// NAND page granularity: reads are rounded up to page multiples.
    pub page_bytes: usize,
    /// Internal-parallelism ramp: a single command of `s` bytes engages
    /// the flash channels/planes as `1 - exp(-s/chan_ramp)` of peak
    /// bandwidth, putting 99% of peak exactly at `chan_ramp * ln(100)`.
    pub chan_ramp: f64,
}

impl DeviceProfile {
    /// Calibrated constructor: choose the channel ramp so that a command
    /// reaches 99% of peak bandwidth exactly at `saturate_bytes` (the
    /// measured knee of Fig 4a / Appendix D). `iops_ceiling` is the
    /// host-side completion limit and binds only for tiny commands.
    pub fn calibrated(
        name: &str,
        peak_bw: f64,
        saturate_bytes: f64,
        cmd_overhead: f64,
        queue_depth: usize,
        iops_ceiling: f64,
    ) -> Self {
        let chan_ramp = saturate_bytes / 100f64.ln();
        Self {
            name: name.to_string(),
            peak_bw,
            cmd_overhead,
            iops_ceiling,
            queue_depth,
            jitter_cv: 0.02,
            mix_penalty: 0.18,
            page_bytes: 4096,
            chan_ramp,
        }
    }

    /// Jetson Orin Nano + SK Hynix Gold P31 (peak 3500 MB/s, saturation
    /// ~348 KB — paper §4.1 + Appendix D). IOPS ceiling reflects the
    /// single-core NVMe interrupt routing on Jetson [8, 42].
    pub fn nano() -> Self {
        let mut p = Self::calibrated("nano", 3500e6, 348e3, 30e-6, 6, 60e3);
        // Lower-end device: controller dynamics amplify tail latency and
        // weaken the averaging effect (paper §3.1) -> more jitter + mixing.
        p.jitter_cv = 0.04;
        p.mix_penalty = 0.25;
        p
    }

    /// Jetson AGX Orin + Samsung 990 Pro (peak 7450 MB/s, saturation
    /// ~236 KB).
    pub fn agx() -> Self {
        Self::calibrated("agx", 7450e6, 236e3, 25e-6, 6, 120e3)
    }

    /// MacBook-class NVMe (LLM-in-a-Flash's testbed): multi-core interrupt
    /// distribution -> saturates below 100 KB (Appendix L).
    pub fn macbook() -> Self {
        Self::calibrated("macbook", 3000e6, 90e3, 20e-6, 8, 250e3)
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "nano" => Some(Self::nano()),
            "agx" => Some(Self::agx()),
            "macbook" => Some(Self::macbook()),
            _ => None,
        }
    }

    /// Round a byte count up to the page granularity.
    pub fn page_round(&self, bytes: usize) -> usize {
        bytes.div_ceil(self.page_bytes) * self.page_bytes
    }

    /// Fraction of peak bandwidth a single command of `bytes` engages
    /// (internal channel/plane striping ramp).
    pub fn parallelism(&self, bytes: usize) -> f64 {
        1.0 - (-(self.page_round(bytes) as f64) / self.chan_ramp).exp()
    }

    /// Analytical throughput for uniform chunks of `bytes` at saturating
    /// request counts (the closed form behind Fig 4a).
    pub fn uniform_throughput(&self, bytes: usize) -> f64 {
        let b = self.page_round(bytes) as f64;
        (self.peak_bw * self.parallelism(bytes))
            .min(self.iops_ceiling * b)
            .min(self.peak_bw)
            * (bytes as f64 / b)
    }

    /// Saturation point implied by the profile (bytes reaching `frac` of
    /// peak), by scan.
    pub fn saturation_bytes(&self, frac: f64) -> usize {
        let peak = self.peak_bw;
        let mut s = self.page_bytes;
        while (self.uniform_throughput(s) as f64) < frac * peak && s < 1 << 24 {
            s += 1024;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_exist() {
        for n in ["nano", "agx", "macbook"] {
            assert_eq!(DeviceProfile::by_name(n).unwrap().name, n);
        }
        assert!(DeviceProfile::by_name("tpu").is_none());
    }

    #[test]
    fn nano_saturates_near_paper_value() {
        let p = DeviceProfile::nano();
        let sat = p.saturation_bytes(0.99);
        assert!(
            (300_000..400_000).contains(&sat),
            "nano saturation {sat} outside paper band (~348 KB)"
        );
    }

    #[test]
    fn agx_saturates_near_paper_value() {
        let p = DeviceProfile::agx();
        let sat = p.saturation_bytes(0.99);
        assert!(
            (200_000..280_000).contains(&sat),
            "agx saturation {sat} outside paper band (~236 KB)"
        );
    }

    #[test]
    fn macbook_saturates_below_100kb() {
        let p = DeviceProfile::macbook();
        assert!(p.saturation_bytes(0.99) <= 100_000);
    }

    #[test]
    fn throughput_monotone_and_capped() {
        let p = DeviceProfile::agx();
        let mut prev = 0.0;
        for kb in (4..=512).step_by(4) {
            let t = p.uniform_throughput(kb * 1024);
            assert!(t >= prev * 0.999, "non-monotone at {kb} KB");
            assert!(t <= p.peak_bw * 1.0001);
            prev = t;
        }
    }

    #[test]
    fn agx_has_wider_absolute_contiguity_gap_than_nano() {
        // Paper §4.2: AGX shows a wider throughput gap between contiguous
        // and scattered access. In our calibrated model this holds for the
        // *absolute* gap (peak − scattered bandwidth); the *relative* gap
        // is wider on Nano because its saturation point (348 KB) sits
        // further out than AGX's (236 KB) — see EXPERIMENTS.md discussion.
        let nano = DeviceProfile::nano();
        let agx = DeviceProfile::agx();
        let gap = |p: &DeviceProfile| p.peak_bw - p.uniform_throughput(4096);
        assert!(gap(&agx) > gap(&nano));
    }

    #[test]
    fn page_round() {
        let p = DeviceProfile::agx();
        assert_eq!(p.page_round(1), 4096);
        assert_eq!(p.page_round(4096), 4096);
        assert_eq!(p.page_round(4097), 8192);
    }
}
