//! Minimal criterion-style micro-benchmark harness (criterion itself is
//! unavailable offline). Auto-calibrates iteration counts, reports
//! median/mean/std, and supports labelled groups. Used by every target in
//! `benches/` (all declared `harness = false`).

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median: Duration,
    pub mean: Duration,
    pub std_dev: Duration,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<56} {:>12}  (mean {:>12}, sd {:>10}, n={})",
            self.name,
            crate::report::fmt_secs(self.median.as_secs_f64()),
            crate::report::fmt_secs(self.mean.as_secs_f64()),
            crate::report::fmt_secs(self.std_dev.as_secs_f64()),
            self.iters
        );
    }
}

/// Benchmark runner with target measurement time.
pub struct Bencher {
    /// Target total measurement duration per benchmark.
    pub target: Duration,
    /// Number of timed batches (samples) the target is split into.
    pub samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new(Duration::from_millis(400), 20)
    }
}

impl Bencher {
    pub fn new(target: Duration, samples: usize) -> Self {
        Self {
            target,
            samples,
            results: Vec::new(),
        }
    }

    /// Quick preset for CI-ish runs.
    pub fn quick() -> Self {
        Self::new(Duration::from_millis(120), 8)
    }

    /// Time `f`, auto-calibrating the per-sample iteration count.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + calibration: how many iters fit in target/samples?
        let mut iters_per_sample = 1u64;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            let el = t0.elapsed();
            if el >= self.target / (self.samples as u32) || iters_per_sample > 1 << 30 {
                break;
            }
            let scale = (self.target.as_secs_f64() / self.samples as f64
                / el.as_secs_f64().max(1e-9))
            .clamp(1.5, 100.0);
            iters_per_sample = ((iters_per_sample as f64) * scale).ceil() as u64;
        }
        // Measurement.
        let mut per_iter: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            per_iter.push(t0.elapsed().as_secs_f64() / iters_per_sample as f64);
        }
        let median = crate::stats::median(&per_iter);
        let mean = crate::stats::mean(&per_iter);
        let sd = crate::stats::std_dev(&per_iter);
        let res = BenchResult {
            name: name.to_string(),
            iters: iters_per_sample * self.samples as u64,
            median: Duration::from_secs_f64(median),
            mean: Duration::from_secs_f64(mean),
            std_dev: Duration::from_secs_f64(sd),
        };
        res.print();
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Prevent the optimizer from discarding a value (std::hint wrapper).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Standard header printed by each bench binary.
pub fn header(target: &str) {
    println!("\n### bench: {target}");
    println!(
        "{:<56} {:>12}  {:>34}",
        "benchmark", "median/iter", "detail"
    );
    println!("{}", "-".repeat(108));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher::new(Duration::from_millis(30), 4);
        let mut acc = 0u64;
        let r = b
            .bench("noop-ish", || {
                acc = acc.wrapping_add(black_box(1));
            })
            .clone();
        assert!(r.iters > 100); // cheap op must auto-scale iters
        assert!(r.median < Duration::from_micros(10));
    }

    #[test]
    fn respects_relative_cost() {
        let mut b = Bencher::new(Duration::from_millis(40), 4);
        // xor-multiply fold has no closed form LLVM can substitute.
        let work = |n: u64| {
            black_box(
                (0..black_box(n)).fold(0u64, |a, i| a ^ i.wrapping_mul(0x9E3779B9)),
            )
        };
        let cheap = b.bench("cheap", || {
            work(10);
        })
        .median;
        let pricey = b.bench("pricey", || {
            work(10_000);
        })
        .median;
        assert!(pricey > cheap * 5);
    }
}
