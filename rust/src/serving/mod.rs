//! Network serving front end + closed-loop load harness.
//!
//! Everything here is dependency-free `std`: [`http`] is a minimal
//! HTTP/1.1 reader/writer, [`json`] a small parser/printer whose float
//! round-trip is bit-exact for `f32` payloads, [`server`] the
//! thread-per-connection front end over the
//! [`Scheduler`](crate::coordinator::Scheduler), [`args`] the shared
//! CLI-flag parser, and [`loadgen`] the open-loop redline bencher
//! (`redline` binary) that drives the server over real sockets and
//! reports coordinated-omission-resistant latency percentiles.

pub mod args;
pub mod http;
pub mod json;
pub mod loadgen;
pub mod server;

pub use args::{parse_mix, ArgError, ArgParser};
pub use server::{Server, ServerConfig};
