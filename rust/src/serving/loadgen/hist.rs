//! Fixed-bucket latency histogram with bounded relative error.
//!
//! Geometric buckets, [`SUB_PER_OCTAVE`] per power of two, so any
//! recorded value lands in a bucket whose width is ≤ `2^(1/16) − 1`
//! ≈ 4.4% of its value — percentile queries are accurate to that bound
//! with O(1) record cost and a few KiB of memory, no matter how many
//! samples a redline run produces.

const SUB_PER_OCTAVE: usize = 16;
const OCTAVES: usize = 40; // 1 µs .. ~2^40 µs (≈ 12.7 days)
const BUCKETS: usize = SUB_PER_OCTAVE * OCTAVES;

/// Latency histogram over microsecond values.
#[derive(Clone, Debug)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum_us: u128,
    min_us: u64,
    max_us: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

fn bucket_index(us: u64) -> usize {
    if us <= 1 {
        return 0;
    }
    let idx = ((us as f64).log2() * SUB_PER_OCTAVE as f64) as usize;
    idx.min(BUCKETS - 1)
}

/// Upper edge of bucket `i` — the value a percentile query reports for
/// samples that landed there.
fn bucket_edge(i: usize) -> u64 {
    2f64.powf((i as f64 + 1.0) / SUB_PER_OCTAVE as f64).round() as u64
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: vec![0; BUCKETS],
            count: 0,
            sum_us: 0,
            min_us: u64::MAX,
            max_us: 0,
        }
    }

    pub fn record(&mut self, us: u64) {
        self.buckets[bucket_index(us)] += 1;
        self.count += 1;
        self.sum_us += us as u128;
        self.min_us = self.min_us.min(us);
        self.max_us = self.max_us.max(us);
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.min_us = self.min_us.min(other.min_us);
        self.max_us = self.max_us.max(other.max_us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn max_us(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.max_us
        }
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Value at quantile `q` ∈ [0, 1] (e.g. `0.99` for p99), clamped to
    /// the observed min/max so bucket edges never report a latency
    /// outside what was actually seen.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            cum += n;
            if cum >= target {
                return bucket_edge(i).clamp(self.min_us, self.max_us);
            }
        }
        self.max_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_track_uniform_data() {
        let mut h = Histogram::new();
        for us in 1..=10_000u64 {
            h.record(us);
        }
        assert_eq!(h.count(), 10_000);
        let p50 = h.percentile(0.50) as f64;
        let p99 = h.percentile(0.99) as f64;
        // Bucket width bounds relative error at ~4.4%; allow 10%.
        assert!((p50 - 5_000.0).abs() / 5_000.0 < 0.10, "p50={p50}");
        assert!((p99 - 9_900.0).abs() / 9_900.0 < 0.10, "p99={p99}");
        assert_eq!(h.percentile(1.0), 10_000);
        assert_eq!(h.max_us(), 10_000);
        assert!((h.mean_us() - 5_000.5).abs() < 1.0);
    }

    #[test]
    fn merge_equals_combined_recording() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut all = Histogram::new();
        for us in [3u64, 40, 500, 6_000, 70_000] {
            a.record(us);
            all.record(us);
        }
        for us in [9u64, 80, 900, 10_000, 200_000] {
            b.record(us);
            all.record(us);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(a.percentile(q), all.percentile(q), "q={q}");
        }
        assert_eq!(a.max_us(), 200_000);
    }

    #[test]
    fn empty_and_tiny() {
        let h = Histogram::new();
        assert_eq!(h.percentile(0.99), 0);
        assert_eq!(h.max_us(), 0);
        let mut h = Histogram::new();
        h.record(0);
        h.record(7);
        assert!(h.percentile(0.5) <= 7);
        assert_eq!(h.percentile(1.0), 7);
    }

    #[test]
    fn bucket_error_is_bounded() {
        // Every representable value's bucket edge is within ~4.5% above.
        for us in [1u64, 10, 137, 999, 12_345, 1_000_000, 123_456_789] {
            let edge = bucket_edge(bucket_index(us));
            assert!(edge >= us, "edge {edge} < {us}");
            assert!((edge as f64) < us as f64 * 1.046 + 1.0, "edge {edge} too far above {us}");
        }
    }
}
