//! The redline load harness: an open-loop generator that drives the
//! serving front end over real sockets.
//!
//! Open-loop means request *intended-send times* come from a fixed
//! schedule (a [`rate::TokenBucket`] at the target RPS), not from when
//! the previous response happened to return — and latency is measured
//! from the intended time, so a stalled server inflates the recorded
//! percentiles instead of silently thinning the arrival rate
//! (coordinated omission). [`hist::Histogram`] buckets latencies with
//! bounded relative error; [`runner`] orchestrates a run and renders
//! the report; [`compare`] diffs two run files and issues regression
//! verdicts that map one-to-one onto the CI bench gate.

pub mod client;
pub mod compare;
pub mod hist;
pub mod rate;
pub mod runner;

pub use compare::compare_files;
pub use hist::Histogram;
pub use rate::TokenBucket;
pub use runner::{run, HealthCounters, RunConfig, RunReport};
