//! Token-bucket pacing for the open-loop generator.

use std::time::{Duration, Instant};

/// A token bucket that hands out *send deadlines* rather than blocking:
/// [`TokenBucket::reserve`] always consumes a token (going into debt if
/// none is available) and returns the instant the consumed token exists,
/// i.e. the intended send time under the configured rate. The caller
/// sleeps until that instant and stamps the request with it — this is
/// what makes the harness open-loop: the schedule never stretches just
/// because the server got slow.
#[derive(Debug)]
pub struct TokenBucket {
    /// Tokens per second.
    rate: f64,
    /// Bucket capacity: how many requests may fire back-to-back after an
    /// idle stretch.
    burst: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    /// `rate` must be positive; `burst` is clamped to ≥ 1.
    pub fn new(rate: f64, burst: usize, now: Instant) -> Self {
        assert!(rate > 0.0, "token bucket rate must be positive");
        let burst = burst.max(1) as f64;
        Self {
            rate,
            burst,
            tokens: burst,
            last: now,
        }
    }

    /// Consume one token, returning the instant at which it is (or
    /// becomes) available. Monotonically non-decreasing across calls.
    pub fn reserve(&mut self, now: Instant) -> Instant {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        self.last = now;
        self.tokens -= 1.0;
        if self.tokens >= 0.0 {
            now
        } else {
            // In debt: the token materializes -tokens/rate from now.
            now + Duration::from_secs_f64(-self.tokens / self.rate)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_fires_immediately_then_paces() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(1000.0, 3, t0);
        // Burst of 3 at t0, then 1ms spacing.
        assert_eq!(b.reserve(t0), t0);
        assert_eq!(b.reserve(t0), t0);
        assert_eq!(b.reserve(t0), t0);
        let d4 = b.reserve(t0) - t0;
        let d5 = b.reserve(t0) - t0;
        assert!(d4 >= Duration::from_micros(900) && d4 <= Duration::from_micros(1100), "{d4:?}");
        assert!(d5 >= Duration::from_micros(1900) && d5 <= Duration::from_micros(2100), "{d5:?}");
    }

    #[test]
    fn refill_caps_at_burst() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(100.0, 2, t0);
        // Drain the burst, then idle 10s: only `burst` tokens accrue.
        b.reserve(t0);
        b.reserve(t0);
        let later = t0 + Duration::from_secs(10);
        assert_eq!(b.reserve(later), later);
        assert_eq!(b.reserve(later), later);
        assert!(b.reserve(later) > later);
    }

    #[test]
    fn deadlines_are_monotonic() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(10_000.0, 1, t0);
        let mut prev = t0;
        for _ in 0..100 {
            let at = b.reserve(t0);
            assert!(at >= prev);
            prev = at;
        }
    }
}
