//! Open-loop run orchestration: pace → send → measure → report.
//!
//! One pacer thread turns the target RPS into a schedule of intended
//! send times (token bucket); worker threads (one connection each) pull
//! scheduled items off a shared queue, fire the request, and record the
//! latency **from the intended time**, so scheduler backlog and server
//! stalls show up in the percentiles instead of stretching the schedule
//! (open-loop / coordinated-omission-resistant measurement).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::client::{Client, Reply};
use super::hist::Histogram;
use super::rate::TokenBucket;
use crate::serving::json::{self, Json};

#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Server address, e.g. `127.0.0.1:8321`.
    pub addr: String,
    /// Target request rate (requests/s, across all ops).
    pub rps: f64,
    /// Token-bucket burst capacity.
    pub burst: usize,
    /// How long to keep the schedule running.
    pub duration: Duration,
    /// Streams to open (requests round-robin across them).
    pub streams: usize,
    /// Client connections = concurrent in-flight requests.
    pub connections: usize,
    /// Prefill:decode request mix per cycle, e.g. `(1, 8)`.
    pub mix: (usize, usize),
    /// Decode steps per decode request.
    pub steps: usize,
    /// Per-decode queue-delay deadline (ms) stamped into requests via
    /// the typed API; also the run's `"slo"` identity. `None` sends no
    /// deadline (server defaults apply).
    pub deadline_ms: Option<u64>,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:8321".to_string(),
            rps: 20.0,
            burst: 4,
            duration: Duration::from_secs(10),
            streams: 4,
            connections: 4,
            mix: (1, 8),
            steps: 4,
            deadline_ms: None,
        }
    }
}

/// Per-op aggregate over one run.
#[derive(Clone, Debug, Default)]
pub struct OpStats {
    pub requests: u64,
    pub errors: u64,
    /// Requests the server shed at admission (HTTP 429 — SLO or budget
    /// backpressure). Expected under deliberate overload, so counted
    /// apart from `errors` and excluded from the latency histogram.
    pub shed: u64,
    /// Tokens produced (decode steps, or frame tokens for prefill).
    pub tokens: u64,
    /// Client-observed latency from intended-send time.
    pub hist: Histogram,
    /// Sum of server-reported execution wall time, µs.
    pub server_us: u64,
    /// Sum of server-reported scheduler queue wait, µs.
    pub queue_us: u64,
}

impl OpStats {
    fn tokens_per_s(&self, wall: Duration) -> f64 {
        let s = wall.as_secs_f64();
        if s > 0.0 {
            self.tokens as f64 / s
        } else {
            0.0
        }
    }
}

/// Last-seen cumulative engine fault-tolerance counters. The server
/// reports them monotonically in every response's `"engine"` object,
/// so the element-wise max across all workers' replies is the run's
/// final snapshot.
#[derive(Clone, Copy, Debug, Default)]
pub struct HealthCounters {
    pub io_retries: u64,
    pub io_failovers: u64,
    pub io_hedges: u64,
    pub io_hedge_wins: u64,
    /// Pool members marked dead at the last observed response.
    pub pool_dead: u64,
    /// Bytes served from the shared hot-chunk RAM cache (cumulative).
    pub cache_hit_bytes: u64,
    /// Cache-resident bytes at the last observed response.
    pub cache_resident_bytes: u64,
    /// Whole-chunk cache evictions (cumulative).
    pub cache_evictions: u64,
    /// Hot-set drift vs the calibrated layout, parts-per-million.
    pub cache_drift_ppm: u64,
}

impl HealthCounters {
    fn absorb(&mut self, r: &Reply) {
        self.io_retries = self.io_retries.max(r.io_retries);
        self.io_failovers = self.io_failovers.max(r.io_failovers);
        self.io_hedges = self.io_hedges.max(r.io_hedges);
        self.io_hedge_wins = self.io_hedge_wins.max(r.io_hedge_wins);
        self.pool_dead = self.pool_dead.max(r.pool_dead);
        self.cache_hit_bytes = self.cache_hit_bytes.max(r.cache_hit_bytes);
        self.cache_resident_bytes = self.cache_resident_bytes.max(r.cache_resident_bytes);
        self.cache_evictions = self.cache_evictions.max(r.cache_evictions);
        self.cache_drift_ppm = self.cache_drift_ppm.max(r.cache_drift_ppm);
    }
}

/// Everything a run produced: identity, per-op stats, wall time.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub cfg: RunConfig,
    /// `(key, raw JSON value)` identity pairs stamped into every entry —
    /// run shape plus the server's own `/v1/config` (policy, devices,
    /// async_io, …), so reports match on true served identity.
    pub ident: Vec<(String, String)>,
    pub decode: OpStats,
    pub append: OpStats,
    /// Final engine fault-tolerance snapshot observed during the run.
    pub health: HealthCounters,
    pub wall: Duration,
}

#[derive(Clone, Copy, Debug)]
enum Op {
    Prefill,
    Decode,
}

struct WorkItem {
    intended: Instant,
    stream: usize,
    op: Op,
}

/// `std::sync::mpsc::Receiver` is not `Sync`, so the multi-consumer
/// queue is a mutexed deque with a condvar and an explicit closed flag.
struct WorkQueue {
    state: Mutex<(VecDeque<WorkItem>, bool)>,
    cv: Condvar,
}

impl WorkQueue {
    fn new() -> Self {
        Self {
            state: Mutex::new((VecDeque::new(), false)),
            cv: Condvar::new(),
        }
    }

    fn push(&self, item: WorkItem) {
        self.state.lock().unwrap().0.push_back(item);
        self.cv.notify_one();
    }

    fn close(&self) {
        self.state.lock().unwrap().1 = true;
        self.cv.notify_all();
    }

    fn pop(&self) -> Option<WorkItem> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(item) = s.0.pop_front() {
                return Some(item);
            }
            if s.1 {
                return None;
            }
            s = self.cv.wait(s).unwrap();
        }
    }
}

/// Deterministic pseudo-embedding (no RNG dependency; values in
/// [-0.5, 0.5) with enough variety to exercise selection).
fn synth_values(n: usize) -> Vec<f32> {
    (0..n)
        .map(|i| (i.wrapping_mul(2_654_435_761) % 1000) as f32 / 1000.0 - 0.5)
        .collect()
}

/// Execute one open-loop run against a live server.
pub fn run(cfg: &RunConfig) -> Result<RunReport, String> {
    if cfg.rps <= 0.0 {
        return Err("--rps must be positive".to_string());
    }
    if cfg.streams == 0 || cfg.connections == 0 || cfg.steps == 0 {
        return Err("--streams/--connections/--steps must be ≥ 1".to_string());
    }
    let (mix_p, mix_d) = cfg.mix;
    // `parse_mix` enforces this for the CLI; re-checked here (overflow-
    // safe) because `RunConfig` is also a library API.
    let cycle = match mix_p.checked_add(mix_d) {
        Some(c) if c > 0 => c,
        Some(_) => return Err("--mix cannot be 0:0".to_string()),
        None => return Err("--mix counts overflow".to_string()),
    };

    // Probe identity + model shape, open and prime the streams.
    let mut probe = Client::connect(&cfg.addr)?;
    let server_cfg = probe.get("/v1/config")?;
    let d = server_cfg
        .get("d")
        .and_then(Json::as_usize)
        .ok_or("server config has no \"d\"")?;
    let tpf = server_cfg
        .get("tokens_per_frame")
        .and_then(Json::as_usize)
        .ok_or("server config has no \"tokens_per_frame\"")?;
    let frame = synth_values(tpf * d);
    let token = synth_values(d);
    let mut stream_ids = Vec::with_capacity(cfg.streams);
    for _ in 0..cfg.streams {
        let id = probe.open_stream()?;
        probe.append(id, &frame)?; // prime: decodes need KV context
        stream_ids.push(id);
    }

    let queue = Arc::new(WorkQueue::new());
    let stats = Arc::new(Mutex::new((
        OpStats::default(),
        OpStats::default(),
        HealthCounters::default(),
    )));

    let workers: Vec<_> = (0..cfg.connections)
        .map(|_| {
            let queue = queue.clone();
            let stats = stats.clone();
            let addr = cfg.addr.clone();
            let frame = frame.clone();
            let token = token.clone();
            let steps = cfg.steps;
            let deadline_ms = cfg.deadline_ms;
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).ok();
                while let Some(item) = queue.pop() {
                    let res = match (client.as_mut(), item.op) {
                        (None, _) => Err("no connection".to_string()),
                        (Some(c), Op::Decode) => {
                            c.decode(item.stream, &token, steps, deadline_ms)
                        }
                        (Some(c), Op::Prefill) => c.append(item.stream, &frame),
                    };
                    let latency = Instant::now().saturating_duration_since(item.intended);
                    let mut guard = stats.lock().unwrap();
                    if let Ok(reply) = &res {
                        guard.2.absorb(reply);
                    }
                    let op_stats = match item.op {
                        Op::Decode => &mut guard.0,
                        Op::Prefill => &mut guard.1,
                    };
                    op_stats.requests += 1;
                    match res {
                        Ok(reply) => {
                            op_stats.hist.record(latency.as_micros() as u64);
                            op_stats.server_us += reply.latency_us;
                            op_stats.queue_us += reply.queue_us;
                            op_stats.tokens += match item.op {
                                // Server-reported step count, falling
                                // back to the configured one.
                                Op::Decode if reply.steps > 0 => reply.steps,
                                Op::Decode => steps as u64,
                                Op::Prefill => tpf as u64,
                            };
                        }
                        // Admission sheds (429) are backpressure doing
                        // its job: counted apart from errors, and the
                        // connection stays (the server answered).
                        Err(e) if super::client::is_shed(&e) => {
                            op_stats.shed += 1;
                        }
                        Err(_) => {
                            op_stats.errors += 1;
                            drop(guard);
                            // One reconnect attempt; persistent failure
                            // keeps counting errors, never panics.
                            client = Client::connect(&addr).ok();
                        }
                    }
                }
            })
        })
        .collect();

    // The pacer: turn RPS into intended-send times and enqueue.
    let start = Instant::now();
    let deadline = start + cfg.duration;
    let mut bucket = TokenBucket::new(cfg.rps, cfg.burst, start);
    let mut seq = 0usize;
    loop {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        let intended = bucket.reserve(now);
        if intended >= deadline {
            break;
        }
        let wait = intended.saturating_duration_since(Instant::now());
        if !wait.is_zero() {
            std::thread::sleep(wait);
        }
        let op = if seq % cycle < mix_p {
            Op::Prefill
        } else {
            Op::Decode
        };
        queue.push(WorkItem {
            intended,
            stream: stream_ids[seq % stream_ids.len()],
            op,
        });
        seq += 1;
    }
    queue.close();
    for w in workers {
        let _ = w.join();
    }
    let wall = start.elapsed();

    let guard = stats.lock().unwrap();
    let (decode, append, health) = (guard.0.clone(), guard.1.clone(), guard.2);
    drop(guard);
    Ok(RunReport {
        cfg: cfg.clone(),
        ident: ident_pairs(cfg, &server_cfg),
        decode,
        append,
        health,
        wall,
    })
}

/// Identity pairs: run shape + server-reported engine identity, in the
/// order the bench gate's ID fields expect to find them.
fn ident_pairs(cfg: &RunConfig, server_cfg: &Json) -> Vec<(String, String)> {
    let mut pairs: Vec<(String, String)> = vec![("mode".into(), "\"served\"".into())];
    // Copy engine identity verbatim from /v1/config (raw JSON values so
    // strings keep quotes and bools/numbers stay bare).
    for key in ["policy", "prefetch", "threads", "devices", "async_io", "queue_depth"] {
        if let Some(v) = server_cfg.get(key) {
            pairs.push((key.to_string(), v.to_string()));
        }
    }
    pairs.push(("streams".into(), cfg.streams.to_string()));
    let mut rps = String::new();
    json::push_f64(&mut rps, cfg.rps);
    pairs.push(("rps".into(), rps));
    pairs.push(("mix".into(), format!("\"{}:{}\"", cfg.mix.0, cfg.mix.1)));
    // SLO identity: runs with different deadlines are different
    // experiments; 0 = no deadline stamped.
    pairs.push(("slo".into(), cfg.deadline_ms.unwrap_or(0).to_string()));
    pairs
}

fn entry_json(ident: &[(String, String)], op: &str, s: &OpStats, wall: Duration) -> String {
    use std::fmt::Write as _;
    let mut b = String::with_capacity(256);
    b.push('{');
    for (k, v) in ident {
        let _ = write!(b, "\"{k}\":{v},");
    }
    let mut tps = String::new();
    json::push_f64(&mut tps, s.tokens_per_s(wall));
    let _ = write!(
        b,
        "\"op\":\"{op}\",\"requests\":{},\"errors\":{},\"shed\":{},\"tokens\":{},\
         \"tokens_per_s\":{tps},\
         \"p50_us\":{},\"p90_us\":{},\"p99_us\":{},\"p999_us\":{},\"max_us\":{},\
         \"mean_us\":{:.1},\"server_us\":{},\"server_queue_us\":{}}}",
        s.requests,
        s.errors,
        s.shed,
        s.tokens,
        s.hist.percentile(0.50),
        s.hist.percentile(0.90),
        s.hist.percentile(0.99),
        s.hist.percentile(0.999),
        s.hist.max_us(),
        s.hist.mean_us(),
        s.server_us,
        s.queue_us,
    );
    b
}

/// Human-friendly microseconds.
pub fn fmt_us(us: u64) -> String {
    if us < 1_000 {
        format!("{us}µs")
    } else if us < 1_000_000 {
        format!("{:.2}ms", us as f64 / 1_000.0)
    } else {
        format!("{:.2}s", us as f64 / 1_000_000.0)
    }
}

impl RunReport {
    /// The JSON run file (`BENCH_serving.json`): run header + one flat
    /// gate-compatible entry per op that saw traffic.
    pub fn to_json(&self) -> String {
        use std::fmt::Write as _;
        let mut b = String::with_capacity(1024);
        let mut rps = String::new();
        json::push_f64(&mut rps, self.cfg.rps);
        let _ = write!(
            b,
            "{{\n  \"bench\": \"serving\",\n  \"addr\": ",
        );
        json::push_str_escaped(&mut b, &self.cfg.addr);
        let h = &self.health;
        let _ = write!(
            b,
            ",\n  \"rps\": {rps},\n  \"duration_s\": {:.3},\n  \"connections\": {},\n  \
             \"steps\": {},\n  \"pool_dead\": {},\n  \"io_retries\": {},\n  \
             \"io_failovers\": {},\n  \"io_hedges\": {},\n  \"io_hedge_wins\": {},\n  \
             \"cache_hit_bytes\": {},\n  \"cache_resident_bytes\": {},\n  \
             \"cache_evictions\": {},\n  \"cache_drift_ppm\": {},\n  \
             \"entries\": [",
            self.wall.as_secs_f64(),
            self.cfg.connections,
            self.cfg.steps,
            h.pool_dead,
            h.io_retries,
            h.io_failovers,
            h.io_hedges,
            h.io_hedge_wins,
            h.cache_hit_bytes,
            h.cache_resident_bytes,
            h.cache_evictions,
            h.cache_drift_ppm,
        );
        let mut first = true;
        for (op, s) in [("decode", &self.decode), ("append", &self.append)] {
            if s.requests == 0 {
                continue;
            }
            if !first {
                b.push(',');
            }
            first = false;
            b.push_str("\n    ");
            b.push_str(&entry_json(&self.ident, op, s, self.wall));
        }
        b.push_str("\n  ]\n}\n");
        b
    }

    /// Pretty terminal table.
    pub fn render_table(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "redline: {} rps for {:.1}s against {} ({} streams, mix {}:{}, {} conns, {} steps/decode)",
            self.cfg.rps,
            self.wall.as_secs_f64(),
            self.cfg.addr,
            self.cfg.streams,
            self.cfg.mix.0,
            self.cfg.mix.1,
            self.cfg.connections,
            self.cfg.steps,
        );
        let _ = writeln!(
            out,
            "{:<8} {:>7} {:>6} {:>6} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9}",
            "op", "reqs", "errs", "shed", "tok/s", "p50", "p90", "p99", "p999", "max"
        );
        for (op, s) in [("decode", &self.decode), ("append", &self.append)] {
            if s.requests == 0 {
                continue;
            }
            let _ = writeln!(
                out,
                "{:<8} {:>7} {:>6} {:>6} {:>9.1} {:>9} {:>9} {:>9} {:>9} {:>9}",
                op,
                s.requests,
                s.errors,
                s.shed,
                s.tokens_per_s(self.wall),
                fmt_us(s.hist.percentile(0.50)),
                fmt_us(s.hist.percentile(0.90)),
                fmt_us(s.hist.percentile(0.99)),
                fmt_us(s.hist.percentile(0.999)),
                fmt_us(s.hist.max_us()),
            );
        }
        let h = &self.health;
        let _ = writeln!(
            out,
            "pool: dead={} retries={} failovers={} hedges={} hedge_wins={}",
            h.pool_dead, h.io_retries, h.io_failovers, h.io_hedges, h.io_hedge_wins,
        );
        if h.cache_hit_bytes > 0 || h.cache_resident_bytes > 0 {
            let _ = writeln!(
                out,
                "cache: hit_bytes={} resident_bytes={} evictions={} drift_ppm={}",
                h.cache_hit_bytes, h.cache_resident_bytes, h.cache_evictions, h.cache_drift_ppm,
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_stats(n: u64) -> OpStats {
        let mut s = OpStats::default();
        for i in 0..n {
            s.requests += 1;
            s.tokens += 4;
            s.hist.record(1_000 + i * 10);
        }
        s
    }

    #[test]
    fn entry_json_is_flat_and_gate_compatible() {
        let ident = vec![
            ("mode".to_string(), "\"served\"".to_string()),
            ("policy".to_string(), "\"topk\"".to_string()),
            ("streams".to_string(), "4".to_string()),
            ("rps".to_string(), "20".to_string()),
            ("mix".to_string(), "\"1:8\"".to_string()),
        ];
        let e = entry_json(&ident, "decode", &fake_stats(100), Duration::from_secs(2));
        // Flat: exactly one object, no nesting.
        assert_eq!(e.matches('{').count(), 1, "{e}");
        assert_eq!(e.matches('}').count(), 1);
        let v = Json::parse(&e).expect("entry parses");
        assert_eq!(v.get("mode").and_then(Json::as_str), Some("served"));
        assert_eq!(v.get("op").and_then(Json::as_str), Some("decode"));
        assert_eq!(v.get("mix").and_then(Json::as_str), Some("1:8"));
        assert_eq!(v.get("tokens_per_s").and_then(Json::as_f64), Some(200.0));
        assert!(v.get("p99_us").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(v.get("p999_us").is_some());
        assert_eq!(v.get("shed").and_then(Json::as_f64), Some(0.0));
    }

    #[test]
    fn report_json_parses_and_lists_active_ops() {
        let report = RunReport {
            cfg: RunConfig::default(),
            ident: vec![("mode".to_string(), "\"served\"".to_string())],
            decode: fake_stats(10),
            append: OpStats::default(), // no traffic → no entry
            health: HealthCounters {
                io_hedges: 3,
                io_hedge_wins: 2,
                pool_dead: 1,
                cache_hit_bytes: 4096,
                cache_resident_bytes: 2048,
                ..HealthCounters::default()
            },
            wall: Duration::from_secs(1),
        };
        let text = report.to_json();
        let v = Json::parse(&text).expect("report parses");
        let entries = v.get("entries").and_then(Json::as_arr).unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].get("op").and_then(Json::as_str), Some("decode"));
        assert_eq!(v.get("io_hedges").and_then(Json::as_f64), Some(3.0));
        assert_eq!(v.get("pool_dead").and_then(Json::as_f64), Some(1.0));
        assert_eq!(v.get("cache_hit_bytes").and_then(Json::as_f64), Some(4096.0));
        let table = report.render_table();
        assert!(table.contains("decode"), "{table}");
        assert!(!table.contains("append"), "{table}");
        assert!(table.contains("pool: dead=1"), "{table}");
        assert!(table.contains("cache: hit_bytes=4096"), "{table}");
    }

    #[test]
    fn fmt_us_scales() {
        assert_eq!(fmt_us(950), "950µs");
        assert_eq!(fmt_us(1_234), "1.23ms");
        assert_eq!(fmt_us(2_500_000), "2.50s");
    }
}
