//! Minimal keep-alive HTTP client for the serving wire protocol.
//!
//! One [`Client`] owns one TCP connection; the runner gives each worker
//! thread its own so concurrent requests really are concurrent at the
//! socket level (the server is thread-per-connection).

use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::serving::http;
use crate::serving::json::{self, Json};

/// Parsed accounting from an append/decode response.
#[derive(Clone, Copy, Debug, Default)]
pub struct Reply {
    /// Server-side execution wall time (sum over steps), µs.
    pub latency_us: u64,
    /// Server-side scheduler queue wait (sum over steps), µs.
    pub queue_us: u64,
    pub steps: u64,
    /// Cumulative fault-tolerance counters from the response's
    /// `"engine"` object (monotonic over the server's lifetime, so the
    /// last-seen values are the run's final snapshot).
    pub io_retries: u64,
    pub io_failovers: u64,
    pub io_hedges: u64,
    pub io_hedge_wins: u64,
    /// Number of pool members currently marked dead.
    pub pool_dead: u64,
    /// Cumulative bytes the shared hot-chunk RAM cache served in place
    /// of flash reads (monotonic, like the fault counters).
    pub cache_hit_bytes: u64,
    /// Bytes currently resident in the cache (gauge, last-seen wins).
    pub cache_resident_bytes: u64,
    /// Cumulative whole-chunk cache evictions.
    pub cache_evictions: u64,
    /// Hot-set drift score vs the calibrated layout, parts-per-million.
    pub cache_drift_ppm: u64,
}

pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self, String> {
        let stream =
            TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
        let _ = stream.set_nodelay(true);
        // A wedged server should fail the request, not hang the worker.
        let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
        let writer = stream.try_clone().map_err(|e| format!("clone socket: {e}"))?;
        Ok(Self {
            writer,
            reader: BufReader::new(stream),
        })
    }

    /// One keep-alive request/response exchange. Non-2xx statuses are
    /// errors carrying the server's `"error"` detail.
    pub fn request(&mut self, method: &str, path: &str, body: &str) -> Result<Json, String> {
        let head = format!(
            "{method} {path} HTTP/1.1\r\nHost: redline\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: keep-alive\r\n\r\n",
            body.len()
        );
        self.writer
            .write_all(head.as_bytes())
            .and_then(|()| self.writer.write_all(body.as_bytes()))
            .and_then(|()| self.writer.flush())
            .map_err(|e| format!("send {method} {path}: {e}"))?;
        let (status, bytes, _keep) = http::read_response(&mut self.reader)
            .map_err(|e| format!("read {method} {path}: {e}"))?;
        let text = String::from_utf8(bytes).map_err(|_| "non-UTF-8 response".to_string())?;
        let value = if text.trim().is_empty() {
            Json::Null
        } else {
            Json::parse(&text).map_err(|e| format!("bad response JSON: {e}"))?
        };
        if !(200..300).contains(&status) {
            let detail = value
                .get("error")
                .and_then(Json::as_str)
                .unwrap_or("no detail");
            return Err(format!("{method} {path}: HTTP {status}: {detail}"));
        }
        Ok(value)
    }

    pub fn get(&mut self, path: &str) -> Result<Json, String> {
        self.request("GET", path, "")
    }

    /// `POST /v1/streams` → new stream id.
    pub fn open_stream(&mut self) -> Result<usize, String> {
        let v = self.request("POST", "/v1/streams", "{}")?;
        v.get("stream")
            .and_then(Json::as_usize)
            .ok_or_else(|| "stream-open reply has no id".to_string())
    }

    /// `POST /v1/streams/{id}/append` with a `[tokens_per_frame * d]` frame.
    pub fn append(&mut self, stream: usize, frame: &[f32]) -> Result<Reply, String> {
        let mut body = String::with_capacity(frame.len() * 8 + 16);
        body.push_str("{\"frame\":");
        json::push_f32_array(&mut body, frame);
        body.push('}');
        let v = self.request("POST", &format!("/v1/streams/{stream}/append"), &body)?;
        Ok(reply_from(&v))
    }

    /// `POST /v1/streams/{id}/decode` for `steps` tokens. A deadline
    /// (milliseconds) rides the typed request API: it orders the
    /// server's interactive queue, earliest first.
    pub fn decode(
        &mut self,
        stream: usize,
        token: &[f32],
        steps: usize,
        deadline_ms: Option<u64>,
    ) -> Result<Reply, String> {
        let mut body = String::with_capacity(token.len() * 8 + 32);
        body.push_str("{\"token\":");
        json::push_f32_array(&mut body, token);
        body.push_str(&format!(",\"steps\":{steps}"));
        if let Some(ms) = deadline_ms {
            body.push_str(&format!(",\"deadline_ms\":{ms}"));
        }
        body.push('}');
        let v = self.request("POST", &format!("/v1/streams/{stream}/decode"), &body)?;
        Ok(reply_from(&v))
    }
}

/// Whether a [`Client::request`] error is an admission shed (HTTP 429:
/// the server is protecting its SLO). Sheds are expected under
/// overload — callers count them separately from transport/server
/// errors and keep the connection (the server answered; nothing is
/// wedged).
pub fn is_shed(err: &str) -> bool {
    err.contains("HTTP 429")
}

fn reply_from(v: &Json) -> Reply {
    let u64_of = |key: &str| {
        v.get(key)
            .and_then(Json::as_f64)
            .map(|x| x.max(0.0) as u64)
            .unwrap_or(0)
    };
    let engine = v.get("engine");
    let eng_u64 = |key: &str| {
        engine
            .and_then(|e| e.get(key))
            .and_then(Json::as_f64)
            .map(|x| x.max(0.0) as u64)
            .unwrap_or(0)
    };
    Reply {
        latency_us: u64_of("latency_us"),
        queue_us: u64_of("queue_us"),
        steps: u64_of("steps"),
        io_retries: eng_u64("io_retries"),
        io_failovers: eng_u64("io_failovers"),
        io_hedges: eng_u64("io_hedges"),
        io_hedge_wins: eng_u64("io_hedge_wins"),
        pool_dead: eng_u64("pool_dead"),
        cache_hit_bytes: eng_u64("cache_hit_bytes"),
        cache_resident_bytes: eng_u64("cache_resident_bytes"),
        cache_evictions: eng_u64("cache_evictions"),
        cache_drift_ppm: eng_u64("cache_drift_ppm"),
    }
}
