//! `redline compare` — diff two run files and issue regression verdicts.
//!
//! Entries are matched on the same identity fields the CI bench gate
//! (`scripts/bench_gate.rs`) uses, and the verdict rules are the gate's
//! rules: throughput (`tokens_per_s`) regresses when it *drops* past the
//! threshold, tail latency (`p99_us`, `p999_us`) regresses when it
//! *rises* past it. A run pair that passes `redline compare --pct N`
//! passes the bench gate at the same threshold, so developers can
//! pre-flight locally exactly what CI will enforce. Entries present on
//! only one side are reported but never fail (the matrix may grow).

use std::collections::BTreeMap;

use crate::serving::json::Json;

/// Identity fields forming the match key — keep in sync with
/// `ID_FIELDS` in `scripts/bench_gate.rs`.
pub const ID_FIELDS: [&str; 13] = [
    "mode",
    "policy",
    "prefetch",
    "threads",
    "streams",
    "devices",
    "op",
    "async_io",
    "queue_depth",
    "rps",
    "mix",
    "slo",
    "dtype",
];

/// Metrics compared, with direction: `true` = higher is better.
const METRICS: [(&str, bool); 3] = [
    ("tokens_per_s", true),
    ("p99_us", false),
    ("p999_us", false),
];

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Status {
    Ok,
    Regressed,
    Improved,
}

#[derive(Clone, Debug)]
pub struct Verdict {
    pub key: String,
    pub metric: &'static str,
    pub base: f64,
    pub cand: f64,
    pub status: Status,
}

#[derive(Clone, Debug)]
pub struct CompareReport {
    pub pct: f64,
    pub matched: usize,
    pub baseline_only: Vec<String>,
    pub candidate_only: Vec<String>,
    pub verdicts: Vec<Verdict>,
}

impl CompareReport {
    pub fn regressions(&self) -> usize {
        self.verdicts
            .iter()
            .filter(|v| v.status == Status::Regressed)
            .count()
    }

    pub fn improvements(&self) -> usize {
        self.verdicts
            .iter()
            .filter(|v| v.status == Status::Improved)
            .count()
    }

    /// Terminal rendering: one line per metric verdict plus a summary.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "redline compare: {} matched entries, threshold {}%",
            self.matched, self.pct
        );
        for v in &self.verdicts {
            let tag = match v.status {
                Status::Ok => "  ok  ",
                Status::Regressed => "REGRES",
                Status::Improved => "improv",
            };
            let delta = if v.base > 0.0 {
                (v.cand / v.base - 1.0) * 100.0
            } else {
                0.0
            };
            let _ = writeln!(
                out,
                "  [{tag}] {}: {} {:.1} -> {:.1} ({delta:+.1}%)",
                v.key, v.metric, v.base, v.cand
            );
        }
        for k in &self.baseline_only {
            let _ = writeln!(out, "  [ skip ] baseline-only entry: {k}");
        }
        for k in &self.candidate_only {
            let _ = writeln!(out, "  [ skip ] candidate-only entry: {k}");
        }
        let _ = writeln!(
            out,
            "verdict: {} regression(s), {} improvement(s), {} matched",
            self.regressions(),
            self.improvements(),
            self.matched
        );
        out
    }
}

/// Every object with a `tokens_per_s` field, anywhere in the document
/// (handles both redline run files and `bench_e2e`-style reports).
fn collect_entries<'a>(v: &'a Json, out: &mut Vec<&'a Json>) {
    match v {
        Json::Obj(fields) => {
            if v.get("tokens_per_s").is_some() {
                out.push(v);
            } else {
                for (_, child) in fields {
                    collect_entries(child, out);
                }
            }
        }
        Json::Arr(items) => {
            for item in items {
                collect_entries(item, out);
            }
        }
        _ => {}
    }
}

fn entry_key(e: &Json) -> String {
    ID_FIELDS
        .iter()
        .map(|f| match e.get(f) {
            None => String::new(),
            Some(Json::Str(s)) => s.clone(),
            Some(other) => other.to_string(),
        })
        .collect::<Vec<_>>()
        .join("|")
}

fn index_entries(text: &str) -> Result<BTreeMap<String, Vec<(&'static str, f64)>>, String> {
    let doc = Json::parse(text).map_err(|e| format!("bad run file: {e}"))?;
    let mut entries = Vec::new();
    collect_entries(&doc, &mut entries);
    let mut by_key = BTreeMap::new();
    for e in entries {
        let metrics: Vec<(&'static str, f64)> = METRICS
            .iter()
            .filter_map(|&(name, _)| {
                e.get(name).and_then(Json::as_f64).map(|v| (name, v))
            })
            .collect();
        by_key.insert(entry_key(e), metrics);
    }
    Ok(by_key)
}

/// Compare two run files (text contents, not paths). `pct` is the
/// symmetric threshold: beyond it in the bad direction → regressed,
/// beyond it in the good direction → improved.
pub fn compare_files(baseline: &str, candidate: &str, pct: f64) -> Result<CompareReport, String> {
    let base = index_entries(baseline)?;
    let cand = index_entries(candidate)?;
    if base.is_empty() {
        return Err("baseline has no entries with tokens_per_s".to_string());
    }
    if cand.is_empty() {
        return Err("candidate has no entries with tokens_per_s".to_string());
    }
    let mut report = CompareReport {
        pct,
        matched: 0,
        baseline_only: Vec::new(),
        candidate_only: cand
            .keys()
            .filter(|k| !base.contains_key(*k))
            .cloned()
            .collect(),
        verdicts: Vec::new(),
    };
    let floor = 1.0 - pct / 100.0;
    let ceil = 1.0 + pct / 100.0;
    for (key, base_metrics) in &base {
        let Some(cand_metrics) = cand.get(key) else {
            report.baseline_only.push(key.clone());
            continue;
        };
        report.matched += 1;
        for &(name, higher_is_better) in &METRICS {
            let b = base_metrics.iter().find(|(n, _)| *n == name).map(|&(_, v)| v);
            let c = cand_metrics.iter().find(|(n, _)| *n == name).map(|&(_, v)| v);
            let (Some(b), Some(c)) = (b, c) else { continue };
            if b <= 0.0 || c <= 0.0 {
                continue; // no meaningful ratio (e.g. zero-error run vs not)
            }
            let ratio = c / b;
            let status = if higher_is_better {
                if ratio < floor {
                    Status::Regressed
                } else if ratio > ceil {
                    Status::Improved
                } else {
                    Status::Ok
                }
            } else if ratio > ceil {
                Status::Regressed
            } else if ratio < floor {
                Status::Improved
            } else {
                Status::Ok
            };
            report.verdicts.push(Verdict {
                key: key.clone(),
                metric: name,
                base: b,
                cand: c,
                status,
            });
        }
    }
    if report.matched == 0 {
        return Err(format!(
            "no entries match between the runs ({} baseline, {} candidate) — \
             were they produced with the same identity (policy/streams/rps/mix)?",
            base.len(),
            cand.len()
        ));
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_file(tps: f64, p99: u64, p999: u64) -> String {
        format!(
            "{{\"bench\":\"serving\",\"entries\":[{{\"mode\":\"served\",\"policy\":\"topk\",\
             \"streams\":4,\"rps\":20,\"mix\":\"1:8\",\"op\":\"decode\",\
             \"tokens_per_s\":{tps},\"p99_us\":{p99},\"p999_us\":{p999}}}]}}"
        )
    }

    #[test]
    fn identical_runs_have_no_regressions() {
        let a = run_file(100.0, 5_000, 9_000);
        let r = compare_files(&a, &a, 10.0).unwrap();
        assert_eq!(r.matched, 1);
        assert_eq!(r.regressions(), 0);
        assert_eq!(r.improvements(), 0);
        assert_eq!(r.verdicts.len(), 3);
        assert!(r.render().contains("0 regression(s)"), "{}", r.render());
    }

    #[test]
    fn throughput_drop_and_tail_rise_regress() {
        let base = run_file(100.0, 5_000, 9_000);
        let slower = run_file(80.0, 5_100, 9_100); // -20% tput
        let r = compare_files(&base, &slower, 10.0).unwrap();
        assert_eq!(r.regressions(), 1);
        let spikier = run_file(99.0, 8_000, 30_000); // p99 +60%, p999 +233%
        let r = compare_files(&base, &spikier, 10.0).unwrap();
        assert_eq!(r.regressions(), 2);
        // Better in the good direction is an improvement, not a failure.
        let faster = run_file(150.0, 2_000, 3_000);
        let r = compare_files(&base, &faster, 10.0).unwrap();
        assert_eq!(r.regressions(), 0);
        assert_eq!(r.improvements(), 3);
    }

    #[test]
    fn unmatched_entries_are_reported_not_failed() {
        let base = run_file(100.0, 5_000, 9_000);
        let other = base.replace("\"1:8\"", "\"0:1\""); // different identity
        assert!(compare_files(&base, &other, 10.0).is_err()); // nothing matches at all
        // A candidate with the matched entry plus a new one: the extra
        // entry is reported, never failed.
        let entry_appended = base.replace(
            "}]}",
            "},{\"mode\":\"served\",\"op\":\"append\",\"tokens_per_s\":50.0,\"p99_us\":100}]}",
        );
        let r = compare_files(&base, &entry_appended, 10.0).unwrap();
        assert_eq!(r.matched, 1);
        assert_eq!(r.regressions(), 0);
        assert_eq!(r.candidate_only.len(), 1);
        assert!(r.render().contains("candidate-only"), "{}", r.render());
    }

    #[test]
    fn keys_use_identity_fields() {
        let e = Json::parse(
            "{\"mode\":\"served\",\"policy\":\"topk\",\"streams\":4,\"rps\":20,\
             \"mix\":\"1:8\",\"op\":\"decode\",\"tokens_per_s\":1}",
        )
        .unwrap();
        // No `dtype` field → empty trailing component, so serving
        // entries produced before the dtype knob still match.
        assert_eq!(entry_key(&e), "served|topk|||4||decode|||20|1:8||");
    }
}
