//! The network serving front end: a dependency-free HTTP/1.1 server
//! (`std::net`, thread-per-connection) fronting a [`Scheduler`].
//!
//! ## Wire protocol (JSON over HTTP/1.1, keep-alive)
//!
//! | Endpoint                        | Meaning                                   |
//! |---------------------------------|-------------------------------------------|
//! | `GET  /healthz`                 | liveness — `200 ok`, or `200 degraded: …` when a pool member is dead but replication keeps serving |
//! | `GET  /metrics`                 | text exposition of the engine metrics fold|
//! | `GET  /v1/config`               | engine/server configuration snapshot      |
//! | `POST /v1/streams`              | open a stream (lazily binds a `Session`)  |
//! | `POST /v1/streams/{id}/append`  | vision prefill: `{"frame":[f32;T*d]}`     |
//! | `POST /v1/streams/{id}/decode`  | `{"token":[f32;d],"steps":N,"echo":bool}` |
//!
//! Every stream-operation body additionally accepts the scheduling
//! fields of the typed request API: `"class"` (`"interactive"` /
//! `"bulk"`, overriding the per-op default) and `"deadline_ms"` (orders
//! the interactive queue, earliest first). Bodies are decoded once into
//! a typed [`ApiRequest`] and dispatched through one table
//! ([`STREAM_OPS`]); validation failures are `400`s that name the
//! offending field in a `"field"` key. Admission sheds (queue delay
//! past the SLO, per-stream prefill budget) are `429`s carrying a
//! `retry_after_ms` hint; hard capacity and shutdown stay `503`.
//!
//! Append/decode responses carry per-request latency (execution wall +
//! queue wait, per decode step), the request's [`StageStats`] breakdown,
//! and a snapshot of the engine's global `io.*` / `batch.*` counters, so
//! a network caller sees exactly the accounting an in-process caller
//! gets. Requests flow through the scheduler — concurrent decodes from
//! different connections fuse into cross-stream batches exactly like
//! in-process traffic, and outputs stay bit-identical to solo
//! [`Session::decode_step`](crate::coordinator::Session::decode_step)
//! calls (pinned by `rust/tests/test_serving.rs`).
//!
//! ## Connection handling
//!
//! One acceptor thread; each connection gets its own handler thread with
//! a bounded total ([`ServerConfig::max_connections`]) — a connection
//! beyond the bound is answered `503` and closed, never left hanging.
//! Handlers poll the shutdown flag on a read-timeout tick, so
//! [`Server::shutdown`] drains idle keep-alive connections promptly and
//! then shuts the scheduler down (idempotently).

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::{
    Class, Completion, Request, RequestOpts, Scheduler, StageStats, SubmitError,
};
use crate::model::ModelSpec;
use crate::serving::http::{self, HttpError, HttpRequest};
use crate::serving::json::{self, Json};

/// Most decode steps honored per request (larger asks are a 400; loop
/// client-side instead of holding one connection thread for minutes).
const MAX_STEPS_PER_REQUEST: usize = 1024;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address, e.g. `127.0.0.1:0` (0 = OS-assigned port; read the
    /// real one back from [`Server::local_addr`]).
    pub listen: String,
    /// Concurrent-connection bound; excess connections get `503`.
    pub max_connections: usize,
    /// Request-body byte cap; larger bodies get `413`.
    pub max_body_bytes: usize,
    /// Idle-read poll tick: how quickly handlers notice shutdown, and
    /// the mid-request inactivity timeout (`408`).
    pub read_timeout: Duration,
    /// Extra `"key": <raw JSON value>` pairs appended to `GET
    /// /v1/config` (the CLI adds flags the engine cannot introspect).
    pub extra_config: Vec<(String, String)>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            listen: "127.0.0.1:0".to_string(),
            max_connections: 64,
            max_body_bytes: 8 << 20,
            read_timeout: Duration::from_secs(2),
            extra_config: Vec::new(),
        }
    }
}

struct ServerInner {
    scheduler: Scheduler,
    cfg: ServerConfig,
    spec: ModelSpec,
    stopping: AtomicBool,
    /// Live connection-handler threads (acceptor enforces the bound).
    active: AtomicUsize,
    /// Monotonic stream-id allocator; ids < `next` are open.
    next_stream: Mutex<usize>,
}

/// A running serving front end. Dropping it (or calling
/// [`Server::shutdown`]) stops accepting, drains handlers, and shuts the
/// scheduler down.
pub struct Server {
    addr: SocketAddr,
    inner: Arc<ServerInner>,
    acceptor: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving `scheduler` (callers should
    /// [`warmup`](crate::coordinator::Engine::warmup) the engine first so
    /// the first request doesn't pay compile stalls).
    pub fn start(cfg: ServerConfig, scheduler: Scheduler) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("cannot bind {}", cfg.listen))?;
        let addr = listener.local_addr().context("no local addr")?;
        let spec = scheduler.engine().spec();
        let inner = Arc::new(ServerInner {
            scheduler,
            cfg,
            spec,
            stopping: AtomicBool::new(false),
            active: AtomicUsize::new(0),
            next_stream: Mutex::new(0),
        });
        let accept_inner = inner.clone();
        let acceptor = std::thread::Builder::new()
            .name("nc-accept".to_string())
            .spawn(move || accept_loop(listener, accept_inner))
            .context("cannot spawn acceptor")?;
        Ok(Server {
            addr,
            inner,
            acceptor: Some(acceptor),
        })
    }

    /// The actually-bound address (resolves `:0` to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Open streams so far (monotonic).
    pub fn streams_open(&self) -> usize {
        *self.inner.next_stream.lock().unwrap()
    }

    /// Graceful stop: stop accepting, drain connection handlers, shut
    /// the scheduler down.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        let Some(acceptor) = self.acceptor.take() else {
            return;
        };
        self.inner.stopping.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        let _ = acceptor.join();
        // Handlers notice `stopping` within one read-timeout tick.
        let deadline = Instant::now() + self.inner.cfg.read_timeout + Duration::from_secs(3);
        while self.inner.active.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        self.inner.scheduler.shutdown();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(listener: TcpListener, inner: Arc<ServerInner>) {
    for conn in listener.incoming() {
        if inner.stopping.load(Ordering::SeqCst) {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue,
        };
        // Connection bound: count optimistically, back out + 503 when
        // over. The client gets an answer, never a hang.
        let now_active = inner.active.fetch_add(1, Ordering::SeqCst) + 1;
        if now_active > inner.cfg.max_connections {
            inner.active.fetch_sub(1, Ordering::SeqCst);
            let mut stream = stream;
            let _ = http::write_response(
                &mut stream,
                503,
                "application/json",
                b"{\"error\":\"connection limit reached\"}",
                false,
            );
            continue;
        }
        let conn_inner = inner.clone();
        let spawned = std::thread::Builder::new()
            .name("nc-conn".to_string())
            .spawn(move || {
                handle_connection(&conn_inner, stream);
                conn_inner.active.fetch_sub(1, Ordering::SeqCst);
            });
        if spawned.is_err() {
            // Thread exhaustion: the optimistic count must be undone
            // (the connection itself just drops closed).
            inner.active.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

fn handle_connection(inner: &Arc<ServerInner>, stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(inner.cfg.read_timeout));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        if inner.stopping.load(Ordering::SeqCst) {
            break;
        }
        match http::read_request(&mut reader, inner.cfg.max_body_bytes) {
            Ok(Some(req)) => {
                let keep = req.keep_alive();
                let resp = route(inner, &req);
                if http::write_response(
                    &mut writer,
                    resp.status,
                    resp.content_type,
                    resp.body.as_bytes(),
                    keep,
                )
                .is_err()
                {
                    break;
                }
                if !keep {
                    break;
                }
            }
            Ok(None) => break, // peer closed between requests
            Err(HttpError::Idle) => continue, // poll tick: re-check stopping
            Err(HttpError::Bad { status, detail }) => {
                let _ = http::write_response(
                    &mut writer,
                    status,
                    "application/json",
                    error_json(&detail).as_bytes(),
                    false,
                );
                break;
            }
            Err(HttpError::Io(_)) => break,
        }
    }
}

struct Response {
    status: u16,
    content_type: &'static str,
    body: String,
}

impl Response {
    fn json(status: u16, body: String) -> Self {
        Response {
            status,
            content_type: "application/json",
            body,
        }
    }

    fn error(status: u16, msg: &str) -> Self {
        Self::json(status, error_json(msg))
    }

    fn text(status: u16, body: String) -> Self {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            body,
        }
    }
}

fn error_json(msg: &str) -> String {
    let mut s = String::from("{\"error\":");
    json::push_str_escaped(&mut s, msg);
    s.push('}');
    s
}

/// `/v1/streams/{id}/{op}` → `(id, op)`.
fn parse_stream_path(path: &str) -> Option<(usize, &str)> {
    let rest = path.strip_prefix("/v1/streams/")?;
    let (id, op) = rest.split_once('/')?;
    if op.is_empty() || op.contains('/') {
        return None;
    }
    Some((id.parse().ok()?, op))
}

fn route(inner: &Arc<ServerInner>, req: &HttpRequest) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => Response::text(200, healthz_text(inner)),
        ("GET", "/metrics") => Response::text(200, metrics_text(inner)),
        ("GET", "/v1/config") => Response::json(200, config_json(inner)),
        ("POST", "/v1/streams") => open_stream(inner),
        (_, "/healthz") | (_, "/metrics") | (_, "/v1/config") | (_, "/v1/streams") => {
            Response::error(405, "method not allowed")
        }
        _ => match parse_stream_path(&req.path) {
            Some((stream, op)) => stream_route(inner, req, stream, op),
            None => Response::error(404, "unknown route"),
        },
    }
}

/// A stream operation, decoded and validated: the typed request API
/// between the wire and the scheduler. [`ApiRequest::parse`] is the
/// single decode step for every `/v1/streams/{id}/{op}` body — there is
/// no per-op parsing path to drift.
enum ApiRequest {
    /// Wire op `append` (name kept for compatibility): a vision prefill.
    Prefill {
        frame: Vec<f32>,
        echo: bool,
        opts: RequestOpts,
    },
    Decode {
        token: Vec<f32>,
        steps: usize,
        echo: bool,
        opts: RequestOpts,
    },
}

/// A request-body validation failure naming the field at fault; the 400
/// body carries it as a `"field"` key so clients can react
/// programmatically.
struct FieldError {
    field: &'static str,
    detail: String,
}

impl FieldError {
    fn new(field: &'static str, detail: impl Into<String>) -> Self {
        FieldError {
            field,
            detail: detail.into(),
        }
    }

    fn response(&self) -> Response {
        let mut b = String::from("{\"error\":");
        json::push_str_escaped(&mut b, &format!("field {:?}: {}", self.field, self.detail));
        b.push_str(",\"field\":");
        json::push_str_escaped(&mut b, self.field);
        b.push('}');
        Response::json(400, b)
    }
}

/// Scheduling fields shared by every stream operation.
fn parse_opts(body: &Json) -> Result<RequestOpts, FieldError> {
    let class = match body.get("class") {
        None => None,
        Some(v) => {
            let s = v
                .as_str()
                .ok_or_else(|| FieldError::new("class", "must be a string"))?;
            Some(
                s.parse::<Class>()
                    .map_err(|e| FieldError::new("class", e))?,
            )
        }
    };
    let deadline = match body.get("deadline_ms") {
        None => None,
        Some(v) => {
            let ms = v
                .as_usize()
                .filter(|&ms| ms >= 1)
                .ok_or_else(|| {
                    FieldError::new("deadline_ms", "must be a positive integer (milliseconds)")
                })?;
            Some(Duration::from_millis(ms as u64))
        }
    };
    Ok(RequestOpts { class, deadline })
}

impl ApiRequest {
    fn parse(op: &str, body: &Json, spec: &ModelSpec) -> Result<ApiRequest, FieldError> {
        // Shared fields first, so e.g. a bad "class" is reported even
        // alongside a bad payload.
        let echo = match body.get("echo") {
            None => false,
            Some(v) => v
                .as_bool()
                .ok_or_else(|| FieldError::new("echo", "must be a boolean"))?,
        };
        let opts = parse_opts(body)?;
        match op {
            "append" => {
                let want = spec.tokens_per_frame * spec.d;
                let frame = body
                    .get("frame")
                    .and_then(Json::as_f32s)
                    .ok_or_else(|| {
                        FieldError::new(
                            "frame",
                            format!("required: [f32; tokens_per_frame * d] = [f32; {want}]"),
                        )
                    })?;
                if frame.len() != want {
                    return Err(FieldError::new(
                        "frame",
                        format!("has {} values, model wants {want}", frame.len()),
                    ));
                }
                Ok(ApiRequest::Prefill { frame, echo, opts })
            }
            "decode" => {
                let steps = match body.get("steps") {
                    None => 1,
                    Some(v) => v
                        .as_usize()
                        .filter(|n| (1..=MAX_STEPS_PER_REQUEST).contains(n))
                        .ok_or_else(|| {
                            FieldError::new(
                                "steps",
                                format!("must be an integer in 1..={MAX_STEPS_PER_REQUEST}"),
                            )
                        })?,
                };
                let token = body
                    .get("token")
                    .and_then(Json::as_f32s)
                    .ok_or_else(|| {
                        FieldError::new("token", format!("required: [f32; d] = [f32; {}]", spec.d))
                    })?;
                if token.len() != spec.d {
                    return Err(FieldError::new(
                        "token",
                        format!("has {} values, model wants {}", token.len(), spec.d),
                    ));
                }
                Ok(ApiRequest::Decode {
                    token,
                    steps,
                    echo,
                    opts,
                })
            }
            other => Err(FieldError::new("op", format!("unknown operation {other:?}"))),
        }
    }
}

type OpHandler = fn(&Arc<ServerInner>, usize, ApiRequest) -> Response;

/// The single dispatch table for stream operations: wire op name →
/// handler. `append` keeps its wire name; internally it is the prefill
/// path of the typed API.
const STREAM_OPS: &[(&str, OpHandler)] = &[("append", op_prefill), ("decode", op_decode)];

fn stream_route(inner: &Arc<ServerInner>, req: &HttpRequest, stream: usize, op: &str) -> Response {
    let Some(&(_, handler)) = STREAM_OPS.iter().find(|(name, _)| *name == op) else {
        return Response::error(404, "unknown route");
    };
    if req.method != "POST" {
        return Response::error(405, "method not allowed");
    }
    if stream >= *inner.next_stream.lock().unwrap() {
        return Response::error(404, "unknown stream (open one with POST /v1/streams)");
    }
    let body = match req.body_str().map(Json::parse) {
        Ok(Ok(v)) => v,
        Ok(Err(e)) => return Response::error(400, &format!("bad JSON body: {e}")),
        Err(_) => return Response::error(400, "body is not valid UTF-8"),
    };
    match ApiRequest::parse(op, &body, &inner.spec) {
        Ok(api) => handler(inner, stream, api),
        Err(e) => e.response(),
    }
}

fn open_stream(inner: &Arc<ServerInner>) -> Response {
    let id = {
        let mut next = inner.next_stream.lock().unwrap();
        if *next >= inner.scheduler.max_streams() {
            return Response::error(503, "stream capacity reached");
        }
        let id = *next;
        *next += 1;
        id
    };
    Response::json(
        200,
        format!(
            "{{\"stream\":{id},\"d\":{},\"tokens_per_frame\":{}}}",
            inner.spec.d, inner.spec.tokens_per_frame
        ),
    )
}

/// Typed admission errors → HTTP: SLO/budget sheds are `429` with a
/// `retry_after_ms` hint (transient — the client backs off and
/// retries); capacity and shutdown are `503`, a bad stream index `404`.
fn submit_error_response(e: &SubmitError) -> Response {
    use std::fmt::Write as _;
    let status = if e.is_shed() {
        429
    } else if matches!(e, SubmitError::UnknownStream { .. }) {
        404
    } else {
        503
    };
    let mut b = String::from("{\"error\":");
    json::push_str_escaped(&mut b, &format!("rejected: {e}"));
    if let Some(ra) = e.retry_after() {
        let _ = write!(b, ",\"retry_after_ms\":{}", ra.as_millis().max(1));
    }
    b.push('}');
    Response::json(status, b)
}

/// Submit one request and wait for its completion.
fn serve_one(inner: &Arc<ServerInner>, request: Request) -> Result<Completion, Response> {
    let rx = inner
        .scheduler
        .submit(request)
        .map_err(|e| submit_error_response(&e))?;
    rx.recv()
        .map_err(|_| Response::error(500, "scheduler dropped the request (shutting down)"))
}

fn op_prefill(inner: &Arc<ServerInner>, stream: usize, api: ApiRequest) -> Response {
    let ApiRequest::Prefill { frame, echo, opts } = api else {
        unreachable!("dispatch table routes append bodies here");
    };
    let completion = match serve_one(inner, Request::Prefill { stream, frame, opts }) {
        Ok(c) => c,
        Err(resp) => return resp,
    };
    match &completion.output {
        Ok(output) => {
            let output = echo.then_some(output.as_slice());
            serve_response(inner, "append", stream, &completion.stats, &[&completion], output)
        }
        Err(e) => Response::error(422, e),
    }
}

fn op_decode(inner: &Arc<ServerInner>, stream: usize, api: ApiRequest) -> Response {
    let ApiRequest::Decode {
        token,
        steps,
        echo,
        opts,
    } = api
    else {
        unreachable!("dispatch table routes decode bodies here");
    };
    let mut agg = StageStats::default();
    let mut completions: Vec<Completion> = Vec::with_capacity(steps);
    let mut last_output: Vec<f32> = Vec::new();
    for step in 0..steps {
        let completion = match serve_one(
            inner,
            Request::Decode {
                stream,
                token: token.clone(),
                opts,
            },
        ) {
            Ok(c) => c,
            Err(resp) => return resp,
        };
        match &completion.output {
            Ok(output) => {
                if echo && step + 1 == steps {
                    last_output = output.clone();
                }
                agg.absorb(&completion.stats);
                completions.push(completion);
            }
            Err(e) => {
                return Response::error(422, &format!("decode step {step}: {e}"));
            }
        }
    }
    let refs: Vec<&Completion> = completions.iter().collect();
    let output = echo.then_some(last_output.as_slice());
    serve_response(inner, "decode", stream, &agg, &refs, output)
}

/// Build the accounting-rich response every served request returns.
fn serve_response(
    inner: &Arc<ServerInner>,
    op: &str,
    stream: usize,
    stats: &StageStats,
    completions: &[&Completion],
    output: Option<&[f32]>,
) -> Response {
    use std::fmt::Write as _;
    let exec_us: u128 = completions.iter().map(|c| c.exec_wall.as_micros()).sum();
    let queue_us: u128 = completions.iter().map(|c| c.queue_wait.as_micros()).sum();
    let mut b = String::with_capacity(512);
    let _ = write!(
        b,
        "{{\"stream\":{stream},\"op\":\"{op}\",\"steps\":{},\
         \"latency_us\":{exec_us},\"queue_us\":{queue_us},\"step_latency_us\":[",
        completions.len(),
    );
    for (i, c) in completions.iter().enumerate() {
        if i > 0 {
            b.push(',');
        }
        let _ = write!(b, "{}", c.exec_wall.as_micros());
    }
    let _ = write!(
        b,
        "],\"io_us\":{},\"compute_us\":{},\"select_us\":{},\"host_us\":{},\
         \"bytes_loaded\":{},\"prefetch_hits\":{},\"retained\":{:.6}",
        stats.io.as_micros(),
        stats.compute.as_micros(),
        stats.select.as_micros(),
        stats.host.as_micros(),
        stats.bytes_loaded,
        stats.prefetch_hits,
        stats.retained_fraction(),
    );
    // Global engine counters (monotonic — network callers diff
    // successive responses the way in-process callers diff
    // `Engine::metrics` snapshots).
    let m = inner.scheduler.engine().metrics();
    let _ = write!(
        b,
        ",\"engine\":{{\"io_s\":{:.9},\"io_bytes\":{},\"io_shared_bytes\":{},\
         \"io_overlapped_s\":{:.9},\"batch_batches\":{},\"batch_members\":{},\
         \"io_retries\":{},\"io_failovers\":{},\"io_hedges\":{},\"io_hedge_wins\":{},\
         \"pool_dead\":{},\"cache_hit_bytes\":{},\"cache_resident_bytes\":{},\
         \"cache_evictions\":{},\"cache_drift_ppm\":{}}}",
        m.total("io").as_secs_f64(),
        m.bytes("io"),
        m.bytes("io.shared_bytes"),
        m.total("io.overlapped").as_secs_f64(),
        m.count("batch.occupancy"),
        m.bytes("batch.occupancy"),
        m.bytes("io.retries"),
        m.bytes("io.failovers"),
        m.bytes("io.hedges"),
        m.bytes("io.hedge_wins"),
        m.bytes("pool.dead"),
        m.bytes("io.cache_hit_bytes"),
        m.bytes("cache.resident_bytes"),
        m.bytes("cache.evictions"),
        m.bytes("cache.drift_ppm"),
    );
    if let Some(out) = output {
        b.push_str(",\"output\":");
        json::push_f32_array(&mut b, out);
    }
    b.push('}');
    Response::json(200, b)
}

/// `/healthz` body: `ok` while every pool member is live. A pool
/// serving around a dead member answers `degraded: …` — still `200`,
/// because replica-covered extents keep serving; orchestrators alert on
/// the body and pull `/metrics` for the failover/hedge counters.
fn healthz_text(inner: &Arc<ServerInner>) -> String {
    use std::fmt::Write as _;
    let h = inner.scheduler.engine().pool_health();
    if h.dead_members.is_empty() {
        return "ok\n".to_string();
    }
    let mut b = String::from("degraded: dead pool members [");
    for (i, m) in h.dead_members.iter().enumerate() {
        if i > 0 {
            b.push(',');
        }
        let _ = write!(b, "{m}");
    }
    let _ = writeln!(
        b,
        "], serving replica-covered extents (retries {}, failovers {}, hedges {})",
        h.retries, h.failovers, h.hedges
    );
    b
}

/// Text exposition of the engine metrics fold plus server gauges.
fn metrics_text(inner: &Arc<ServerInner>) -> String {
    use std::fmt::Write as _;
    let m = inner.scheduler.engine().metrics();
    let mut out = String::with_capacity(1024);
    out.push_str("# neuron-chunking serving metrics (counters since engine start)\n");
    for (stage, d) in m.stages() {
        let _ = writeln!(out, "nc_stage_seconds{{stage=\"{stage}\"}} {:.9}", d.as_secs_f64());
    }
    for (stage, c) in m.counts_iter() {
        let _ = writeln!(out, "nc_stage_count{{stage=\"{stage}\"}} {c}");
    }
    for (stage, bytes) in m.bytes_iter() {
        let _ = writeln!(out, "nc_stage_bytes{{stage=\"{stage}\"}} {bytes}");
    }
    // Active storage dtype as an info-style gauge; the matching traffic
    // counter (`io.bytes_<dtype>`) is in the generic byte loop above.
    let _ = writeln!(
        out,
        "nc_storage_dtype{{dtype=\"{}\"}} 1",
        inner.scheduler.engine().dtype().name()
    );
    let _ = writeln!(
        out,
        "nc_server_active_connections {}",
        inner.active.load(Ordering::SeqCst)
    );
    let _ = writeln!(out, "nc_server_streams_open {}", *inner.next_stream.lock().unwrap());
    let _ = writeln!(out, "nc_server_queued_requests {}", inner.scheduler.queued());
    // Per-class admission accounting: current queue depth, served/shed
    // totals, and the cumulative queue delay (µs) of served requests
    // (divide by `nc_requests_total` for the mean delay).
    let adm = inner.scheduler.admission();
    for (class, c) in adm.classes() {
        let _ = writeln!(out, "nc_queue_depth{{class=\"{class}\"}} {}", c.queued);
        let _ = writeln!(out, "nc_requests_total{{class=\"{class}\"}} {}", c.served);
        let _ = writeln!(out, "nc_shed_total{{class=\"{class}\"}} {}", c.shed);
        let _ = writeln!(
            out,
            "nc_queue_delay_us_total{{class=\"{class}\"}} {}",
            c.queue_delay_us
        );
    }
    // Derived hot-chunk cache hit ratio: bytes served from RAM over all
    // bytes the decode path demanded (hits + flash reads). The raw
    // counters (`io.cache_hit_bytes`, `cache.*`) are in the generic
    // byte-gauge loop above.
    let hit = m.bytes("io.cache_hit_bytes");
    if hit > 0 || m.bytes("cache.budget_bytes") > 0 {
        let demanded = hit + m.bytes("io");
        let ratio = if demanded > 0 {
            hit as f64 / demanded as f64
        } else {
            0.0
        };
        let _ = writeln!(out, "nc_cache_hit_ratio {ratio:.6}");
    }
    out
}

/// Engine/server configuration snapshot — the loadgen stamps these into
/// its run reports so `redline compare` and the bench gate match entries
/// on true served identity, not client-side guesses.
fn config_json(inner: &Arc<ServerInner>) -> String {
    use std::fmt::Write as _;
    let engine = inner.scheduler.engine();
    let mut b = String::with_capacity(256);
    b.push_str("{\"model\":");
    json::push_str_escaped(&mut b, &inner.spec.name);
    b.push_str(",\"policy\":");
    json::push_str_escaped(&mut b, engine.policy().name());
    b.push_str(",\"dtype\":");
    json::push_str_escaped(&mut b, engine.dtype().name());
    let _ = write!(
        b,
        ",\"d\":{},\"tokens_per_frame\":{},\"layers\":{},\"prefetch\":{},\"threads\":{},\
         \"devices\":{},\"async_io\":{},\"queue_depth\":{},\"workers\":{},\"max_streams\":{},\
         \"max_connections\":{}",
        inner.spec.d,
        inner.spec.tokens_per_frame,
        inner.spec.layers,
        engine.prefetch(),
        engine.exec_threads(),
        engine.devices(),
        engine.async_io(),
        engine.io_queue_depth(),
        inner.scheduler.workers(),
        inner.scheduler.max_streams(),
        inner.cfg.max_connections,
    );
    let _ = write!(b, ",\"cache_mb\":{}", engine.cache_mb());
    // Admission-control / disaggregation knobs, from the scheduler's own
    // config so the served values cannot drift from the ones in force.
    let sched = inner.scheduler.config();
    match sched.slo {
        Some(slo) => {
            let _ = write!(b, ",\"slo_ms\":{}", slo.as_millis());
        }
        None => b.push_str(",\"slo_ms\":null"),
    }
    let _ = write!(
        b,
        ",\"prefill_budget\":{},\"prefill_chunk\":{}",
        sched.prefill_budget, sched.prefill_chunk
    );
    for (key, raw) in &inner.cfg.extra_config {
        b.push(',');
        json::push_str_escaped(&mut b, key);
        b.push(':');
        b.push_str(raw);
    }
    b.push('}');
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_paths_parse() {
        assert_eq!(parse_stream_path("/v1/streams/3/decode"), Some((3, "decode")));
        assert_eq!(parse_stream_path("/v1/streams/0/append"), Some((0, "append")));
        for bad in [
            "/v1/streams",
            "/v1/streams/",
            "/v1/streams/3",
            "/v1/streams/x/decode",
            "/v1/streams/3/decode/extra",
            "/v2/streams/3/decode",
        ] {
            assert_eq!(parse_stream_path(bad), None, "{bad}");
        }
    }

    #[test]
    fn error_bodies_escape() {
        assert_eq!(error_json("a\"b"), "{\"error\":\"a\\\"b\"}");
    }

    fn parse(op: &str, body: &str) -> Result<ApiRequest, FieldError> {
        ApiRequest::parse(op, &Json::parse(body).unwrap(), &ModelSpec::tiny())
    }

    #[test]
    fn api_request_400s_name_the_offending_field() {
        // tiny: d = 64, tokens_per_frame = 8 → frame wants 512 values.
        let token = format!("[{}]", vec!["0.1"; 64].join(","));
        let cases: Vec<(&str, String, &str)> = vec![
            ("append", "{}".into(), "frame"),
            ("append", "{\"frame\":[1.0]}".into(), "frame"),
            ("append", "{\"frame\":\"x\"}".into(), "frame"),
            ("append", "{\"class\":5}".into(), "class"),
            ("append", "{\"class\":\"speedy\"}".into(), "class"),
            ("append", "{\"deadline_ms\":0}".into(), "deadline_ms"),
            ("append", "{\"deadline_ms\":-3}".into(), "deadline_ms"),
            ("append", "{\"echo\":\"yes\"}".into(), "echo"),
            ("decode", "{}".into(), "token"),
            ("decode", "{\"token\":[0.1,0.2]}".into(), "token"),
            ("decode", format!("{{\"token\":{token},\"steps\":0}}"), "steps"),
            ("decode", format!("{{\"token\":{token},\"steps\":4096}}"), "steps"),
            ("decode", format!("{{\"token\":{token},\"steps\":1.5}}"), "steps"),
        ];
        for (op, body, field) in cases {
            let err = parse(op, &body).err().unwrap_or_else(|| {
                panic!("{op} {body} should fail on field {field:?}")
            });
            assert_eq!(err.field, field, "{op} {body}: {}", err.detail);
            let resp = err.response();
            assert_eq!(resp.status, 400);
            assert!(
                resp.body.contains(&format!("\"field\":\"{field}\"")),
                "{}",
                resp.body
            );
        }
    }

    #[test]
    fn api_request_parses_scheduling_fields() {
        let token = format!("[{}]", vec!["0.1"; 64].join(","));
        let body = format!(
            "{{\"token\":{token},\"steps\":3,\"class\":\"bulk\",\"deadline_ms\":20,\"echo\":true}}"
        );
        match parse("decode", &body).unwrap() {
            ApiRequest::Decode {
                token,
                steps,
                echo,
                opts,
            } => {
                assert_eq!(token.len(), 64);
                assert_eq!(steps, 3);
                assert!(echo);
                assert_eq!(opts.class, Some(Class::Bulk));
                assert_eq!(opts.deadline, Some(Duration::from_millis(20)));
            }
            _ => panic!("decode body parsed to the wrong variant"),
        }
        // Defaults: no class/deadline overrides, one step, no echo.
        let body = format!("{{\"token\":{token}}}");
        match parse("decode", &body).unwrap() {
            ApiRequest::Decode { steps, echo, opts, .. } => {
                assert_eq!(steps, 1);
                assert!(!echo);
                assert_eq!(opts, RequestOpts::default());
            }
            _ => panic!("decode body parsed to the wrong variant"),
        }
    }

    #[test]
    fn dispatch_table_covers_wire_ops() {
        let names: Vec<&str> = STREAM_OPS.iter().map(|(n, _)| *n).collect();
        assert_eq!(names, ["append", "decode"]);
    }

    #[test]
    fn shed_errors_map_to_429_with_retry_hint() {
        let shed = SubmitError::Overloaded {
            class: Class::Bulk,
            queue_delay: Duration::from_millis(12),
            retry_after: Duration::from_millis(7),
        };
        let resp = submit_error_response(&shed);
        assert_eq!(resp.status, 429);
        assert!(resp.body.contains("\"retry_after_ms\":7"), "{}", resp.body);
        let budget = SubmitError::BudgetExhausted {
            stream: 1,
            queued_tokens: 16,
            budget: 16,
            retry_after: Duration::from_millis(5),
        };
        assert_eq!(submit_error_response(&budget).status, 429);
        assert_eq!(submit_error_response(&SubmitError::Stopping).status, 503);
        let missing = SubmitError::UnknownStream {
            stream: 9,
            max_streams: 4,
        };
        assert_eq!(submit_error_response(&missing).status, 404);
    }
}
