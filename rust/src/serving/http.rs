//! Dependency-free HTTP/1.1 framing: request reading for the server,
//! response writing, and the response-parsing half used by the loadgen
//! client. `std::net` only — the offline environment has no hyper.
//!
//! Scope is deliberately narrow: identity bodies with `Content-Length`,
//! keep-alive, and a bounded header section. Chunked transfer encoding
//! is rejected cleanly with `501` (the wire protocol never needs it),
//! oversized bodies with `413`, and a POST without a length with `411`.

use std::io::{BufRead, Read, Write};

/// Longest accepted request/status/header line, bytes.
const MAX_LINE: usize = 8 * 1024;
/// Most headers accepted per message.
const MAX_HEADERS: usize = 64;

/// One parsed request. Header names are lowercased on read.
#[derive(Clone, Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub version: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// Case-insensitive header lookup (names are stored lowercased).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }

    /// HTTP/1.1 defaults to keep-alive unless `Connection: close`;
    /// HTTP/1.0 defaults to close unless `Connection: keep-alive`.
    pub fn keep_alive(&self) -> bool {
        match self.header("connection") {
            Some(v) if v.eq_ignore_ascii_case("close") => false,
            Some(v) if v.eq_ignore_ascii_case("keep-alive") => true,
            _ => self.version != "HTTP/1.0",
        }
    }

    pub fn body_str(&self) -> Result<&str, HttpError> {
        std::str::from_utf8(&self.body)
            .map_err(|_| HttpError::bad(400, "body is not valid UTF-8"))
    }
}

/// Why a request could not be read.
#[derive(Debug)]
pub enum HttpError {
    /// Read timed out before the first byte of a request arrived: the
    /// connection is idle, not broken — callers poll their shutdown flag
    /// and try again.
    Idle,
    /// Protocol violation: answer with `status`, then close.
    Bad { status: u16, detail: String },
    /// Transport failure mid-message: close without answering.
    Io(std::io::Error),
}

impl HttpError {
    pub fn bad(status: u16, detail: &str) -> Self {
        HttpError::Bad {
            status,
            detail: detail.to_string(),
        }
    }
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::Idle => write!(f, "idle"),
            HttpError::Bad { status, detail } => write!(f, "{status}: {detail}"),
            HttpError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// Read one line (up to `\n`, stripping `\r\n`). `first` marks the first
/// line of a message, where EOF/timeout means "idle connection" rather
/// than "truncated request".
fn read_line(r: &mut impl BufRead, first: bool) -> Result<Option<Vec<u8>>, HttpError> {
    let mut line = Vec::new();
    loop {
        let buf = match r.fill_buf() {
            Ok(b) => b,
            Err(e) if is_timeout(&e) => {
                if first && line.is_empty() {
                    return Err(HttpError::Idle);
                }
                return Err(HttpError::bad(408, "timed out mid-request"));
            }
            Err(e) => return Err(HttpError::Io(e)),
        };
        if buf.is_empty() {
            // EOF.
            if first && line.is_empty() {
                return Ok(None);
            }
            return Err(HttpError::bad(400, "connection closed mid-request"));
        }
        match buf.iter().position(|&b| b == b'\n') {
            Some(nl) => {
                line.extend_from_slice(&buf[..nl]);
                r.consume(nl + 1);
                if line.last() == Some(&b'\r') {
                    line.pop();
                }
                return Ok(Some(line));
            }
            None => {
                let n = buf.len();
                line.extend_from_slice(buf);
                r.consume(n);
                if line.len() > MAX_LINE {
                    return Err(HttpError::bad(431, "header line too long"));
                }
            }
        }
    }
}

/// Read one request off a (possibly keep-alive) connection.
///
/// * `Ok(Some(req))` — a complete request.
/// * `Ok(None)` — the peer closed cleanly between requests.
/// * `Err(HttpError::Idle)` — read timeout between requests (poll and
///   retry).
/// * `Err(HttpError::Bad{..})` — answer with the status, then close.
/// * `Err(HttpError::Io(_))` — close silently.
pub fn read_request(
    r: &mut impl BufRead,
    max_body: usize,
) -> Result<Option<HttpRequest>, HttpError> {
    let line = match read_line(r, true)? {
        Some(l) => l,
        None => return Ok(None),
    };
    let line = String::from_utf8(line).map_err(|_| HttpError::bad(400, "bad request line"))?;
    let mut parts = line.split_whitespace();
    let (method, path, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v), None) if v.starts_with("HTTP/") => {
            (m.to_string(), p.to_string(), v.to_string())
        }
        _ => return Err(HttpError::bad(400, "bad request line")),
    };

    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let line = match read_line(r, false)? {
            Some(l) => l,
            None => return Err(HttpError::bad(400, "truncated header section")),
        };
        if line.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            return Err(HttpError::bad(431, "too many headers"));
        }
        let line =
            String::from_utf8(line).map_err(|_| HttpError::bad(400, "bad header encoding"))?;
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| HttpError::bad(400, "malformed header"))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }

    let req_head = HttpRequest {
        method,
        path,
        version,
        headers,
        body: Vec::new(),
    };

    if req_head.header("transfer-encoding").is_some() {
        // Chunked (and any other transfer coding) is out of scope; the
        // client must frame with Content-Length.
        return Err(HttpError::bad(501, "transfer-encoding not supported"));
    }

    let body = match req_head.header("content-length") {
        Some(v) => {
            let len: usize = v
                .parse()
                .map_err(|_| HttpError::bad(400, "bad content-length"))?;
            if len > max_body {
                return Err(HttpError::bad(413, "body exceeds server limit"));
            }
            let mut body = vec![0u8; len];
            if let Err(e) = r.read_exact(&mut body) {
                if is_timeout(&e) {
                    return Err(HttpError::bad(408, "timed out reading body"));
                }
                if e.kind() == std::io::ErrorKind::UnexpectedEof {
                    return Err(HttpError::bad(400, "connection closed mid-body"));
                }
                return Err(HttpError::Io(e));
            }
            body
        }
        None if req_head.method == "POST" || req_head.method == "PUT" => {
            return Err(HttpError::bad(411, "content-length required"));
        }
        None => Vec::new(),
    };

    Ok(Some(HttpRequest { body, ..req_head }))
}

/// Standard reason phrase for the statuses this server emits.
pub fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        411 => "Length Required",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Response",
    }
}

/// Write one response (identity body, explicit length).
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        reason_phrase(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Read one response (status, body) off a client connection. Returns
/// `(status, body, keep_alive)`.
pub fn read_response(r: &mut impl BufRead) -> Result<(u16, Vec<u8>, bool), HttpError> {
    let line = match read_line(r, true)? {
        Some(l) => l,
        None => return Err(HttpError::bad(400, "connection closed before response")),
    };
    let line = String::from_utf8(line).map_err(|_| HttpError::bad(400, "bad status line"))?;
    let mut parts = line.split_whitespace();
    let status: u16 = match (parts.next(), parts.next()) {
        (Some(v), Some(code)) if v.starts_with("HTTP/") => code
            .parse()
            .map_err(|_| HttpError::bad(400, "bad status code"))?,
        _ => return Err(HttpError::bad(400, "bad status line")),
    };
    let mut content_length: Option<usize> = None;
    let mut keep_alive = true;
    loop {
        let line = match read_line(r, false)? {
            Some(l) => l,
            None => return Err(HttpError::bad(400, "truncated response headers")),
        };
        if line.is_empty() {
            break;
        }
        let line =
            String::from_utf8(line).map_err(|_| HttpError::bad(400, "bad header encoding"))?;
        if let Some((name, value)) = line.split_once(':') {
            let name = name.trim().to_ascii_lowercase();
            let value = value.trim();
            if name == "content-length" {
                content_length = value.parse().ok();
            } else if name == "connection" && value.eq_ignore_ascii_case("close") {
                keep_alive = false;
            }
        }
    }
    let len = content_length.ok_or_else(|| HttpError::bad(400, "response without length"))?;
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).map_err(HttpError::Io)?;
    Ok((status, body, keep_alive))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn req(raw: &str) -> Result<Option<HttpRequest>, HttpError> {
        read_request(&mut Cursor::new(raw.as_bytes().to_vec()), 1024)
    }

    #[test]
    fn parses_get() {
        let r = req("GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap().unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/healthz");
        assert!(r.keep_alive());
        assert_eq!(r.header("HOST"), Some("x"));
        assert!(r.body.is_empty());
    }

    #[test]
    fn parses_post_with_body() {
        let r = req("POST /v1/streams HTTP/1.1\r\nContent-Length: 4\r\n\r\nabcd")
            .unwrap()
            .unwrap();
        assert_eq!(r.body, b"abcd");
        assert_eq!(r.body_str().unwrap(), "abcd");
    }

    #[test]
    fn connection_close_disables_keep_alive() {
        let r = req("GET / HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap().unwrap();
        assert!(!r.keep_alive());
        let r = req("GET / HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!r.keep_alive(), "1.0 defaults to close");
    }

    #[test]
    fn shed_status_has_a_reason_phrase() {
        // 429 carries admission sheds (retryable); it must not fall into
        // the generic "Response" bucket on the wire.
        assert_eq!(reason_phrase(429), "Too Many Requests");
    }

    #[test]
    fn chunked_rejected_with_501() {
        let e = req("POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n").unwrap_err();
        assert!(matches!(e, HttpError::Bad { status: 501, .. }), "{e}");
    }

    #[test]
    fn oversized_body_rejected_with_413() {
        let e = req("POST /x HTTP/1.1\r\nContent-Length: 2048\r\n\r\n").unwrap_err();
        assert!(matches!(e, HttpError::Bad { status: 413, .. }), "{e}");
    }

    #[test]
    fn post_without_length_rejected_with_411() {
        let e = req("POST /x HTTP/1.1\r\n\r\n").unwrap_err();
        assert!(matches!(e, HttpError::Bad { status: 411, .. }), "{e}");
    }

    #[test]
    fn malformed_request_line_rejected_with_400() {
        for bad in ["GARBAGE\r\n\r\n", "GET /x\r\n\r\n", "GET /x NOPE/1.1\r\n\r\n"] {
            let e = req(bad).unwrap_err();
            assert!(matches!(e, HttpError::Bad { status: 400, .. }), "{bad:?}: {e}");
        }
    }

    #[test]
    fn truncated_body_rejected_with_400() {
        let e = req("POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc").unwrap_err();
        assert!(matches!(e, HttpError::Bad { status: 400, .. }), "{e}");
    }

    #[test]
    fn clean_eof_is_none() {
        assert!(req("").unwrap().is_none());
    }

    #[test]
    fn response_round_trip() {
        let mut wire = Vec::new();
        write_response(&mut wire, 200, "application/json", b"{\"ok\":true}", true).unwrap();
        let (status, body, keep) = read_response(&mut Cursor::new(wire)).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"{\"ok\":true}");
        assert!(keep);
    }
}
