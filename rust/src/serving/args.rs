//! Tiny typed CLI-flag parser shared by `repro serve` and `redline`.
//!
//! The previous ad-hoc pattern (`flag(..).and_then(|s| s.parse().ok())
//! .unwrap_or(default)`) silently swallowed typos — `--streams x` served
//! one stream instead of failing. Here a present-but-unparsable (or
//! valueless) flag is a hard [`ArgError`] the binaries turn into a usage
//! message and exit code 2, never a panic and never a silent default.

use std::str::FromStr;
use std::time::Duration;

use crate::coordinator::SchedulerConfig;

/// A flag-parsing failure: which flag, and why.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArgError {
    pub flag: String,
    pub reason: String,
}

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.flag, self.reason)
    }
}

impl std::error::Error for ArgError {}

/// Borrowing view over a raw `--flag value` argument list.
pub struct ArgParser<'a> {
    args: &'a [String],
}

impl<'a> ArgParser<'a> {
    pub fn new(args: &'a [String]) -> Self {
        Self { args }
    }

    /// Presence of a boolean flag.
    pub fn has(&self, name: &str) -> bool {
        self.args.iter().any(|a| a == name)
    }

    /// The raw token following `name`, if the flag is present at all.
    /// A following token that is itself a flag counts as a missing
    /// value (negative numbers are fine: they start with a single `-`).
    pub fn raw(&self, name: &str) -> Result<Option<&'a str>, ArgError> {
        let Some(i) = self.args.iter().position(|a| a == name) else {
            return Ok(None);
        };
        match self.args.get(i + 1) {
            Some(v) if !v.starts_with("--") => Ok(Some(v.as_str())),
            _ => Err(ArgError {
                flag: name.to_string(),
                reason: "missing value".to_string(),
            }),
        }
    }

    /// Typed optional flag: absent → `Ok(None)`; present with a bad or
    /// missing value → `Err`.
    pub fn parsed<T: FromStr>(&self, name: &str) -> Result<Option<T>, ArgError> {
        match self.raw(name)? {
            None => Ok(None),
            Some(v) => v.parse::<T>().map(Some).map_err(|_| ArgError {
                flag: name.to_string(),
                reason: format!("invalid value {v:?}"),
            }),
        }
    }

    /// Typed flag with a default for absence.
    pub fn parsed_or<T: FromStr>(&self, name: &str, default: T) -> Result<T, ArgError> {
        Ok(self.parsed(name)?.unwrap_or(default))
    }

    /// Typed mandatory flag.
    pub fn require<T: FromStr>(&self, name: &str) -> Result<T, ArgError> {
        self.parsed(name)?.ok_or_else(|| ArgError {
            flag: name.to_string(),
            reason: "required flag missing".to_string(),
        })
    }

    /// String flag with a default.
    pub fn string_or(&self, name: &str, default: &str) -> Result<String, ArgError> {
        Ok(self.raw(name)?.map(str::to_string).unwrap_or_else(|| default.to_string()))
    }
}

/// Largest per-cycle count either side of a `--mix` ratio accepts. The
/// loadgen cycles through `p + d` request slots; capping both sides
/// keeps that sum (and every derived `seq % cycle`) far from overflow
/// while allowing any ratio a human would type.
pub const MAX_MIX: usize = 1_000_000;

/// Parse a `P:D` stream-mix ratio (prefills per cycle, decodes per
/// cycle), e.g. `1:8` = one vision prefill per eight decode requests.
/// `0:1` disables ongoing prefills entirely. Malformed ratios —
/// non-numeric or negative counts, `0:0`, counts past [`MAX_MIX`] — are
/// usage errors, never a degenerate run.
pub fn parse_mix(s: &str) -> Result<(usize, usize), ArgError> {
    let err = |reason: &str| ArgError {
        flag: "--mix".to_string(),
        reason: format!("{reason} (expected P:D, e.g. 1:8)"),
    };
    let (p, d) = s.split_once(':').ok_or_else(|| err("missing ':'"))?;
    let p: usize = p.parse().map_err(|_| err("bad prefill count"))?;
    let d: usize = d.parse().map_err(|_| err("bad decode count"))?;
    if p == 0 && d == 0 {
        return Err(err("mix cannot be 0:0"));
    }
    if p > MAX_MIX || d > MAX_MIX {
        return Err(err("mix counts must be at most 1000000"));
    }
    Ok((p, d))
}

/// `--slo-ms` as both binaries read it: absent or `0` disables the SLO
/// (`None`); anything else is the queue-delay target.
pub fn slo_from_args(p: &ArgParser) -> Result<Option<Duration>, ArgError> {
    Ok(p.parsed::<u64>("--slo-ms")?
        .filter(|&ms| ms > 0)
        .map(Duration::from_millis))
}

/// The scheduler flags shared by `repro serve` and `redline`'s docs:
/// one parsing path on top of [`SchedulerConfig::from_env`], so the
/// binaries (and the `NC_*` environment) can't drift. Flags override
/// the environment; absent flags keep the env-derived values.
pub fn scheduler_config(p: &ArgParser) -> Result<SchedulerConfig, ArgError> {
    let mut cfg = SchedulerConfig::default(); // = from_env()
    if let Some(n) = p.parsed::<usize>("--workers")? {
        if n == 0 {
            return Err(ArgError {
                flag: "--workers".to_string(),
                reason: "must be at least 1".to_string(),
            });
        }
        cfg = cfg.with_workers(n);
    }
    if let Some(us) = p.parsed::<u64>("--batch-window")? {
        cfg = cfg.with_batch_window(Duration::from_micros(us));
    }
    if let Some(n) = p.parsed::<usize>("--streams")? {
        if n == 0 {
            return Err(ArgError {
                flag: "--streams".to_string(),
                reason: "must be at least 1".to_string(),
            });
        }
        cfg = cfg.with_max_streams(n);
    }
    if p.raw("--slo-ms")?.is_some() {
        cfg = cfg.with_slo(slo_from_args(p)?);
    }
    if let Some(tokens) = p.parsed::<usize>("--prefill-budget")? {
        cfg = cfg.with_prefill_budget(tokens);
    }
    if let Some(layers) = p.parsed::<usize>("--prefill-chunk")? {
        cfg = cfg.with_prefill_chunk(layers);
    }
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(tokens: &[&str]) -> Vec<String> {
        tokens.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn absent_flag_uses_default() {
        let args = argv(&["--other", "1"]);
        let p = ArgParser::new(&args);
        assert_eq!(p.parsed_or("--streams", 4usize).unwrap(), 4);
        assert_eq!(p.parsed::<usize>("--streams").unwrap(), None);
        assert!(!p.has("--verbose"));
    }

    #[test]
    fn present_flag_parses() {
        let args = argv(&["--streams", "8", "--rps", "2.5", "--verbose"]);
        let p = ArgParser::new(&args);
        assert_eq!(p.parsed_or("--streams", 1usize).unwrap(), 8);
        assert_eq!(p.parsed_or("--rps", 1.0f64).unwrap(), 2.5);
        assert!(p.has("--verbose"));
    }

    #[test]
    fn bad_value_is_an_error_not_a_default() {
        let args = argv(&["--streams", "lots"]);
        let p = ArgParser::new(&args);
        let e = p.parsed_or("--streams", 1usize).unwrap_err();
        assert_eq!(e.flag, "--streams");
        assert!(e.reason.contains("lots"), "{e}");
    }

    #[test]
    fn missing_value_is_an_error() {
        // Trailing flag, and flag followed by another flag.
        for toks in [vec!["--streams"], vec!["--streams", "--other"]] {
            let args = argv(&toks);
            let p = ArgParser::new(&args);
            let e = p.parsed_or("--streams", 1usize).unwrap_err();
            assert_eq!(e.reason, "missing value");
        }
    }

    #[test]
    fn negative_numbers_are_values() {
        let args = argv(&["--delta", "-3"]);
        let p = ArgParser::new(&args);
        assert_eq!(p.parsed_or("--delta", 0i64).unwrap(), -3);
    }

    #[test]
    fn require_reports_absence() {
        let args = argv(&[]);
        let p = ArgParser::new(&args);
        let e = p.require::<String>("--target").unwrap_err();
        assert_eq!(e.flag, "--target");
        assert!(e.reason.contains("required"));
    }

    #[test]
    fn mix_parses_and_rejects() {
        assert_eq!(parse_mix("1:8").unwrap(), (1, 8));
        assert_eq!(parse_mix("0:1").unwrap(), (0, 1));
        assert_eq!(parse_mix("1000000:1").unwrap(), (1_000_000, 1));
        for bad in ["", "1", "x:2", "1:y", "0:0", "1:2:3", "-1:8", "1:-8", "1.5:8"] {
            assert!(parse_mix(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn mix_rejects_overflow_ratios() {
        // Counts past the cap used to survive into `p + d` arithmetic
        // downstream; now they are usage errors up front.
        let max = usize::MAX.to_string();
        for bad in [
            format!("{max}:{max}"),
            format!("{max}:1"),
            format!("1:{max}"),
            "1000001:1".to_string(),
        ] {
            let e = parse_mix(&bad).unwrap_err();
            assert_eq!(e.flag, "--mix");
            assert!(e.reason.contains("at most"), "{bad}: {e}");
        }
    }

    #[test]
    fn scheduler_flags_override_env_defaults() {
        let args = argv(&[
            "--workers", "3",
            "--batch-window", "150",
            "--streams", "9",
            "--slo-ms", "40",
            "--prefill-budget", "64",
            "--prefill-chunk", "2",
        ]);
        let cfg = scheduler_config(&ArgParser::new(&args)).unwrap();
        assert_eq!(cfg.workers, 3);
        assert_eq!(cfg.batch_window, Duration::from_micros(150));
        assert_eq!(cfg.max_streams, 9);
        assert_eq!(cfg.slo, Some(Duration::from_millis(40)));
        assert_eq!(cfg.prefill_budget, 64);
        assert_eq!(cfg.prefill_chunk, 2);
    }

    #[test]
    fn scheduler_flags_validate() {
        for (toks, flag) in [
            (vec!["--workers", "0"], "--workers"),
            (vec!["--streams", "0"], "--streams"),
            (vec!["--batch-window", "x"], "--batch-window"),
            (vec!["--slo-ms", "-5"], "--slo-ms"),
        ] {
            let args = argv(&toks);
            let e = scheduler_config(&ArgParser::new(&args)).unwrap_err();
            assert_eq!(e.flag, flag, "{toks:?}");
        }
    }

    #[test]
    fn explicit_zero_slo_disables_shedding() {
        let args = argv(&["--slo-ms", "0"]);
        let p = ArgParser::new(&args);
        assert_eq!(slo_from_args(&p).unwrap(), None);
        let cfg = scheduler_config(&p).unwrap();
        assert_eq!(cfg.slo, None);
    }
}
