//! Tiny typed CLI-flag parser shared by `repro serve` and `redline`.
//!
//! The previous ad-hoc pattern (`flag(..).and_then(|s| s.parse().ok())
//! .unwrap_or(default)`) silently swallowed typos — `--streams x` served
//! one stream instead of failing. Here a present-but-unparsable (or
//! valueless) flag is a hard [`ArgError`] the binaries turn into a usage
//! message and exit code 2, never a panic and never a silent default.

use std::str::FromStr;

/// A flag-parsing failure: which flag, and why.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArgError {
    pub flag: String,
    pub reason: String,
}

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.flag, self.reason)
    }
}

impl std::error::Error for ArgError {}

/// Borrowing view over a raw `--flag value` argument list.
pub struct ArgParser<'a> {
    args: &'a [String],
}

impl<'a> ArgParser<'a> {
    pub fn new(args: &'a [String]) -> Self {
        Self { args }
    }

    /// Presence of a boolean flag.
    pub fn has(&self, name: &str) -> bool {
        self.args.iter().any(|a| a == name)
    }

    /// The raw token following `name`, if the flag is present at all.
    /// A following token that is itself a flag counts as a missing
    /// value (negative numbers are fine: they start with a single `-`).
    pub fn raw(&self, name: &str) -> Result<Option<&'a str>, ArgError> {
        let Some(i) = self.args.iter().position(|a| a == name) else {
            return Ok(None);
        };
        match self.args.get(i + 1) {
            Some(v) if !v.starts_with("--") => Ok(Some(v.as_str())),
            _ => Err(ArgError {
                flag: name.to_string(),
                reason: "missing value".to_string(),
            }),
        }
    }

    /// Typed optional flag: absent → `Ok(None)`; present with a bad or
    /// missing value → `Err`.
    pub fn parsed<T: FromStr>(&self, name: &str) -> Result<Option<T>, ArgError> {
        match self.raw(name)? {
            None => Ok(None),
            Some(v) => v.parse::<T>().map(Some).map_err(|_| ArgError {
                flag: name.to_string(),
                reason: format!("invalid value {v:?}"),
            }),
        }
    }

    /// Typed flag with a default for absence.
    pub fn parsed_or<T: FromStr>(&self, name: &str, default: T) -> Result<T, ArgError> {
        Ok(self.parsed(name)?.unwrap_or(default))
    }

    /// Typed mandatory flag.
    pub fn require<T: FromStr>(&self, name: &str) -> Result<T, ArgError> {
        self.parsed(name)?.ok_or_else(|| ArgError {
            flag: name.to_string(),
            reason: "required flag missing".to_string(),
        })
    }

    /// String flag with a default.
    pub fn string_or(&self, name: &str, default: &str) -> Result<String, ArgError> {
        Ok(self.raw(name)?.map(str::to_string).unwrap_or_else(|| default.to_string()))
    }
}

/// Parse a `P:D` stream-mix ratio (prefills per cycle, decodes per
/// cycle), e.g. `1:8` = one vision prefill per eight decode requests.
/// `0:1` disables ongoing prefills entirely.
pub fn parse_mix(s: &str) -> Result<(usize, usize), ArgError> {
    let err = |reason: &str| ArgError {
        flag: "--mix".to_string(),
        reason: format!("{reason} (expected P:D, e.g. 1:8)"),
    };
    let (p, d) = s.split_once(':').ok_or_else(|| err("missing ':'"))?;
    let p: usize = p.parse().map_err(|_| err("bad prefill count"))?;
    let d: usize = d.parse().map_err(|_| err("bad decode count"))?;
    if p + d == 0 {
        return Err(err("mix cannot be 0:0"));
    }
    Ok((p, d))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(tokens: &[&str]) -> Vec<String> {
        tokens.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn absent_flag_uses_default() {
        let args = argv(&["--other", "1"]);
        let p = ArgParser::new(&args);
        assert_eq!(p.parsed_or("--streams", 4usize).unwrap(), 4);
        assert_eq!(p.parsed::<usize>("--streams").unwrap(), None);
        assert!(!p.has("--verbose"));
    }

    #[test]
    fn present_flag_parses() {
        let args = argv(&["--streams", "8", "--rps", "2.5", "--verbose"]);
        let p = ArgParser::new(&args);
        assert_eq!(p.parsed_or("--streams", 1usize).unwrap(), 8);
        assert_eq!(p.parsed_or("--rps", 1.0f64).unwrap(), 2.5);
        assert!(p.has("--verbose"));
    }

    #[test]
    fn bad_value_is_an_error_not_a_default() {
        let args = argv(&["--streams", "lots"]);
        let p = ArgParser::new(&args);
        let e = p.parsed_or("--streams", 1usize).unwrap_err();
        assert_eq!(e.flag, "--streams");
        assert!(e.reason.contains("lots"), "{e}");
    }

    #[test]
    fn missing_value_is_an_error() {
        // Trailing flag, and flag followed by another flag.
        for toks in [vec!["--streams"], vec!["--streams", "--other"]] {
            let args = argv(&toks);
            let p = ArgParser::new(&args);
            let e = p.parsed_or("--streams", 1usize).unwrap_err();
            assert_eq!(e.reason, "missing value");
        }
    }

    #[test]
    fn negative_numbers_are_values() {
        let args = argv(&["--delta", "-3"]);
        let p = ArgParser::new(&args);
        assert_eq!(p.parsed_or("--delta", 0i64).unwrap(), -3);
    }

    #[test]
    fn require_reports_absence() {
        let args = argv(&[]);
        let p = ArgParser::new(&args);
        let e = p.require::<String>("--target").unwrap_err();
        assert_eq!(e.flag, "--target");
        assert!(e.reason.contains("required"));
    }

    #[test]
    fn mix_parses_and_rejects() {
        assert_eq!(parse_mix("1:8").unwrap(), (1, 8));
        assert_eq!(parse_mix("0:1").unwrap(), (0, 1));
        for bad in ["", "1", "x:2", "1:y", "0:0", "1:2:3"] {
            assert!(parse_mix(bad).is_err(), "{bad:?} should not parse");
        }
    }
}
