//! Minimal JSON for the wire protocol — the offline environment has no
//! serde, and `scripts/bench_gate.rs`'s flat scanner is too weak for
//! request bodies carrying float arrays, so this is a small but complete
//! recursive-descent parser + writer over an owned [`Json`] tree.
//!
//! ## Float fidelity
//!
//! The loopback bit-identity guarantee rides on this module: an `f32`
//! written with [`push_f32`] is widened to `f64` (exact), printed with
//! Rust's shortest round-trip `Display`, parsed back as `f64` (recovers
//! the same `f64`), and narrowed to `f32` (exact inverse of the
//! widening) — so weight activations survive the wire bit for bit.
//! Non-finite floats are written as `null` (JSON has no NaN/inf); the
//! engine never produces them on the serving path.

use std::fmt::Write as _;

/// A parsed JSON value. Object keys keep insertion order (no hashing —
/// lookup is linear, bodies are small).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing characters at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object-field lookup (None for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Non-negative integer accessor (rejects fractional and negative).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= usize::MAX as f64 => {
                Some(*n as usize)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v.as_slice()),
            _ => None,
        }
    }

    /// Interpret an array of numbers as `f32`s (bit-exact for values
    /// written by [`push_f32`]).
    pub fn as_f32s(&self) -> Option<Vec<f32>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f64()? as f32);
        }
        Some(out)
    }

    /// Serialize (compact, no whitespace).
    pub fn write_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => push_f64(out, *n),
            Json::Str(s) => push_str_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_into(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    push_str_escaped(out, k);
                    out.push(':');
                    v.write_into(out);
                }
                out.push('}');
            }
        }
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write_into(&mut s);
        f.write_str(&s)
    }
}

/// Append a JSON string literal (quoted, escaped).
pub fn push_str_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Append an `f64` with round-trip precision (`null` for non-finite).
pub fn push_f64(out: &mut String, n: f64) {
    if n.is_finite() {
        let _ = write!(out, "{n}");
    } else {
        out.push_str("null");
    }
}

/// Append an `f32` so it survives the wire bit-exactly (see module doc).
pub fn push_f32(out: &mut String, x: f32) {
    push_f64(out, x as f64);
}

/// Append a whole `f32` array literal.
pub fn push_f32_array(out: &mut String, xs: &[f32]) {
    out.push('[');
    for (i, &x) in xs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        push_f32(out, x);
    }
    out.push(']');
}

/// Nesting depth cap: protects the thread-per-connection server from
/// stack exhaustion on adversarial bodies.
const MAX_DEPTH: usize = 64;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}",
                b as char, self.pos
            ))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > MAX_DEPTH {
            return Err("nesting too deep".into());
        }
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.pos += 1;
                let mut items = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                loop {
                    items.push(self.value(depth + 1)?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => {
                            self.pos += 1;
                        }
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(Json::Arr(items));
                        }
                        _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
                    }
                }
            }
            Some(b'{') => {
                self.pos += 1;
                let mut fields = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                loop {
                    self.skip_ws();
                    let key = self.string()?;
                    self.skip_ws();
                    self.expect(b':')?;
                    let val = self.value(depth + 1)?;
                    fields.push((key, val));
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => {
                            self.pos += 1;
                        }
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(Json::Obj(fields));
                        }
                        _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
                    }
                }
            }
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(b) => Err(format!("unexpected {:?} at byte {}", b as char, self.pos)),
            None => Err("unexpected end of input".into()),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            match b {
                b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9' => self.pos += 1,
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| format!("invalid number at byte {start}"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("invalid number {text:?} at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self
                .peek()
                .ok_or_else(|| "unterminated string".to_string())?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let esc = self
                        .peek()
                        .ok_or_else(|| "unterminated escape".to_string())?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or_else(|| "truncated \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("invalid \\u escape {hex:?}"))?;
                            self.pos += 4;
                            // Surrogates map to the replacement character;
                            // the wire protocol never emits them.
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        other => {
                            return Err(format!("invalid escape \\{}", other as char));
                        }
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence starting here.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = start + len;
                    let chunk = self
                        .bytes
                        .get(start..end)
                        .and_then(|c| std::str::from_utf8(c).ok())
                        .ok_or_else(|| format!("invalid utf-8 at byte {start}"))?;
                    out.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("-2.5e3").unwrap(), Json::Num(-2500.0));
        assert_eq!(
            Json::parse("\"a\\nb\\u0041\"").unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":true}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_usize(), Some(2));
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "1 2", "\"open", "{\"a\":}"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn rejects_deep_nesting() {
        let deep = "[".repeat(200) + &"]".repeat(200);
        assert!(Json::parse(&deep).is_err());
    }

    #[test]
    fn serializes_round_trip() {
        let src = r#"{"a":[1,2.5,null],"b":"x\"y","c":false}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn f32_survives_the_wire_bit_exactly() {
        // Awkward values: subnormals, values with no short decimal form,
        // negative zero, exact powers of two.
        let xs: Vec<f32> = vec![
            0.1,
            -0.0,
            1.0e-40,
            std::f32::consts::PI,
            f32::MIN_POSITIVE,
            3.3333333,
            -123456.78,
            2.0f32.powi(-20),
        ];
        let mut s = String::new();
        push_f32_array(&mut s, &xs);
        let back = Json::parse(&s).unwrap().as_f32s().unwrap();
        assert_eq!(back.len(), xs.len());
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} changed bits over the wire");
        }
    }
}
