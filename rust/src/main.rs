//! `repro` — the neuron-chunking serving CLI.
//!
//! Subcommands:
//!   serve    — run the runnable engine on a synthetic video stream and
//!              report per-frame latency/throughput (the serving loop).
//!   profile  — run the Appendix-D microbenchmark against a device
//!              profile (or a real file) and dump the T[s] table.
//!   select   — one-shot chunk selection demo on synthetic importance.
//!   models   — list known model specs.
//!
//! Hand-rolled arg parsing: the offline environment has no clap.

use std::path::PathBuf;

use neuron_chunking::coordinator::{Engine, Policy};
use neuron_chunking::report::{fmt_bw, fmt_secs, Table};
use neuron_chunking::serving::{ArgError, ArgParser};
use neuron_chunking::stats;
use neuron_chunking::storage::{
    DeviceProfile, Profiler, ProfileConfig, RealFileDevice, SimulatedSsd, StripePolicy,
};
use neuron_chunking::workload::FrameTrace;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(|s| s.as_str()) {
        Some("serve") => cmd_serve(&args[1..]),
        Some("profile") => cmd_profile(&args[1..]),
        Some("select") => cmd_select(&args[1..]),
        Some("models") => cmd_models(),
        _ => {
            eprintln!(
                "repro — flash-offloaded VLM serving with neuron chunking\n\
                 usage:\n\
                 \x20 repro serve   [--model small] [--policy POLICY] [--sparsity 0.5]\n\
                 \x20               [--device nano|agx] [--frames 8] [--decode 4]\n\
                 \x20               [--reorder] [--no-prefetch] [--artifacts DIR]\n\
                 \x20               [--threads N]  executor kernel worker threads\n\
                 \x20                              (default 1; outputs are bit-identical\n\
                 \x20                              at every thread count)\n\
                 \x20               [--devices N]  storage-pool members (default 1 or\n\
                 \x20                              $NC_DEVICES; outputs are bit-identical\n\
                 \x20                              at every pool size)\n\
                 \x20               [--stripe-hot] layout-aware striping (co-locate each\n\
                 \x20                              matrix's hot rows, staggered per matrix)\n\
                 \x20               [--stripe-kb K] explicit stripe unit (default adaptive)\n\
                 \x20               [--replication N] extra copies of each region's hot\n\
                 \x20                              stripe blocks on other members (default 1\n\
                 \x20                              or $NC_REPLICATION; 1 = no replication;\n\
                 \x20                              enables failover + hedged reads)\n\
                 \x20               [--async-io]   asynchronous I/O pipeline (submit layer\n\
                 \x20                              k+1's prefetch before layer k's kernels;\n\
                 \x20                              outputs are bit-identical either way)\n\
                 \x20               [--queue-depth N] in-flight whole-layer prefetch bound\n\
                 \x20                              (default 2)\n\
                 \x20               [--file-backed DIR] serve from real per-member backing\n\
                 \x20                              files under DIR (wall-clock I/O)\n\
                 \x20               [--cache-mb N] shared cross-session hot-chunk RAM cache\n\
                 \x20                              budget in MiB (default 0 or $NC_CACHE_MB;\n\
                 \x20                              0 = off; admission follows live selection\n\
                 \x20                              frequency; outputs stay bit-identical)\n\
                 \x20               [--dtype D]    on-flash weight storage dtype: f32 | fp16 |\n\
                 \x20                              int8 (default f32 or $NC_DTYPE; quantized\n\
                 \x20                              images shrink reads + reprice selection;\n\
                 \x20                              outputs carry the format's rounding error)\n\
                 \x20               [--streams N]  concurrent decode streams served through\n\
                 \x20                              the scheduler (default 1 = single stream;\n\
                 \x20                              with --listen: stream capacity, default 64)\n\
                 \x20               [--batch-window US] cross-stream decode-batching window\n\
                 \x20                              in microseconds (with --streams > 1;\n\
                 \x20                              fused I/O plans, outputs bit-identical)\n\
                 \x20               [--listen HOST:PORT] network mode: serve the engine over\n\
                 \x20                              HTTP/1.1 (POST /v1/streams,\n\
                 \x20                              /v1/streams/{id}/append, …/decode;\n\
                 \x20                              GET /metrics, /healthz, /v1/config);\n\
                 \x20                              port 0 picks a free port\n\
                 \x20               [--addr-file PATH] write the bound address to PATH\n\
                 \x20               [--workers N]  scheduler worker threads (network mode)\n\
                 \x20               [--slo-ms MS]  queue-delay SLO: shed requests (HTTP 429\n\
                 \x20                              + retry_after_ms) once their class's\n\
                 \x20                              queue delay exceeds MS ms (0 = off;\n\
                 \x20                              default $NC_SLO_MS or off)\n\
                 \x20               [--prefill-budget T] per-stream cap on queued prefill\n\
                 \x20                              tokens; excess prefills shed with 429\n\
                 \x20                              (0 = unlimited)\n\
                 \x20               [--prefill-chunk L] layers per chunked-prefill step;\n\
                 \x20                              decode batches interleave at chunk\n\
                 \x20                              boundaries (default 1; 0 = monolithic,\n\
                 \x20                              outputs bit-identical either way)\n\
                 \x20               [--max-connections N] connection bound (default 64)\n\
                 \x20               [--max-body-kb N] request-body cap (default 8192)\n\
                 \x20               [--duration S] network mode: stop serving after S\n\
                 \x20                              seconds (default: until SIGINT/SIGTERM)\n\
                 \x20               POLICY: dense | topk | threshold[:t] |\n\
                 \x20                       chunking[:min_kb,jump_kb,max_kb] | bundling[:rows]\n\
                 \x20 repro profile [--device nano|agx|macbook] [--file PATH] [--out PATH]\n\
                 \x20 repro select  [--rows 4096] [--sparsity 0.5] [--device nano]\n\
                 \x20 repro models"
            );
            2
        }
    };
    std::process::exit(code);
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn cmd_serve(args: &[String]) -> i32 {
    // Typed flag parsing (shared with `redline`): a bad or valueless
    // flag is a usage error (exit 2), never a panic or a silent default.
    match cmd_serve_inner(args) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("repro serve: {e}");
            eprintln!("run `repro` without arguments for usage");
            2
        }
    }
}

fn cmd_serve_inner(args: &[String]) -> Result<i32, ArgError> {
    let p = ArgParser::new(args);
    let model = p.string_or("--model", "small")?;
    let policy_name = p.string_or("--policy", "chunking")?;
    let sparsity: f64 = p.parsed_or("--sparsity", 0.5)?;
    let device = p.string_or("--device", "nano")?;
    let frames: usize = p.parsed_or("--frames", 8)?;
    let decode_steps: usize = p.parsed_or("--decode", 4)?;
    let threads = p.parsed_or("--threads", 1usize)?.max(1);
    let artifacts = PathBuf::from(p.string_or("--artifacts", "artifacts")?);

    let profile = match DeviceProfile::by_name(&device) {
        Some(p) => p,
        None => {
            eprintln!("unknown device {device}");
            return Ok(2);
        }
    };
    let sat_kb = profile.saturation_bytes(0.99) as f64 / 1024.0;
    // `FromStr for Policy` handles names and `:`-parameters; the chunking
    // window cap is then re-tuned to this device's saturation point.
    let policy = match policy_name.parse::<Policy>() {
        Ok(p) => p.tuned_for_saturation(sat_kb),
        Err(e) => {
            eprintln!("{e}");
            return Ok(2);
        }
    };

    let mut builder = Engine::builder(&model)
        .policy(policy)
        .sparsity(sparsity)
        .profile(profile)
        .prefetch(!p.has("--no-prefetch"))
        .exec_threads(threads)
        .artifacts(&artifacts);
    if let Some(n) = p.parsed::<usize>("--devices")? {
        builder = builder.devices(n);
    }
    if p.has("--stripe-hot") {
        builder = builder.stripe_policy(StripePolicy::HotAware);
    }
    if let Some(kb) = p.parsed::<usize>("--stripe-kb")? {
        builder = builder.stripe_bytes(kb * 1024);
    }
    if let Some(r) = p.parsed::<usize>("--replication")? {
        builder = builder.replication(r);
    }
    if p.has("--async-io") {
        builder = builder.async_io(true);
    }
    if let Some(n) = p.parsed::<usize>("--queue-depth")? {
        builder = builder.io_queue_depth(n);
    }
    if let Some(dir) = p.raw("--file-backed")? {
        builder = builder.file_backed(std::path::Path::new(dir));
    }
    if let Some(mb) = p.parsed::<usize>("--cache-mb")? {
        builder = builder.cache_mb(mb);
    }
    if let Some(s) = p.raw("--dtype")? {
        match s.parse() {
            Ok(dt) => builder = builder.dtype(dt),
            Err(reason) => {
                return Err(ArgError {
                    flag: "--dtype".into(),
                    reason,
                })
            }
        }
    }
    let engine = match builder.build() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("engine init failed: {e:#}");
            return Ok(1);
        }
    };
    if p.has("--listen") {
        return serve_network(engine, &p, &model, &device, sparsity);
    }
    let streams = p.parsed_or("--streams", 1usize)?.max(1);
    if streams > 1 {
        let window_us: u64 = p.parsed_or("--batch-window", 0u64)?;
        return Ok(serve_batched(engine, streams, window_us, decode_steps));
    }
    println!(
        "serving model={model} policy={policy_name} sparsity={sparsity} device={device} \
         threads={threads} devices={} async_io={} queue_depth={}",
        engine.devices(),
        engine.async_io(),
        engine.io_queue_depth()
    );
    let spec = engine.spec();
    let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, frames + 1, 11);

    if p.has("--reorder") {
        let calib: Vec<Vec<f32>> = (0..4).map(|i| trace.frame(i)).collect();
        println!("calibrating hot–cold reorder on 4 frames…");
        if let Err(e) = engine.calibrate_and_reorder(&calib) {
            eprintln!("reorder failed: {e:#}");
            return Ok(1);
        }
    }

    println!("compiling {} artifacts…", engine.warmup().unwrap_or(0));
    let session = engine.new_session();
    // Warmup frame (not measured).
    if let Err(e) = session.append_frame(&trace.frame(0)) {
        eprintln!("warmup failed: {e:#}");
        return Ok(1);
    }

    let mut t = Table::new(
        "per-frame serving stats",
        &["frame", "io", "compute", "select", "host", "e2e", "MB", "pf_hits", "retained"],
    );
    let mut e2e = Vec::new();
    for f in 1..=frames {
        let (_, s) = session.append_frame(&trace.frame(f)).unwrap();
        e2e.push(s.end_to_end().as_secs_f64());
        t.row(vec![
            format!("{f}"),
            fmt_secs(s.io.as_secs_f64()),
            fmt_secs(s.compute.as_secs_f64()),
            fmt_secs(s.select.as_secs_f64()),
            fmt_secs(s.host.as_secs_f64()),
            fmt_secs(s.end_to_end().as_secs_f64()),
            format!("{:.1}", s.bytes_loaded as f64 / 1e6),
            format!("{}", s.prefetch_hits),
            format!("{:.3}", s.retained_fraction()),
        ]);
    }
    for dstep in 0..decode_steps {
        let token = vec![0.05f32; spec.d];
        let (_, s) = session.decode_step(&token).unwrap();
        t.row(vec![
            format!("dec{dstep}"),
            fmt_secs(s.io.as_secs_f64()),
            fmt_secs(s.compute.as_secs_f64()),
            fmt_secs(s.select.as_secs_f64()),
            fmt_secs(s.host.as_secs_f64()),
            fmt_secs(s.end_to_end().as_secs_f64()),
            format!("{:.1}", s.bytes_loaded as f64 / 1e6),
            format!("{}", s.prefetch_hits),
            format!("{:.3}", s.retained_fraction()),
        ]);
    }
    println!("{}", t.render());
    let med = stats::median(&e2e);
    println!(
        "median frame latency {} -> {:.2} frames/s sustainable",
        fmt_secs(med),
        1.0 / med
    );
    // I/O overlap achieved by the prefetch pipeline (async or inline).
    {
        let m = engine.metrics();
        let overlapped = m.total("io.overlapped").as_secs_f64();
        let charged = m.total("io").as_secs_f64();
        if overlapped > 0.0 {
            println!(
                "io overlap ratio: {:.1}% ({} of {} service hidden behind compute)",
                100.0 * overlapped / (overlapped + charged),
                fmt_secs(overlapped),
                fmt_secs(overlapped + charged)
            );
        }
    }
    // Per-member I/O breakdown + utilization skew for multi-device pools.
    let n_dev = engine.devices();
    if n_dev > 1 {
        let m = engine.metrics();
        let mut dt = Table::new("per-device I/O", &["device", "MB", "service", "share"]);
        let services: Vec<f64> = (0..n_dev)
            .map(|i| m.total(&format!("io.dev{i}")).as_secs_f64())
            .collect();
        let total_service: f64 = services.iter().sum();
        for (i, &s) in services.iter().enumerate() {
            dt.row(vec![
                format!("dev{i}"),
                format!("{:.1}", m.bytes(&format!("io.dev{i}")) as f64 / 1e6),
                fmt_secs(s),
                format!(
                    "{:.1}%",
                    if total_service > 0.0 { 100.0 * s / total_service } else { 0.0 }
                ),
            ]);
        }
        println!("{}", dt.render());
        let max = services.iter().cloned().fold(0.0f64, f64::max);
        let mean = total_service / n_dev as f64;
        println!(
            "utilization skew (max/mean member service): {:.2}",
            if mean > 0.0 { max / mean } else { 1.0 }
        );
    }
    Ok(0)
}

/// Signal flag for the network server's graceful shutdown (`SIGINT` /
/// `SIGTERM` → drain connections, join workers, exit 0).
static SHUTDOWN_SIGNAL: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

extern "C" fn on_shutdown_signal(_sig: libc::c_int) {
    // Async-signal-safe: a relaxed atomic store and nothing else.
    SHUTDOWN_SIGNAL.store(true, std::sync::atomic::Ordering::Relaxed);
}

/// `repro serve --listen ADDR`: expose the engine over HTTP through the
/// scheduler. Runs until SIGINT/SIGTERM (or `--duration` elapses), then
/// shuts down gracefully — the scheduler's idempotent `shutdown` makes
/// the signal path and `Drop` safe to overlap.
fn serve_network(
    engine: Engine,
    p: &ArgParser,
    model: &str,
    device: &str,
    sparsity: f64,
) -> Result<i32, ArgError> {
    use neuron_chunking::coordinator::Scheduler;
    use neuron_chunking::serving::args::scheduler_config;
    use neuron_chunking::serving::{Server, ServerConfig};
    use std::sync::atomic::Ordering;

    let listen: String = p.require("--listen")?;
    let addr_file = p.raw("--addr-file")?.map(str::to_string);
    let duration_s: Option<f64> = p.parsed("--duration")?;
    // Shared scheduler flag set (also documented by `redline`):
    // --workers / --batch-window / --streams (capacity) / --slo-ms /
    // --prefill-budget / --prefill-chunk, on top of NC_* env defaults.
    let sched_cfg = scheduler_config(p)?;
    let window_us = sched_cfg.batch_window.as_micros() as u64;
    let server_cfg = ServerConfig {
        listen,
        max_connections: p.parsed_or("--max-connections", 64usize)?.max(1),
        max_body_bytes: p.parsed_or("--max-body-kb", 8192usize)?.max(1) * 1024,
        extra_config: vec![
            ("device".to_string(), format!("\"{device}\"")),
            ("sparsity".to_string(), format!("{sparsity}")),
            ("batch_window_us".to_string(), format!("{window_us}")),
        ],
        ..ServerConfig::default()
    };

    println!("compiling {} artifacts…", engine.warmup().unwrap_or(0));
    // Keep a facade handle (cheap Arc clone) for the end-of-run pool
    // health summary; the scheduler owns the moved engine.
    let engine_handle = engine.clone();
    let sched = Scheduler::spawn(sched_cfg, move || engine);
    let server = match Server::start(server_cfg, sched) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("server start failed: {e:#}");
            return Ok(1);
        }
    };
    let addr = server.local_addr();
    if let Some(path) = &addr_file {
        if let Err(e) = std::fs::write(path, format!("{addr}\n")) {
            eprintln!("cannot write --addr-file {path}: {e}");
            return Ok(1);
        }
    }
    println!("serving model={model} device={device} on http://{addr}");
    println!(
        "endpoints: POST /v1/streams | POST /v1/streams/{{id}}/append | \
         POST /v1/streams/{{id}}/decode | GET /metrics | GET /healthz | GET /v1/config"
    );
    unsafe {
        let handler = on_shutdown_signal as extern "C" fn(libc::c_int) as libc::sighandler_t;
        libc::signal(libc::SIGINT, handler);
        libc::signal(libc::SIGTERM, handler);
    }
    let deadline = duration_s.map(|s| {
        std::time::Instant::now() + std::time::Duration::from_secs_f64(s.max(0.0))
    });
    while !SHUTDOWN_SIGNAL.load(Ordering::Relaxed) {
        if deadline.is_some_and(|d| std::time::Instant::now() >= d) {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    println!("shutting down…");
    let h = engine_handle.pool_health();
    println!(
        "pool health: dead={:?} retries={} failovers={} hedges={} hedge_wins={}",
        h.dead_members, h.retries, h.failovers, h.hedges, h.hedge_wins
    );
    server.shutdown();
    Ok(0)
}

/// Multi-stream decode serving through the scheduler's cross-stream
/// batching path: every stream is primed with its own frame, then decode
/// rounds are submitted concurrently so the bounded window fuses them
/// into shared-read batches. Reports throughput, achieved batch
/// occupancy, and the fused-I/O dedup ratio.
fn serve_batched(engine: Engine, streams: usize, window_us: u64, decode_steps: usize) -> i32 {
    use neuron_chunking::coordinator::{Request, Scheduler, SchedulerConfig};
    let spec = engine.spec();
    println!(
        "batched serving: {streams} streams, window {window_us}us, {} decode rounds",
        decode_steps.max(1)
    );
    let cfg = SchedulerConfig {
        workers: 1,
        batch_window: std::time::Duration::from_micros(window_us),
        max_batch: streams.max(2),
        ..SchedulerConfig::default()
    };
    let sched = Scheduler::spawn(cfg, move || engine);
    sched.engine().warmup().ok();
    let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, streams + 1, 11);
    // Prime every stream with its own frame.
    let rxs: Vec<_> = (0..streams)
        .map(|st| {
            sched.submit(Request::prefill(st, trace.frame(st))).unwrap()
        })
        .collect();
    for rx in rxs {
        if let Err(e) = rx.recv().unwrap().output {
            eprintln!("stream priming failed: {e}");
            return 1;
        }
    }
    let token = vec![0.05f32; spec.d];
    let rounds = decode_steps.max(1);
    let t0 = std::time::Instant::now();
    for _ in 0..rounds {
        let rxs: Vec<_> = (0..streams)
            .map(|st| {
                sched.submit(Request::decode(st, token.clone())).unwrap()
            })
            .collect();
        for rx in rxs {
            if let Err(e) = rx.recv().unwrap().output {
                eprintln!("decode failed: {e}");
                return 1;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let total = (streams * rounds) as f64;
    let m = sched.engine().metrics();
    let batches = m.count("batch.occupancy");
    let occupancy = if batches > 0 {
        m.bytes("batch.occupancy") as f64 / batches as f64
    } else {
        1.0
    };
    let shared = m.bytes("io.shared_bytes");
    let io_bytes = m.bytes("io");
    println!(
        "decode throughput: {:.0} tok/s ({streams} streams x {rounds} rounds in {:.3}s)",
        total / wall,
        wall
    );
    println!("batch occupancy: {occupancy:.2} avg members over {batches} fused batches");
    println!(
        "shared (deduped) reads: {:.2} MB of {:.2} MB demanded ({:.1}% saved by fusion)",
        shared as f64 / 1e6,
        (shared + io_bytes) as f64 / 1e6,
        100.0 * shared as f64 / ((shared + io_bytes).max(1)) as f64
    );
    sched.shutdown();
    0
}

fn cmd_profile(args: &[String]) -> i32 {
    let out = flag(args, "--out");
    let table = if let Some(path) = flag(args, "--file") {
        let threads: usize = flag(args, "--threads")
            .and_then(|s| s.parse().ok())
            .unwrap_or(6);
        println!("profiling real file {path} with {threads} threads…");
        let dev = match RealFileDevice::open(std::path::Path::new(&path), threads, false) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("open failed: {e:#}");
                return 1;
            }
        };
        use neuron_chunking::storage::FlashDevice;
        let max = (FlashDevice::capacity(&dev) / 256).min(512 * 1024) as usize;
        Profiler::new(&dev, ProfileConfig::coarse(max.max(4096), 1024)).build_table()
    } else {
        let device = flag(args, "--device").unwrap_or_else(|| "nano".into());
        let profile = match DeviceProfile::by_name(&device) {
            Some(p) => p,
            None => {
                eprintln!("unknown device {device}");
                return 2;
            }
        };
        println!(
            "profiling simulated {device} (peak {}, saturation {} KB)…",
            fmt_bw(profile.peak_bw),
            profile.saturation_bytes(0.99) / 1024
        );
        let dev = SimulatedSsd::timing_only(profile.clone(), 1 << 40, 1);
        Profiler::new(
            &dev,
            ProfileConfig {
                step_bytes: 4096,
                max_bytes: profile.saturation_bytes(0.99),
                ..Default::default()
            },
        )
        .build_table()
    };
    let table = match table {
        Ok(t) => t,
        Err(e) => {
            eprintln!("profiling failed: {e:#}");
            return 1;
        }
    };
    let mut report = Table::new("T[s] lookup table", &["chunk_kb", "latency", "throughput"]);
    let mut kb = 4;
    while kb * 1024 <= table.max_bytes() {
        let l = table.latency_bytes(kb * 1024);
        report.row(vec![
            format!("{kb}"),
            fmt_secs(l),
            fmt_bw(kb as f64 * 1024.0 / l),
        ]);
        kb *= 2;
    }
    println!("{}", report.render());
    if let Some(path) = out {
        if let Err(e) = std::fs::write(&path, table.to_text()) {
            eprintln!("write failed: {e}");
            return 1;
        }
        println!("wrote {path}");
    }
    0
}

fn cmd_select(args: &[String]) -> i32 {
    use neuron_chunking::sparsify::{ChunkSelect, ChunkSelectConfig, Selector, TopK};
    use neuron_chunking::workload::ActivationGen;
    let rows: usize = flag(args, "--rows").and_then(|s| s.parse().ok()).unwrap_or(4096);
    let sparsity: f64 = flag(args, "--sparsity")
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.5);
    let device = flag(args, "--device").unwrap_or_else(|| "nano".into());
    let profile = DeviceProfile::by_name(&device).unwrap_or_else(DeviceProfile::nano);
    let probe = SimulatedSsd::timing_only(profile.clone(), 1 << 40, 1);
    let table = Profiler::new(
        &probe,
        ProfileConfig::coarse(profile.saturation_bytes(0.99), 1024),
    )
    .build_table()
    .unwrap()
    .with_row_bytes(2048);

    let imp = ActivationGen::vlm(rows, 196, 0.4, 7).sample(0);
    let budget = ((1.0 - sparsity) * rows as f64) as usize;
    let sat_kb = profile.saturation_bytes(0.99) as f64 / 1024.0;
    let mut t = Table::new(
        &format!("selection comparison ({rows} rows, sparsity {sparsity}, {device})"),
        &["policy", "chunks", "mean_chunk", "est_latency", "importance_captured"],
    );
    for (name, sel) in [
        ("topk", Box::new(TopK) as Box<dyn Selector>),
        (
            "chunking",
            Box::new(ChunkSelect::new(ChunkSelectConfig::new(8.0, 8.0, sat_kb))),
        ),
    ] {
        let m = sel.select(&imp, budget, &table);
        let d = neuron_chunking::latency::ContiguityDistribution::from_chunks(&m.chunks);
        t.row(vec![
            name.into(),
            format!("{}", d.num_chunks()),
            format!("{:.1}", d.mean_chunk()),
            fmt_secs(table.estimate_chunks(&m.chunks)),
            format!("{:.4}", m.captured_importance(&imp)),
        ]);
    }
    println!("{}", t.render());
    0
}

fn cmd_models() -> i32 {
    use neuron_chunking::model::ModelSpec;
    let mut t = Table::new(
        "model catalogue",
        &["name", "d", "h", "kv", "layers", "tokens/frame", "weights", "runnable"],
    );
    let mut all = ModelSpec::paper_models();
    all.extend([ModelSpec::tiny(), ModelSpec::small(), ModelSpec::base()]);
    for m in all {
        t.row(vec![
            m.name.clone(),
            format!("{}", m.d),
            format!("{}", m.h),
            format!("{}", m.kv),
            format!("{}", m.layers),
            format!("{}", m.tokens_per_frame),
            format!("{:.1} GB", m.total_bytes() as f64 / 1e9),
            format!("{}", m.runnable),
        ]);
    }
    println!("{}", t.render());
    0
}
