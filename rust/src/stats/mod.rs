//! Statistics substrate: summary statistics, percentiles, coefficient of
//! variation, histograms, and the BCa bootstrap the paper uses for latency
//! confidence intervals (§4.1: median + 95% CI from a 10 000-sample
//! bias-corrected-and-accelerated bootstrap).

use crate::rng::Rng;

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Coefficient of variation (std/mean) — the smoothness metric of Table 1.
pub fn cv(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        return f64::NAN;
    }
    std_dev(xs) / m
}

/// Percentile via linear interpolation on the sorted copy (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    percentile_sorted(&v, p)
}

pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Standard-normal CDF (Abramowitz–Stegun 7.1.26 via erf approximation).
pub fn phi(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Inverse standard-normal CDF (Acklam's rational approximation).
pub fn phi_inv(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "phi_inv domain: {p}");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let plow = 0.02425;
    if p < plow {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - plow {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        -phi_inv(1.0 - p)
    }
}

fn erf(x: f64) -> f64 {
    // Abramowitz–Stegun 7.1.26, |err| <= 1.5e-7.
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736)
            * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Result of a bootstrap CI estimate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BootstrapCi {
    pub estimate: f64,
    pub lo: f64,
    pub hi: f64,
}

/// BCa bootstrap CI for the median (the paper's latency-reporting method).
///
/// `resamples` defaults to the paper's 10 000 in callers; `alpha` = 0.05
/// gives a 95% interval. Deterministic given the seed.
pub fn bootstrap_bca_median(
    xs: &[f64],
    resamples: usize,
    alpha: f64,
    seed: u64,
) -> BootstrapCi {
    bootstrap_bca(xs, median, resamples, alpha, seed)
}

/// Generic BCa bootstrap for any statistic.
pub fn bootstrap_bca(
    xs: &[f64],
    stat: fn(&[f64]) -> f64,
    resamples: usize,
    alpha: f64,
    seed: u64,
) -> BootstrapCi {
    assert!(!xs.is_empty());
    let theta = stat(xs);
    if xs.len() == 1 {
        return BootstrapCi {
            estimate: theta,
            lo: theta,
            hi: theta,
        };
    }
    let mut rng = Rng::new(seed);
    let n = xs.len();
    let mut boots = Vec::with_capacity(resamples);
    let mut buf = vec![0.0; n];
    for _ in 0..resamples {
        for slot in buf.iter_mut() {
            *slot = xs[rng.below(n)];
        }
        boots.push(stat(&buf));
    }
    boots.sort_by(|a, b| a.total_cmp(b));

    // Bias correction: fraction of bootstrap stats below the point estimate.
    let below = boots.iter().filter(|&&b| b < theta).count();
    let prop = ((below as f64) + 0.5) / (resamples as f64 + 1.0); // smoothed
    let z0 = phi_inv(prop.clamp(1e-9, 1.0 - 1e-9));

    // Acceleration via jackknife.
    let mut jack = Vec::with_capacity(n);
    let mut jbuf = Vec::with_capacity(n - 1);
    for i in 0..n {
        jbuf.clear();
        jbuf.extend(xs.iter().enumerate().filter(|(j, _)| *j != i).map(|(_, v)| *v));
        jack.push(stat(&jbuf));
    }
    let jm = mean(&jack);
    let num: f64 = jack.iter().map(|j| (jm - j).powi(3)).sum();
    let den: f64 = jack.iter().map(|j| (jm - j).powi(2)).sum::<f64>().powf(1.5);
    let a = if den.abs() < 1e-30 { 0.0 } else { num / (6.0 * den) };

    let z_alpha = phi_inv(alpha / 2.0);
    let z_1alpha = phi_inv(1.0 - alpha / 2.0);
    let adj = |z: f64| -> f64 {
        let w = z0 + (z0 + z) / (1.0 - a * (z0 + z));
        phi(w)
    };
    let lo_q = adj(z_alpha).clamp(0.0, 1.0) * 100.0;
    let hi_q = adj(z_1alpha).clamp(0.0, 1.0) * 100.0;
    BootstrapCi {
        estimate: theta,
        lo: percentile_sorted(&boots, lo_q),
        hi: percentile_sorted(&boots, hi_q),
    }
}

/// Fixed-bin histogram over [lo, hi).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Self {
            lo,
            hi,
            bins: vec![0; nbins],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let idx = ((x - self.lo) / (self.hi - self.lo) * self.bins.len() as f64)
                as usize;
            let idx = idx.min(self.bins.len() - 1);
            self.bins[idx] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// (bin_center, density) pairs; density integrates to <= 1.
    pub fn density(&self) -> Vec<(f64, f64)> {
        let total = self.total().max(1) as f64;
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        self.bins
            .iter()
            .enumerate()
            .map(|(i, &c)| (self.lo + (i as f64 + 0.5) * w, c as f64 / total / w))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
    }

    #[test]
    fn std_dev_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((std_dev(&xs) - 2.138).abs() < 1e-3);
    }

    #[test]
    fn cv_scale_invariant() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b: Vec<f64> = a.iter().map(|x| x * 100.0).collect();
        assert!((cv(&a) - cv(&b)).abs() < 1e-12);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [10.0, 20.0, 30.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 30.0);
        assert_eq!(percentile(&xs, 50.0), 20.0);
    }

    #[test]
    fn phi_inv_round_trip() {
        for p in [0.01, 0.025, 0.2, 0.5, 0.8, 0.975, 0.99] {
            assert!((phi(phi_inv(p)) - p).abs() < 1e-6, "p={p}");
        }
    }

    #[test]
    fn phi_symmetry() {
        assert!((phi(0.0) - 0.5).abs() < 1e-9);
        assert!((phi(1.96) - 0.975).abs() < 1e-3);
    }

    #[test]
    fn bootstrap_covers_median() {
        let mut rng = Rng::new(100);
        let xs: Vec<f64> = (0..60).map(|_| rng.normal_ms(50.0, 5.0)).collect();
        let ci = bootstrap_bca_median(&xs, 2000, 0.05, 7);
        assert!(ci.lo <= ci.estimate && ci.estimate <= ci.hi);
        assert!(ci.lo > 40.0 && ci.hi < 60.0, "{ci:?}");
    }

    #[test]
    fn bootstrap_deterministic() {
        let xs: Vec<f64> = (0..40).map(|i| (i as f64).sin() * 10.0 + 50.0).collect();
        let a = bootstrap_bca_median(&xs, 500, 0.05, 42);
        let b = bootstrap_bca_median(&xs, 500, 0.05, 42);
        assert_eq!(a, b);
    }

    #[test]
    fn bootstrap_single_sample() {
        let ci = bootstrap_bca_median(&[3.0], 100, 0.05, 1);
        assert_eq!(ci.lo, 3.0);
        assert_eq!(ci.hi, 3.0);
    }

    #[test]
    fn bootstrap_tight_for_constant_data() {
        let xs = vec![5.0; 30];
        let ci = bootstrap_bca_median(&xs, 500, 0.05, 3);
        assert_eq!(ci.lo, 5.0);
        assert_eq!(ci.hi, 5.0);
    }

    #[test]
    fn histogram_counts_and_density() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(11.0);
        assert_eq!(h.total(), 12);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert!(h.bins.iter().all(|&b| b == 1));
        let d = h.density();
        assert_eq!(d.len(), 10);
        let integral: f64 = d.iter().map(|(_, y)| y * 1.0).sum();
        assert!((integral - 10.0 / 12.0).abs() < 1e-9);
    }
}
