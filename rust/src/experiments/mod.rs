//! Experiment harness: the shared machinery that regenerates every table
//! and figure of the paper (see DESIGN.md §5 for the index).
//!
//! I/O-only experiments run the five paper models (exact matrix shapes,
//! fp16 rows) against the calibrated flash simulator, sampling three
//! representative layers (early/mid/late, like the paper's appendix) and
//! scaling I/O to the full depth. Accuracy comes from the retained-
//! importance proxy mapped through the per-dataset curves. End-to-end
//! experiments (Fig 8) use the runnable engine instead.

mod figures;

pub use figures::*;

use std::collections::HashMap;

use crate::latency::LatencyTable;
use crate::model::{MatrixId, MatrixKind, ModelSpec, WeightStore};
use crate::reorder::{HotColdReorder, Permutation};
use crate::sparsify::teal::{MatrixCalibration, SparsityAllocator};
use crate::sparsify::{ChunkSelectConfig, SelectionMask, Selector};
use crate::stats;
use crate::storage::{DeviceProfile, FlashDevice, ProfileConfig, Profiler, SimulatedSsd};
use crate::workload::{AccuracyModel, ActivationGen, DatasetSpec};

/// Selection policy variants used across experiments.
#[derive(Clone, Debug, PartialEq)]
pub enum IoPolicy {
    TopK,
    /// Top-k over an offline hot–cold reordered layout.
    TopKReordered,
    /// Chunk selection (+ reordering, the full method).
    Chunking,
    /// Chunk selection without reordering (ablation).
    ChunkingNoReorder,
    /// LLM-in-a-Flash bundling over the reordered layout (Table 3).
    Bundling,
}

impl IoPolicy {
    pub fn label(&self) -> &'static str {
        match self {
            IoPolicy::TopK => "baseline",
            IoPolicy::TopKReordered => "baseline+reorder",
            IoPolicy::Chunking => "ours",
            IoPolicy::ChunkingNoReorder => "ours-noreorder",
            IoPolicy::Bundling => "baseline+bundling",
        }
    }

    fn reordered(&self) -> bool {
        matches!(
            self,
            IoPolicy::TopKReordered | IoPolicy::Chunking | IoPolicy::Bundling
        )
    }
}

/// One representative layer sampled by the I/O experiments.
#[derive(Clone, Copy, Debug)]
pub struct LayerSample {
    pub layer: usize,
    /// Relative depth in [0, 1] (drives the activation-CV profile).
    pub pos: f64,
}

/// The I/O experiment rig for one (model, device) pair.
pub struct PaperRig {
    pub spec: ModelSpec,
    pub profile: DeviceProfile,
    pub store: WeightStore,
    pub device: SimulatedSsd,
    /// Byte-keyed `T[s]` from profiling the simulator (re-keyed per row).
    pub table: LatencyTable,
    pub layers: Vec<LayerSample>,
    /// Importance generators per (sampled layer, scored kind).
    gens: HashMap<(usize, MatrixKind), ActivationGen>,
    /// Hot–cold permutations per (sampled layer, scored kind).
    perms: HashMap<(usize, MatrixKind), Permutation>,
    /// Per (sampled layer, scored kind): sparsity allocator index.
    alloc: SparsityAllocator,
    alloc_keys: Vec<(usize, MatrixKind)>,
    pub dataset_seed: u64,
}

/// Calibration sizing (speed/fidelity knobs).
#[derive(Clone, Copy, Debug)]
pub struct RigConfig {
    pub calib_samples: usize,
    pub tokens_per_frame: usize,
    pub seed: u64,
}

impl Default for RigConfig {
    fn default() -> Self {
        Self {
            calib_samples: 24,
            tokens_per_frame: 0, // 0 = model default
            seed: 1,
        }
    }
}

impl PaperRig {
    pub fn new(spec: ModelSpec, profile: DeviceProfile, cfg: RigConfig) -> anyhow::Result<Self> {
        let store = WeightStore::new(spec.clone(), false, cfg.seed);
        let device = SimulatedSsd::timing_only(
            profile.clone(),
            store.layout.total_bytes().max(1 << 32),
            cfg.seed ^ 0x51ED,
        );
        let sat = profile.saturation_bytes(0.99);
        let probe = SimulatedSsd::timing_only(profile.clone(), 1 << 40, cfg.seed ^ 0xBEEF);
        let table = Profiler::new(&probe, ProfileConfig::coarse(sat, 1024)).build_table()?;

        // Representative layers: early / mid / late (paper Appendix A).
        let l = spec.layers;
        let layers = vec![
            LayerSample { layer: 0, pos: 0.0 },
            LayerSample {
                layer: l / 2,
                pos: 0.5,
            },
            LayerSample {
                layer: l - 1,
                pos: 1.0,
            },
        ];

        let tokens = if cfg.tokens_per_frame == 0 {
            spec.tokens_per_frame
        } else {
            cfg.tokens_per_frame
        };
        let mut gens = HashMap::new();
        let mut calibs = Vec::new();
        let mut alloc_keys = Vec::new();
        for ls in &layers {
            for kind in MatrixKind::SCORED {
                let rows = spec.shape_of(kind).rows;
                let seed = cfg.seed
                    ^ (ls.layer as u64) << 20
                    ^ (kind as u64) << 12
                    ^ 0xACE0;
                let gen = ActivationGen::vlm(rows, tokens, ls.pos, seed);
                // Calibration set for TEAL allocation + reordering.
                let samples = gen.samples(cfg.calib_samples, 1_000_000);
                let flat: Vec<f32> = samples.iter().flat_map(|s| {
                    // Subsample big matrices to bound allocator cost.
                    let stride = (s.len() / 2048).max(1);
                    s.iter().step_by(stride).copied().collect::<Vec<_>>()
                }).collect();
                calibs.push(MatrixCalibration {
                    name: format!("l{}_{}", ls.layer, kind.name()),
                    rows,
                    samples: flat,
                });
                alloc_keys.push((ls.layer, kind));
                gens.insert((ls.layer, kind), gen);
            }
        }
        let alloc = SparsityAllocator::new(calibs);

        // Hot–cold permutations from the same calibration stream.
        let mut perms = HashMap::new();
        for ls in &layers {
            for kind in MatrixKind::SCORED {
                let gen = &gens[&(ls.layer, kind)];
                let rows = spec.shape_of(kind).rows;
                let samples = gen.samples(cfg.calib_samples, 1_000_000);
                perms.insert((ls.layer, kind), HotColdReorder.build(&samples, rows));
            }
        }

        Ok(Self {
            spec,
            profile,
            store,
            device,
            table,
            layers,
            gens,
            perms,
            alloc,
            alloc_keys,
            dataset_seed: cfg.seed,
        })
    }

    pub fn gen(&self, layer: usize, kind: MatrixKind) -> &ActivationGen {
        &self.gens[&(layer, kind)]
    }

    pub fn perm(&self, layer: usize, kind: MatrixKind) -> &Permutation {
        &self.perms[&(layer, kind)]
    }

    /// Per-(sampled layer, scored kind) row budgets at a target effective
    /// sparsity (TEAL-style allocation shared by all policies, §4.1).
    pub fn budgets(&self, sparsity: f64) -> HashMap<(usize, MatrixKind), usize> {
        self.alloc
            .budgets(sparsity)
            .into_iter()
            .zip(&self.alloc_keys)
            .map(|(b, &k)| (k, b))
            .collect()
    }

    /// The paper's chunk-selection config for a matrix shape on this
    /// device (Table 2), or a default derived from the saturation point.
    pub fn chunk_config(&self, kind: MatrixKind) -> ChunkSelectConfig {
        let shape = self.spec.shape_of(kind);
        let sat_kb = self.profile.saturation_bytes(0.99) as f64 / 1024.0;
        crate::sparsify::tuning::paper_config_for(
            shape.rows,
            shape.cols,
            &self.profile.name,
            sat_kb,
        )
        .unwrap_or_else(|| ChunkSelectConfig::new(8.0, 8.0, sat_kb))
    }

    fn selector_for(&self, policy: &IoPolicy, kind: MatrixKind) -> Box<dyn Selector> {
        match policy {
            IoPolicy::TopK | IoPolicy::TopKReordered => Box::new(crate::sparsify::TopK),
            IoPolicy::Chunking | IoPolicy::ChunkingNoReorder => Box::new(
                crate::sparsify::ChunkSelect::new(self.chunk_config(kind)),
            ),
            IoPolicy::Bundling => Box::new(crate::sparsify::Bundling::new(2)),
        }
    }

    /// Run one frame through one sampled layer group and return
    /// (io seconds, captured importance, total importance, selection masks
    /// per scored kind).
    pub fn frame_layer_io(
        &self,
        policy: &IoPolicy,
        layer: usize,
        frame_idx: u64,
        budgets: &HashMap<(usize, MatrixKind), usize>,
    ) -> anyhow::Result<FrameLayerIo> {
        let mut io = 0.0f64;
        let mut kept = 0.0f64;
        let mut total = 0.0f64;
        let mut masks = HashMap::new();
        for kind in MatrixKind::SCORED {
            let gen = self.gen(layer, kind);
            let imp_logical = gen.sample(frame_idx);
            let imp: Vec<f32> = if policy.reordered() {
                self.perm(layer, kind).apply(&imp_logical)
            } else {
                imp_logical
            };
            let budget = budgets[&(layer, kind)];
            let row_bytes = self.spec.row_bytes(kind);
            let table = self.table.with_row_bytes(row_bytes);
            let selector = self.selector_for(policy, kind);
            let sel = selector.select(&imp, budget, &table);
            total += imp.iter().map(|&v| v as f64).sum::<f64>();
            kept += sel.captured_importance(&imp);
            if matches!(policy, IoPolicy::Bundling) {
                // LLM-in-a-Flash row–column bundling (Appendix L): gate,
                // up and down rows of a neuron are stored adjacently.
                // Gate-mask loads read 2-row bundles contiguously (and
                // adjacent selected neurons merge), but the *down* matrix,
                // sparsified by its own activation, now sits at stride-3
                // row spacing: its reads are isolated single rows no
                // matter how contiguous the mask is. Q/K/V/O keep their
                // plain layout.
                io += self.bundled_io(layer, kind, &sel)?;
            } else {
                // Load every member matrix sharing this mask.
                for member in MatrixKind::ALL {
                    if member.mask_source() != kind {
                        continue;
                    }
                    let id = MatrixId::new(layer, member);
                    let t = self.store.read_timing(&self.device, id, &sel.chunks)?;
                    io += t.as_secs_f64();
                }
            }
            masks.insert(kind, sel);
        }
        Ok(FrameLayerIo {
            io_seconds: io,
            kept,
            total,
            masks,
        })
    }

    /// I/O time for one selection group under the bundled (interleaved
    /// gate/up/down) layout — see the Bundling branch in
    /// [`Self::frame_layer_io`].
    fn bundled_io(
        &self,
        layer: usize,
        kind: MatrixKind,
        sel: &SelectionMask,
    ) -> anyhow::Result<f64> {
        use crate::storage::Extent;
        let mut io = 0.0f64;
        match kind {
            MatrixKind::Gate => {
                // gate+up rows fused: each chunk covers 2*row contiguous
                // bytes per neuron within the interleaved region.
                let row = self.spec.row_bytes(MatrixKind::Gate)
                    + self.spec.row_bytes(MatrixKind::Up);
                let region = self.spec.row_bytes(MatrixKind::Gate)
                    + self.spec.row_bytes(MatrixKind::Up)
                    + self.spec.row_bytes(MatrixKind::Down);
                let extents: Vec<Extent> = sel
                    .chunks
                    .iter()
                    .flat_map(|c| {
                        // Adjacent neurons do NOT merge: the interleaved
                        // down row splits them.
                        (c.start..c.end()).map(move |i| Extent::new((i * region) as u64, row))
                    })
                    .collect();
                io += self.device.service_time(&extents)?.as_secs_f64();
            }
            MatrixKind::Down => {
                // Down rows at stride-3: every selected row is isolated.
                let row = self.spec.row_bytes(MatrixKind::Down);
                let region = self.spec.row_bytes(MatrixKind::Gate)
                    + self.spec.row_bytes(MatrixKind::Up)
                    + row;
                let base = self.spec.row_bytes(MatrixKind::Gate)
                    + self.spec.row_bytes(MatrixKind::Up);
                let extents: Vec<Extent> = sel
                    .chunks
                    .iter()
                    .flat_map(|c| {
                        (c.start..c.end())
                            .map(move |i| Extent::new((base + i * region) as u64, row))
                    })
                    .collect();
                io += self.device.service_time(&extents)?.as_secs_f64();
            }
            other => {
                // Q/K/V/O keep the plain per-matrix layout.
                for member in MatrixKind::ALL {
                    if member.mask_source() != other {
                        continue;
                    }
                    let id = MatrixId::new(layer, member);
                    io += self
                        .store
                        .read_timing(&self.device, id, &sel.chunks)?
                        .as_secs_f64();
                }
            }
        }
        Ok(io)
    }

    /// Full accuracy–latency curve point for a policy at one sparsity.
    pub fn run_point(
        &self,
        policy: &IoPolicy,
        sparsity: f64,
        dataset: &DatasetSpec,
        frames: usize,
    ) -> anyhow::Result<CurvePoint> {
        let budgets = self.budgets(sparsity);
        let acc_model = AccuracyModel::new(dataset.clone());
        let scale = self.spec.layers as f64 / self.layers.len() as f64;
        let mut frame_ios = Vec::with_capacity(frames);
        let mut retained = Vec::with_capacity(frames);
        for f in 0..frames as u64 {
            let mut io = 0.0;
            let mut kept = 0.0;
            let mut total = 0.0;
            for ls in &self.layers {
                let r = self.frame_layer_io(
                    policy,
                    ls.layer,
                    dataset.seed.wrapping_mul(1000) + f,
                    &budgets,
                )?;
                io += r.io_seconds;
                kept += r.kept;
                total += r.total;
            }
            frame_ios.push(io * scale);
            retained.push(kept / total.max(1e-12));
        }
        let mean_ret = stats::mean(&retained);
        // Smaller backbones have less neuron redundancy, so losing the
        // same importance fraction costs them more accuracy (standard
        // pruning-literature behaviour; it is also why the paper's
        // measured 0.5B speedups are not larger than the 7B ones despite
        // worse fragmentation). Scale the importance *loss* by a mild
        // size factor anchored at 7B.
        let params = self.spec.total_bytes() as f64 / self.spec.dtype_bytes as f64;
        let redundancy = (7e9 / params).powf(0.25).clamp(1.0, 2.0);
        let eff_ret = 1.0 - (1.0 - mean_ret) * redundancy;
        Ok(CurvePoint {
            sparsity,
            io_seconds: stats::median(&frame_ios),
            io_ci: stats::bootstrap_bca_median(&frame_ios, 2000, 0.05, 77),
            retained: mean_ret,
            accuracy: acc_model.score(eff_ret),
        })
    }

    /// Full curve over sparsity levels (paper: 0..=0.7 step 0.1).
    pub fn run_curve(
        &self,
        policy: &IoPolicy,
        dataset: &DatasetSpec,
        sparsities: &[f64],
        frames: usize,
    ) -> anyhow::Result<Vec<CurvePoint>> {
        sparsities
            .iter()
            .map(|&s| self.run_point(policy, s, dataset, frames))
            .collect()
    }
}

/// Result of one (frame, layer) I/O pass.
pub struct FrameLayerIo {
    pub io_seconds: f64,
    pub kept: f64,
    pub total: f64,
    pub masks: HashMap<MatrixKind, SelectionMask>,
}

/// One accuracy–latency curve point.
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    pub sparsity: f64,
    pub io_seconds: f64,
    pub io_ci: stats::BootstrapCi,
    pub retained: f64,
    pub accuracy: f64,
}

/// Paper-style speedup at matched accuracy: for each accuracy level on
/// `ours`, linearly interpolate the baseline's latency at that accuracy
/// and take the ratio. Returns (mean, max) over the overlapping range.
pub fn speedup_at_matched_accuracy(baseline: &[CurvePoint], ours: &[CurvePoint]) -> (f64, f64) {
    // Build baseline accuracy -> latency interpolation (sorted by acc).
    let mut base: Vec<(f64, f64)> = baseline.iter().map(|p| (p.accuracy, p.io_seconds)).collect();
    base.sort_by(|a, b| a.0.total_cmp(&b.0));
    let (lo, hi) = (base.first().unwrap().0, base.last().unwrap().0);
    let interp = |acc: f64| -> Option<f64> {
        if acc < lo || acc > hi {
            return None;
        }
        let idx = base.partition_point(|p| p.0 < acc);
        if idx == 0 {
            return Some(base[0].1);
        }
        if idx >= base.len() {
            return Some(base.last().unwrap().1);
        }
        let (a0, l0) = base[idx - 1];
        let (a1, l1) = base[idx];
        let f = if a1 > a0 { (acc - a0) / (a1 - a0) } else { 0.5 };
        Some(l0 * (1.0 - f) + l1 * f)
    };
    let mut ratios = Vec::new();
    for p in ours {
        if let Some(bl) = interp(p.accuracy) {
            if p.io_seconds > 0.0 {
                ratios.push(bl / p.io_seconds);
            }
        }
    }
    if ratios.is_empty() {
        return (1.0, 1.0);
    }
    (
        stats::mean(&ratios),
        ratios.iter().copied().fold(f64::MIN, f64::max),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rig() -> PaperRig {
        // The 0.5B model keeps test cost low.
        PaperRig::new(
            ModelSpec::llava_05b(),
            DeviceProfile::nano(),
            RigConfig {
                calib_samples: 8,
                tokens_per_frame: 0,
                seed: 3,
            },
        )
        .unwrap()
    }

    #[test]
    fn budgets_scale_with_sparsity() {
        let r = rig();
        let b20 = r.budgets(0.2);
        let b60 = r.budgets(0.6);
        let sum = |b: &HashMap<(usize, MatrixKind), usize>| b.values().sum::<usize>();
        assert!(sum(&b60) < sum(&b20));
    }

    #[test]
    fn chunking_point_beats_topk_io_at_same_sparsity() {
        let r = rig();
        let ds = DatasetSpec::tempcompass();
        let ours = r.run_point(&IoPolicy::Chunking, 0.4, &ds, 3).unwrap();
        let base = r.run_point(&IoPolicy::TopK, 0.4, &ds, 3).unwrap();
        assert!(
            ours.io_seconds < base.io_seconds,
            "ours {} vs baseline {}",
            ours.io_seconds,
            base.io_seconds
        );
        // Baseline retains >= importance (it's optimal on importance).
        assert!(base.retained >= ours.retained - 0.02);
    }

    #[test]
    fn topk_io_can_exceed_dense_at_low_sparsity() {
        // Fig 4b / §4.2: fragmented reads at low-mid sparsity can cost
        // more than a full contiguous load.
        let r = rig();
        let ds = DatasetSpec::tempcompass();
        let frag = r.run_point(&IoPolicy::TopK, 0.2, &ds, 2).unwrap();
        // Dense = one full contiguous read of everything (3 layers scaled).
        let scale = r.spec.layers as f64 / 3.0;
        let mut dense = 0.0;
        for ls in &r.layers {
            for m in MatrixKind::ALL {
                let id = MatrixId::new(ls.layer, m);
                let rows = r.spec.shape_of(m).rows;
                let t = r
                    .store
                    .read_timing(&r.device, id, &[crate::latency::Chunk::new(0, rows)])
                    .unwrap();
                dense += t.as_secs_f64();
            }
        }
        dense *= scale;
        assert!(
            frag.io_seconds > dense,
            "fragmented {} should exceed dense {}",
            frag.io_seconds,
            dense
        );
    }

    #[test]
    fn speedup_interpolation_sane() {
        let mk = |acc: &[f64], lat: &[f64]| -> Vec<CurvePoint> {
            acc.iter()
                .zip(lat)
                .map(|(&a, &l)| CurvePoint {
                    sparsity: 0.0,
                    io_seconds: l,
                    io_ci: stats::BootstrapCi {
                        estimate: l,
                        lo: l,
                        hi: l,
                    },
                    retained: a,
                    accuracy: a,
                })
                .collect()
        };
        let base = mk(&[0.5, 0.6, 0.7], &[4.0, 6.0, 8.0]);
        let ours = mk(&[0.5, 0.6, 0.7], &[2.0, 2.0, 4.0]);
        let (mean, max) = speedup_at_matched_accuracy(&base, &ours);
        assert!((mean - (2.0 + 3.0 + 2.0) / 3.0).abs() < 1e-9);
        assert!((max - 3.0).abs() < 1e-9);
    }

    #[test]
    fn curve_latency_decreases_with_sparsity_for_ours() {
        let r = rig();
        let ds = DatasetSpec::nextqa();
        let pts = r
            .run_curve(&IoPolicy::Chunking, &ds, &[0.1, 0.4, 0.7], 2)
            .unwrap();
        assert!(pts[0].io_seconds > pts[2].io_seconds);
        assert!(pts[0].accuracy >= pts[2].accuracy - 1e-9);
    }
}
