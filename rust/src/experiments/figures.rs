//! One function per paper table/figure. Each returns [`report::Table`]s
//! ready to print and dump as CSV; `rust/src/bin/figures.rs` dispatches.

use std::collections::HashMap;

use crate::experiments::{speedup_at_matched_accuracy, CurvePoint, IoPolicy, PaperRig, RigConfig};
use crate::latency::ContiguityDistribution;
use crate::model::{MatrixKind, ModelSpec};
use crate::reorder::CoActivationReorder;
use crate::report::{fmt_bw, fmt_secs, Table};
use crate::rng::Rng;
use crate::sparsify::{Selector, TopK};
use crate::stats;
use crate::storage::{DeviceProfile, Extent, SimulatedSsd};
use crate::workload::{ActivationGen, DatasetSpec};

/// Effort knob: `quick` for CI, `full` for EXPERIMENTS.md runs.
#[derive(Clone, Copy, Debug)]
pub struct Quality {
    pub frames: usize,
    pub calib: usize,
    pub trials: usize,
}

impl Quality {
    pub fn quick() -> Self {
        Self {
            frames: 3,
            calib: 8,
            trials: 5,
        }
    }

    pub fn full() -> Self {
        Self {
            frames: 8,
            calib: 24,
            trials: 30,
        }
    }
}

fn rig(model: ModelSpec, profile: DeviceProfile, q: Quality) -> anyhow::Result<PaperRig> {
    PaperRig::new(
        model,
        profile,
        RigConfig {
            calib_samples: q.calib,
            tokens_per_frame: 0,
            seed: 1,
        },
    )
}

const SPARSITIES: [f64; 8] = [0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7];

// ---------------------------------------------------------------- Fig 2

/// Activation-magnitude profiles: ReLU LLM decode vs gated VLM frame
/// append (sorted, normalized).
pub fn fig2(_q: Quality) -> anyhow::Result<Vec<Table>> {
    let n = 4096;
    let relu = ActivationGen::relu(n, 11).sample(0);
    let vlm = ActivationGen::vlm(n, 196, 0.5, 11).sample(0);
    let norm_sort = |mut v: Vec<f32>| {
        v.sort_by(|a, b| b.total_cmp(a));
        let max = v[0].max(1e-9);
        v.into_iter().map(|x| x / max).collect::<Vec<f32>>()
    };
    let (r, v) = (norm_sort(relu), norm_sort(vlm));
    let mut t = Table::new(
        "Fig 2: sorted activation magnitude (normalized)",
        &["rank_pct", "relu_llm", "gated_vlm"],
    );
    for pct in (0..=100).step_by(5) {
        let idx = ((pct as f64 / 100.0) * (n - 1) as f64) as usize;
        t.row(vec![
            format!("{pct}"),
            format!("{:.4}", r[idx]),
            format!("{:.4}", v[idx]),
        ]);
    }
    Ok(vec![t])
}

// ---------------------------------------------------------------- Fig 3

/// Read throughput vs block size × request count (AGX + 990 Pro).
pub fn fig3(_q: Quality) -> anyhow::Result<Vec<Table>> {
    let dev = SimulatedSsd::timing_only(DeviceProfile::agx(), 1 << 40, 5);
    let mut t = Table::new(
        "Fig 3: throughput vs block size and request count (agx)",
        &["block_kb", "requests", "throughput_mbps"],
    );
    for &kb in &[4usize, 16, 64, 236, 512] {
        for &n in &[1usize, 2, 4, 8, 16, 32, 64, 128, 256] {
            let extents: Vec<Extent> = (0..n)
                .map(|i| Extent::new((i * kb * 2048) as u64, kb * 1024))
                .collect();
            let secs = dev.model_service_seconds(&extents, 1.0);
            let tput = (n * kb * 1024) as f64 / secs / 1e6;
            t.row(vec![
                format!("{kb}"),
                format!("{n}"),
                format!("{tput:.1}"),
            ]);
        }
    }
    Ok(vec![t])
}

// --------------------------------------------------------------- Fig 4a

/// Throughput vs block size reading 128 MB (both devices).
pub fn fig4a(_q: Quality) -> anyhow::Result<Vec<Table>> {
    let total = 128usize << 20;
    let mut t = Table::new(
        "Fig 4a: block size vs flash read throughput (128 MB total)",
        &["block_kb", "nano_mbps", "agx_mbps"],
    );
    let devs = [
        SimulatedSsd::timing_only(DeviceProfile::nano(), 1 << 40, 7),
        SimulatedSsd::timing_only(DeviceProfile::agx(), 1 << 40, 7),
    ];
    for kb in [1usize, 2, 4, 8, 16, 32, 64, 128, 192, 236, 256, 348, 512, 1024] {
        let n = (total / (kb * 1024)).max(1);
        let extents: Vec<Extent> = (0..n)
            .map(|i| Extent::new((i * kb * 2048) as u64, kb * 1024))
            .collect();
        let tput: Vec<String> = devs
            .iter()
            .map(|d| {
                let secs = d.model_service_seconds(&extents, 1.0);
                format!("{:.1}", (n * kb * 1024) as f64 / secs / 1e6)
            })
            .collect();
        t.row(vec![format!("{kb}"), tput[0].clone(), tput[1].clone()]);
    }
    Ok(vec![t])
}

// --------------------------------------------------------------- Fig 4b

/// Latency vs sparsity under scattered vs contiguous access (128 MB
/// matrix, Qwen2-7B gate row size), both devices.
pub fn fig4b(q: Quality) -> anyhow::Result<Vec<Table>> {
    let spec = ModelSpec::llava_7b();
    let row_bytes = spec.row_bytes(MatrixKind::Gate); // ~37.9 KB fp16
    let rows = spec.d; // 3584 rows = ~130 MB
    let mut out = Vec::new();
    for profile in [DeviceProfile::nano(), DeviceProfile::agx()] {
        let sat = profile.saturation_bytes(0.99);
        let dev = SimulatedSsd::timing_only(profile.clone(), 1 << 40, 13);
        let mut t = Table::new(
            &format!(
                "Fig 4b: latency vs sparsity ({}), full-load = contiguous s=0",
                profile.name
            ),
            &["sparsity", "scattered_ms", "contiguous_ms", "full_load_ms"],
        );
        let full_extent = vec![Extent::new(0, rows * row_bytes)];
        let full_ms = dev.model_service_seconds(&full_extent, 1.0) * 1e3;
        let mut rng = Rng::new(17);
        for s in [0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9] {
            let keep = ((1.0 - s) * rows as f64) as usize;
            // Scattered: `keep` random single rows.
            let mut scattered_ms = Vec::new();
            for _ in 0..q.trials.max(3) {
                let idx = rng.sample_indices(rows, keep);
                let extents: Vec<Extent> = idx
                    .iter()
                    .map(|&i| Extent::new((i * row_bytes) as u64, row_bytes))
                    .collect();
                scattered_ms.push(dev.model_service_seconds(&extents, 1.0) * 1e3);
            }
            // Contiguous: saturating-size chunks.
            let chunk_rows = (sat / row_bytes).max(1);
            let mut extents = Vec::new();
            let mut left = keep;
            let mut at = 0usize;
            while left > 0 {
                let take = left.min(chunk_rows);
                extents.push(Extent::new((at * row_bytes) as u64, take * row_bytes));
                at += take * 2; // fixed stride between chunks
                left -= take;
            }
            let contiguous_ms = dev.model_service_seconds(&extents, 1.0) * 1e3;
            t.row(vec![
                format!("{s:.1}"),
                format!("{:.1}", stats::mean(&scattered_ms)),
                format!("{contiguous_ms:.1}"),
                format!("{full_ms:.1}"),
            ]);
        }
        out.push(t);
    }
    Ok(out)
}

// ---------------------------------------------------------------- Fig 5

/// Latency-model validation: estimated vs "actual" (simulated) latency
/// for chunk-selected patterns; reports pairs + proportional-fit stats.
pub fn fig5(q: Quality) -> anyhow::Result<Vec<Table>> {
    let mut out = Vec::new();
    for (model, profile) in [
        (ModelSpec::llava_7b(), DeviceProfile::agx()),
        (ModelSpec::llava_7b(), DeviceProfile::nano()),
        (ModelSpec::llava_05b(), DeviceProfile::agx()),
        (ModelSpec::llava_05b(), DeviceProfile::nano()),
    ] {
        let name = format!("{} / {}", model.name, profile.name);
        let r = rig(model, profile, q)?;
        let mut t = Table::new(
            &format!("Fig 5: real vs estimated latency ({name})"),
            &["pattern", "estimated_ms", "actual_ms", "ratio"],
        );
        let mut ests = Vec::new();
        let mut acts = Vec::new();
        let budgets_list: Vec<_> = [0.2, 0.4, 0.6]
            .iter()
            .map(|&s| r.budgets(s))
            .collect();
        let mut i = 0;
        for budgets in &budgets_list {
            for ls in &r.layers {
                let fio = r.frame_layer_io(&IoPolicy::Chunking, ls.layer, 42 + i, budgets)?;
                // Estimated via the additive chunk model over all member
                // matrices; actual from the simulator (already in fio).
                let mut est = 0.0;
                for (kind, sel) in &fio.masks {
                    for member in MatrixKind::ALL {
                        if member.mask_source() != *kind {
                            continue;
                        }
                        let table = r.table.with_row_bytes(r.spec.row_bytes(member));
                        est += table.estimate_chunks(&sel.chunks);
                    }
                }
                ests.push(est);
                acts.push(fio.io_seconds);
                t.row(vec![
                    format!("s{}_l{}", i, ls.layer),
                    format!("{:.2}", est * 1e3),
                    format!("{:.2}", fio.io_seconds * 1e3),
                    format!("{:.3}", fio.io_seconds / est.max(1e-12)),
                ]);
                i += 1;
            }
        }
        // Proportional-fit quality: slope through origin + R².
        let slope = ests
            .iter()
            .zip(&acts)
            .map(|(e, a)| e * a)
            .sum::<f64>()
            / ests.iter().map(|e| e * e).sum::<f64>();
        let mean_a = stats::mean(&acts);
        let ss_tot: f64 = acts.iter().map(|a| (a - mean_a).powi(2)).sum();
        let ss_res: f64 = ests
            .iter()
            .zip(&acts)
            .map(|(e, a)| (a - slope * e).powi(2))
            .sum();
        let r2 = 1.0 - ss_res / ss_tot.max(1e-18);
        t.row(vec![
            "fit".into(),
            format!("slope={slope:.3}"),
            format!("r2={r2:.4}"),
            String::new(),
        ]);
        out.push(t);
    }
    Ok(out)
}

// ------------------------------------------------------------ Fig 6 / 7

/// End-to-end accuracy–latency curves: 5 models × 3 datasets, baseline vs
/// ours, on one device. Fig 6 = nano, Fig 7/14 = agx.
pub fn fig6(profile: DeviceProfile, q: Quality) -> anyhow::Result<Vec<Table>> {
    let mut curves = Table::new(
        &format!(
            "Fig {}: accuracy-latency curves ({})",
            if profile.name == "nano" { "6" } else { "7/14" },
            profile.name
        ),
        &[
            "model", "dataset", "policy", "sparsity", "accuracy", "io_ms", "ci_lo", "ci_hi",
            "retained",
        ],
    );
    let mut speedups = Table::new(
        &format!("Fig {}: speedups at matched accuracy", if profile.name == "nano" { "6" } else { "7/14" }),
        &["model", "dataset", "avg_speedup", "max_speedup"],
    );
    let mut all_avg = Vec::new();
    let mut all_max: f64 = 0.0;
    for model in ModelSpec::paper_models() {
        let r = rig(model.clone(), profile.clone(), q)?;
        for ds in DatasetSpec::all() {
            let mut curves_by_policy = Vec::new();
            for policy in [IoPolicy::TopK, IoPolicy::Chunking] {
                let pts = r.run_curve(&policy, &ds, &SPARSITIES, q.frames)?;
                for p in &pts {
                    curves.row(vec![
                        model.name.clone(),
                        ds.name.clone(),
                        policy.label().into(),
                        format!("{:.1}", p.sparsity),
                        format!("{:.4}", p.accuracy),
                        format!("{:.1}", p.io_seconds * 1e3),
                        format!("{:.1}", p.io_ci.lo * 1e3),
                        format!("{:.1}", p.io_ci.hi * 1e3),
                        format!("{:.4}", p.retained),
                    ]);
                }
                curves_by_policy.push(pts);
            }
            let (avg, max) = speedup_at_matched_accuracy(&curves_by_policy[0], &curves_by_policy[1]);
            all_avg.push(avg);
            all_max = all_max.max(max);
            speedups.row(vec![
                model.name.clone(),
                ds.name.clone(),
                format!("{avg:.2}x"),
                format!("{max:.2}x"),
            ]);
        }
    }
    speedups.row(vec![
        "OVERALL".into(),
        String::new(),
        format!("{:.2}x", stats::mean(&all_avg)),
        format!("{all_max:.2}x"),
    ]);
    Ok(vec![curves, speedups])
}

// ---------------------------------------------------------------- Fig 8

/// Latency breakdown at ~5% accuracy drop: real engine, dense vs baseline
/// vs ours (runnable `small` model; compute is measured stage-executor
/// wall time — XLA under `--features pjrt`, the host reference executor
/// in default builds).
pub fn fig8(artifact_dir: &std::path::Path, q: Quality) -> anyhow::Result<Vec<Table>> {
    use crate::coordinator::{Engine, Policy};
    let mut t = Table::new(
        "Fig 8: latency breakdown per frame (runnable 'small' model, nano profile)",
        &["policy", "io_ms", "compute_ms", "select_ms", "host_ms", "e2e_ms", "bytes_mb", "retained"],
    );
    let sat_kb = DeviceProfile::nano().saturation_bytes(0.99) as f64 / 1024.0;
    let cases = [
        ("dense", Policy::Dense, 0.0),
        ("baseline(topk)", Policy::TopK, 0.5),
        (
            "ours(chunking)",
            Policy::Chunking {
                config: crate::sparsify::ChunkSelectConfig::new(2.0, 2.0, sat_kb),
            },
            0.5,
        ),
    ];
    for (label, policy, sparsity) in cases {
        let eng = Engine::builder("small")
            .policy(policy)
            .sparsity(sparsity)
            .artifacts(artifact_dir)
            .build()?;
        let session = eng.new_session();
        let trace = crate::workload::FrameTrace::new(
            eng.spec().d,
            eng.spec().tokens_per_frame,
            q.frames,
            9,
        );
        // Warm one frame (compile), then measure.
        session.append_frame(&trace.frame(0))?;
        let mut io = Vec::new();
        let mut comp = Vec::new();
        let mut sel = Vec::new();
        let mut host = Vec::new();
        let mut bytes = 0u64;
        let mut retained = Vec::new();
        for f in 1..=q.frames {
            let (_, s) = session.append_frame(&trace.frame(f))?;
            io.push(s.io.as_secs_f64() * 1e3);
            comp.push(s.compute.as_secs_f64() * 1e3);
            sel.push(s.select.as_secs_f64() * 1e3);
            host.push(s.host.as_secs_f64() * 1e3);
            bytes += s.bytes_loaded;
            retained.push(s.retained_fraction());
        }
        let (io, comp, sel, host) = (
            stats::median(&io),
            stats::median(&comp),
            stats::median(&sel),
            stats::median(&host),
        );
        t.row(vec![
            label.into(),
            format!("{io:.2}"),
            format!("{comp:.2}"),
            format!("{sel:.3}"),
            format!("{host:.2}"),
            format!("{:.2}", io + comp + sel + host),
            format!("{:.1}", bytes as f64 / q.frames as f64 / 1e6),
            format!("{:.3}", stats::mean(&retained)),
        ]);
    }
    Ok(vec![t])
}

// ---------------------------------------------------------------- Fig 9

/// Ablation: baseline → +reorder → +reorder+chunking (llava-7b, nano).
pub fn fig9(q: Quality) -> anyhow::Result<Vec<Table>> {
    let r = rig(ModelSpec::llava_7b(), DeviceProfile::nano(), q)?;
    let ds = DatasetSpec::tempcompass();
    let mut t = Table::new(
        "Fig 9: ablation (llava-7b, nano, tempcompass)",
        &["variant", "sparsity", "accuracy", "io_ms"],
    );
    let mut curves = Vec::new();
    for policy in [
        IoPolicy::TopK,
        IoPolicy::TopKReordered,
        IoPolicy::Chunking,
    ] {
        let pts = r.run_curve(&policy, &ds, &SPARSITIES, q.frames)?;
        for p in &pts {
            t.row(vec![
                policy.label().into(),
                format!("{:.1}", p.sparsity),
                format!("{:.4}", p.accuracy),
                format!("{:.1}", p.io_seconds * 1e3),
            ]);
        }
        curves.push(pts);
    }
    let mut s = Table::new(
        "Fig 9: incremental speedups at matched accuracy",
        &["comparison", "avg_speedup", "max_speedup"],
    );
    let (a1, m1) = speedup_at_matched_accuracy(&curves[0], &curves[1]);
    let (a2, m2) = speedup_at_matched_accuracy(&curves[0], &curves[2]);
    s.row(vec!["+reorder vs baseline".into(), format!("{a1:.2}x"), format!("{m1:.2}x")]);
    s.row(vec![
        "+reorder+chunking vs baseline".into(),
        format!("{a2:.2}x"),
        format!("{m2:.2}x"),
    ]);
    Ok(vec![t, s])
}

// ------------------------------------------------------- Fig 10 / Fig 15

/// Mask patterns + contiguity distributions across layers and matrix
/// kinds, for the three variants (Fig 10 is the layer-0/q case study;
/// Fig 15 is the full grid).
pub fn fig10(q: Quality) -> anyhow::Result<Vec<Table>> {
    let r = rig(ModelSpec::llava_7b(), DeviceProfile::nano(), q)?;
    let mut t = Table::new(
        "Fig 10/15: contiguity by variant, layer and matrix (sparsity 0.4)",
        &["layer", "matrix", "variant", "num_chunks", "mean_chunk", "mode_chunk"],
    );
    let budgets = r.budgets(0.4);
    for ls in &r.layers {
        for kind in MatrixKind::SCORED {
            for policy in [IoPolicy::TopK, IoPolicy::TopKReordered, IoPolicy::Chunking] {
                // Average over frames.
                let mut chunks_n = Vec::new();
                let mut means = Vec::new();
                let mut modes = Vec::new();
                for f in 0..q.frames as u64 {
                    let fio = r.frame_layer_io(&policy, ls.layer, 900 + f, &budgets)?;
                    let d = ContiguityDistribution::from_chunks(&fio.masks[&kind].chunks);
                    chunks_n.push(d.num_chunks() as f64);
                    means.push(d.mean_chunk());
                    modes.push(d.mode_chunk() as f64);
                }
                t.row(vec![
                    format!("{}", ls.layer),
                    kind.name().into(),
                    policy.label().into(),
                    format!("{:.0}", stats::mean(&chunks_n)),
                    format!("{:.1}", stats::mean(&means)),
                    format!("{:.0}", stats::mean(&modes)),
                ]);
            }
        }
    }
    Ok(vec![t])
}

// --------------------------------------------------------------- Fig 11

/// Neuron activation frequency analysis (hot/cold fractions per layer ×
/// matrix at 40% effective sparsity).
pub fn fig11(q: Quality) -> anyhow::Result<Vec<Table>> {
    let r = rig(ModelSpec::llava_7b(), DeviceProfile::nano(), q)?;
    let mut t = Table::new(
        "Fig 11: activation frequency structure (llava-7b)",
        &["layer", "matrix", "hot_pct", "cold_pct", "mid_pct", "freq_cv"],
    );
    for ls in &r.layers {
        for kind in MatrixKind::SCORED {
            let gen = r.gen(ls.layer, kind);
            let rows = r.spec.shape_of(kind).rows;
            let samples = gen.samples(q.calib.max(16), 5000);
            let freq = crate::reorder::activation_frequency(&samples, rows);
            let (hot, cold) = crate::reorder::hot_cold_fractions(&freq);
            t.row(vec![
                format!("{}", ls.layer),
                kind.name().into(),
                format!("{:.1}", hot * 100.0),
                format!("{:.1}", cold * 100.0),
                format!("{:.1}", (1.0 - hot - cold) * 100.0),
                format!("{:.2}", stats::cv(&freq)),
            ]);
        }
    }
    Ok(vec![t])
}

// --------------------------------------------------------------- Fig 12

/// Contiguity CDF of top-k selections: original vs hot–cold vs
/// co-activation (Ripple-like) reordering, sparsity 0.4.
pub fn fig12(q: Quality) -> anyhow::Result<Vec<Table>> {
    let spec = ModelSpec::llava_05b();
    let mut t = Table::new(
        "Fig 12: rows-weighted contiguity CDF after reordering (llava-0.5b, sparsity 0.4)",
        &["matrix", "chunk_size<=", "original", "hotcold", "coactivation"],
    );
    for kind in [MatrixKind::Q, MatrixKind::Down] {
        let rows = spec.shape_of(kind).rows;
        let gen = ActivationGen::vlm(rows, spec.tokens_per_frame, 0.3, 21);
        let calib = gen.samples(q.calib.max(12), 0);
        let hotcold = crate::reorder::HotColdReorder.build(&calib, rows);
        let coact = CoActivationReorder::default().build(
            &calib[..calib.len().min(12)],
            rows,
        );
        let budget = (rows as f64 * 0.6) as usize;
        let table = crate::latency::LatencyTable::new(1024, vec![1e-4; 64], 1024);
        // Average CDFs over frames.
        let mut dists: [Vec<ContiguityDistribution>; 3] = Default::default();
        for f in 0..q.frames as u64 {
            let imp = gen.sample(10_000 + f);
            for (i, sel_imp) in [
                imp.clone(),
                hotcold.apply(&imp),
                coact.apply(&imp),
            ]
            .into_iter()
            .enumerate()
            {
                let sel = TopK.select(&sel_imp, budget, &table);
                dists[i].push(ContiguityDistribution::from_chunks(&sel.chunks));
            }
        }
        let cdf_at = |ds: &[ContiguityDistribution], size: usize| -> f64 {
            let vals: Vec<f64> = ds
                .iter()
                .map(|d| {
                    let total = d.num_rows().max(1) as f64;
                    let below: u64 = d
                        .iter()
                        .filter(|(s, _)| *s <= size)
                        .map(|(s, c)| s as u64 * c)
                        .sum();
                    below as f64 / total
                })
                .collect();
            stats::mean(&vals)
        };
        for size in [1usize, 2, 4, 8, 16, 32, 64] {
            t.row(vec![
                kind.name().into(),
                format!("{size}"),
                format!("{:.3}", cdf_at(&dists[0], size)),
                format!("{:.3}", cdf_at(&dists[1], size)),
                format!("{:.3}", cdf_at(&dists[2], size)),
            ]);
        }
    }
    Ok(vec![t])
}

// --------------------------------------------------------------- Fig 13

/// Hyperparameter sweep: selection runtime vs (start size, jump cap),
/// with the 2 ms feasibility gate, per device.
pub fn fig13(_q: Quality) -> anyhow::Result<Vec<Table>> {
    let mut out = Vec::new();
    for profile in [DeviceProfile::agx(), DeviceProfile::nano()] {
        let sat_kb = profile.saturation_bytes(0.99) as f64 / 1024.0;
        let probe = SimulatedSsd::timing_only(profile.clone(), 1 << 40, 3);
        let table = crate::storage::Profiler::new(
            &probe,
            crate::storage::ProfileConfig::coarse(profile.saturation_bytes(0.99), 1024),
        )
        .build_table()?;
        let mut t = Table::new(
            &format!("Fig 13: selection overhead sweep ({})", profile.name),
            &["shape", "start_kb", "jump_kb", "runtime_ms", "feasible(<=2ms)"],
        );
        // The two extreme shapes: largest (18944x3584) and a small one.
        for (rows, cols) in [(18944usize, 3584usize), (3584, 3584)] {
            let row_bytes = cols * 2;
            for start in [4.0f64, 8.0, 16.0, 32.0, 48.0] {
                for jump in [4.0f64, 8.0, 16.0, 32.0, 48.0] {
                    let cfg = crate::sparsify::ChunkSelectConfig::new(start, jump, sat_kb);
                    let rt = crate::sparsify::tuning::measure_runtime_ms(
                        cfg, rows, row_bytes, &table, 3, 7,
                    );
                    t.row(vec![
                        format!("{rows}x{cols}"),
                        format!("{start:.0}"),
                        format!("{jump:.0}"),
                        format!("{rt:.2}"),
                        (if rt <= 2.0 { "yes" } else { "NO" }).into(),
                    ]);
                }
            }
        }
        out.push(t);
    }
    Ok(out)
}

// --------------------------------------------------------------- Fig 16

/// Token-density sweep: accuracy–latency for 196/98/49 tokens per frame
/// (spatial pooling 1×/2×/4×), llava-7b on nano.
pub fn fig16(q: Quality) -> anyhow::Result<Vec<Table>> {
    let mut t = Table::new(
        "Fig 16: effect of visual-token density (llava-7b, nano, tempcompass)",
        &["tokens", "policy", "sparsity", "accuracy", "io_ms"],
    );
    let ds = DatasetSpec::tempcompass();
    let mut s = Table::new(
        "Fig 16: speedups at matched accuracy per density",
        &["tokens", "avg_speedup", "max_speedup"],
    );
    for tokens in [196usize, 98, 49] {
        let r = PaperRig::new(
            ModelSpec::llava_7b(),
            DeviceProfile::nano(),
            RigConfig {
                calib_samples: q.calib,
                tokens_per_frame: tokens,
                seed: 1,
            },
        )?;
        let mut curves = Vec::new();
        for policy in [IoPolicy::TopK, IoPolicy::Chunking] {
            let pts = r.run_curve(&policy, &ds, &SPARSITIES, q.frames)?;
            for p in &pts {
                // Token reduction also costs accuracy (pooled embeddings
                // lose detail): apply the paper's observed modest drop.
                let density_penalty = match tokens {
                    196 => 0.0,
                    98 => 0.012,
                    _ => 0.03,
                };
                t.row(vec![
                    format!("{tokens}"),
                    policy.label().into(),
                    format!("{:.1}", p.sparsity),
                    format!("{:.4}", p.accuracy - density_penalty),
                    format!("{:.1}", p.io_seconds * 1e3),
                ]);
            }
            curves.push(pts);
        }
        let (avg, max) = speedup_at_matched_accuracy(&curves[0], &curves[1]);
        s.row(vec![
            format!("{tokens}"),
            format!("{avg:.2}x"),
            format!("{max:.2}x"),
        ]);
    }
    Ok(vec![t, s])
}

// --------------------------------------------------------------- Table 1

/// CV of neuron importance before the down-projection across models
/// (first/mid/last layer) + ReLU baseline.
pub fn table1(q: Quality) -> anyhow::Result<Vec<Table>> {
    let mut t = Table::new(
        "Table 1: coefficient of variation of neuron importance (down-proj input)",
        &["layer", "llava-7b", "llava-0.5b", "vila-8b", "nvila-2b", "longva-7b", "opt-6.7b(relu)"],
    );
    let layer_rows = |spec: &ModelSpec| spec.shape_of(MatrixKind::Down).rows;
    let positions = [("first", 0.0), ("mid", 0.5), ("last", 1.0)];
    for (li, (lname, pos)) in positions.iter().enumerate() {
        let mut row = vec![lname.to_string()];
        for spec in ModelSpec::paper_models() {
            let gen = ActivationGen::vlm(
                layer_rows(&spec),
                spec.tokens_per_frame,
                *pos,
                100 + li as u64,
            );
            let cvs: Vec<f64> = (0..q.frames.max(4) as u64)
                .map(|i| {
                    let s = gen.sample(i);
                    stats::cv(&s.iter().map(|&x| x as f64).collect::<Vec<_>>())
                })
                .collect();
            row.push(format!("{:.2}", stats::mean(&cvs)));
        }
        // OPT-6.7B ReLU decode baseline (h = 16384 rows).
        let gen = ActivationGen::relu(16384, 300 + li as u64);
        let cvs: Vec<f64> = (0..q.frames.max(4) as u64)
            .map(|i| {
                let s = gen.sample(i);
                stats::cv(&s.iter().map(|&x| x as f64).collect::<Vec<_>>())
            })
            .collect();
        row.push(format!("{:.2}", stats::mean(&cvs)));
        t.row(row);
    }
    Ok(vec![t])
}

// --------------------------------------------------------------- Table 2

/// Published hyperparameters per matrix shape + measured runtime of our
/// selector at those settings (validating the 2 ms gate).
pub fn table2(_q: Quality) -> anyhow::Result<Vec<Table>> {
    let mut t = Table::new(
        "Table 2: chunk-selection hyperparameters per shape (paper) + measured runtime",
        &["shape", "agx_chunk", "agx_jump", "agx_ms", "nano_chunk", "nano_jump", "nano_ms"],
    );
    for (profile_agx, profile_nano) in [(DeviceProfile::agx(), DeviceProfile::nano())] {
        let mk_table = |p: &DeviceProfile| {
            let probe = SimulatedSsd::timing_only(p.clone(), 1 << 40, 3);
            crate::storage::Profiler::new(
                &probe,
                crate::storage::ProfileConfig::coarse(p.saturation_bytes(0.99), 1024),
            )
            .build_table()
            .unwrap()
        };
        let t_agx = mk_table(&profile_agx);
        let t_nano = mk_table(&profile_nano);
        for e in crate::sparsify::tuning::paper_table2() {
            let row_bytes = e.cols * 2;
            let sat_agx = profile_agx.saturation_bytes(0.99) as f64 / 1024.0;
            let sat_nano = profile_nano.saturation_bytes(0.99) as f64 / 1024.0;
            let rt_agx = crate::sparsify::tuning::measure_runtime_ms(
                crate::sparsify::ChunkSelectConfig::new(e.agx_chunk_kb, e.agx_jump_kb, sat_agx),
                e.rows,
                row_bytes,
                &t_agx,
                3,
                5,
            );
            let rt_nano = crate::sparsify::tuning::measure_runtime_ms(
                crate::sparsify::ChunkSelectConfig::new(e.nano_chunk_kb, e.nano_jump_kb, sat_nano),
                e.rows,
                row_bytes,
                &t_nano,
                3,
                5,
            );
            t.row(vec![
                format!("{}x{}", e.rows, e.cols),
                format!("{:.0}", e.agx_chunk_kb),
                format!("{:.0}", e.agx_jump_kb),
                format!("{rt_agx:.2}"),
                format!("{:.0}", e.nano_chunk_kb),
                format!("{:.0}", e.nano_jump_kb),
                format!("{rt_nano:.2}"),
            ]);
        }
    }
    Ok(vec![t])
}

// --------------------------------------------------------------- Table 3

/// Ours vs baseline and ours vs baseline+bundling (5 models × 3 datasets,
/// nano).
pub fn table3(q: Quality) -> anyhow::Result<Vec<Table>> {
    let mut t = Table::new(
        "Table 3: avg speedup of ours vs baseline / vs baseline+bundling (nano)",
        &["dataset", "llava-7b", "llava-0.5b", "vila-8b", "nvila-2b", "longva-7b"],
    );
    let mut per_ds: HashMap<String, Vec<String>> = HashMap::new();
    for model in ModelSpec::paper_models() {
        let r = rig(model.clone(), DeviceProfile::nano(), q)?;
        for ds in DatasetSpec::all() {
            let base = r.run_curve(&IoPolicy::TopK, &ds, &SPARSITIES, q.frames)?;
            let bundle = r.run_curve(&IoPolicy::Bundling, &ds, &SPARSITIES, q.frames)?;
            let ours = r.run_curve(&IoPolicy::Chunking, &ds, &SPARSITIES, q.frames)?;
            let (vs_base, _) = speedup_at_matched_accuracy(&base, &ours);
            let (vs_bundle, _) = speedup_at_matched_accuracy(&bundle, &ours);
            per_ds
                .entry(ds.name.clone())
                .or_default()
                .push(format!("{vs_base:.2}/{vs_bundle:.2}"));
        }
    }
    for ds in DatasetSpec::all() {
        let mut row = vec![ds.name.clone()];
        row.extend(per_ds[&ds.name].clone());
        t.row(row);
    }
    Ok(vec![t])
}

// ------------------------------------------------------------ Appendix N

/// Plain-LLM generalization: single-token (decode) smoothness, LLaMA3-8B
/// and Qwen2-7B shapes, importance–latency speedup at three layers.
pub fn appn(q: Quality) -> anyhow::Result<Vec<Table>> {
    let mut t = Table::new(
        "Appendix N: plain-LLM generalization (GSM8k-like decode, nano)",
        &["model", "layer", "avg_speedup_at_matched_importance"],
    );
    for (name, spec) in [
        ("llama3-8b", ModelSpec::vila_8b()),
        ("qwen2-7b", ModelSpec::llava_7b()),
    ] {
        // Single-token inference: much less smoothing (tokens=4 models the
        // mild multi-sample aggregation of batched decode).
        let r = PaperRig::new(
            spec,
            DeviceProfile::nano(),
            RigConfig {
                calib_samples: q.calib,
                tokens_per_frame: 4,
                seed: 2,
            },
        )?;
        let ds = DatasetSpec::tempcompass(); // proxy curve irrelevant here
        let base = r.run_curve(&IoPolicy::TopK, &ds, &SPARSITIES, q.frames)?;
        let ours = r.run_curve(&IoPolicy::Chunking, &ds, &SPARSITIES, q.frames)?;
        // Importance-based speedup (the paper's App-N proxy): match on
        // retained importance instead of accuracy.
        let remap = |pts: &[CurvePoint]| -> Vec<CurvePoint> {
            pts.iter()
                .map(|p| CurvePoint {
                    accuracy: p.retained,
                    ..*p
                })
                .collect()
        };
        let (avg, _) = speedup_at_matched_accuracy(&remap(&base), &remap(&ours));
        for ls in &r.layers {
            t.row(vec![
                name.into(),
                format!("{}", ls.layer),
                format!("{avg:.2}x"),
            ]);
        }
    }
    Ok(vec![t])
}

// ------------------------------------------------- real-model trade-off

/// Supplementary: the Fig-6 protocol on the *runnable* model with real
/// stage compute (XLA under `--features pjrt`, host reference executor by
/// default) — quality is measured, not proxied (cosine similarity of
/// output hidden states vs the dense model).
pub fn fig6_real(artifact_dir: &std::path::Path, q: Quality) -> anyhow::Result<Vec<Table>> {
    use crate::coordinator::{Engine, Policy};
    let mut t = Table::new(
        "Fig 6 (real compute): quality vs I/O on the runnable 'small' model (nano)",
        &["policy", "sparsity", "cosine_vs_dense", "io_ms", "e2e_ms"],
    );
    let frames = q.frames.min(4);
    let trace = crate::workload::FrameTrace::new(256, 16, frames + 1, 31);
    let dense_outs: Vec<Vec<f32>> = {
        let e = Engine::builder("small").artifacts(artifact_dir).build()?;
        let session = e.new_session();
        (0..frames)
            .map(|f| session.append_frame(&trace.frame(f)).map(|(y, _)| y))
            .collect::<anyhow::Result<_>>()?
    };
    let sat_kb = DeviceProfile::nano().saturation_bytes(0.99) as f64 / 1024.0;
    let cases: [(&str, Policy); 2] = [
        ("baseline", Policy::TopK),
        (
            "ours",
            Policy::Chunking {
                config: crate::sparsify::ChunkSelectConfig::new(2.0, 2.0, sat_kb),
            },
        ),
    ];
    for (label, policy) in cases {
        for sparsity in [0.0, 0.2, 0.4, 0.6] {
            let e = Engine::builder("small")
                .policy(policy.clone())
                .sparsity(sparsity)
                .artifacts(artifact_dir)
                .build()?;
            let session = e.new_session();
            let mut cos = Vec::new();
            let mut io = Vec::new();
            let mut e2e = Vec::new();
            for f in 0..frames {
                let (y, s) = session.append_frame(&trace.frame(f))?;
                let want = &dense_outs[f];
                let dot: f64 = y.iter().zip(want).map(|(a, b)| (a * b) as f64).sum();
                let na: f64 = y.iter().map(|&a| (a as f64).powi(2)).sum::<f64>().sqrt();
                let nb: f64 = want.iter().map(|&a| (a as f64).powi(2)).sum::<f64>().sqrt();
                cos.push(dot / (na * nb).max(1e-12));
                io.push(s.io.as_secs_f64() * 1e3);
                e2e.push(s.end_to_end().as_secs_f64() * 1e3);
            }
            t.row(vec![
                label.into(),
                format!("{sparsity:.1}"),
                format!("{:.4}", stats::mean(&cos)),
                format!("{:.2}", stats::median(&io)),
                format!("{:.2}", stats::median(&e2e)),
            ]);
        }
    }
    Ok(vec![t])
}

// ------------------------------------------ §5 discussion: emerging I/O

/// Discussion §5 ("Impact of Emerging I/O Mechanisms"): if io_uring-class
/// async I/O improved small/scattered reads (modeled as a higher host
/// IOPS ceiling + faster channel ramp), does chunking still pay off?
/// The paper predicts the gap narrows but structured access stays ahead.
pub fn disc_iouring(q: Quality) -> anyhow::Result<Vec<Table>> {
    let mut t = Table::new(
        "Discussion §5: speedup vs scattered-I/O capability (llava-7b, tempcompass)",
        &["device variant", "saturation_kb", "avg_speedup", "max_speedup"],
    );
    let ds = DatasetSpec::tempcompass();
    let base_profile = DeviceProfile::nano();
    for (label, ramp_scale, iops_scale) in [
        ("nano (paper-calibrated)", 1.0, 1.0),
        ("nano + io_uring-class (2x)", 0.5, 2.0),
        ("nano + aggressive async (4x)", 0.25, 4.0),
    ] {
        let mut p = base_profile.clone();
        p.chan_ramp *= ramp_scale;
        p.iops_ceiling *= iops_scale;
        p.name = "nano".into(); // keep Table-2 config lookups valid
        let r = PaperRig::new(
            ModelSpec::llava_7b(),
            p.clone(),
            RigConfig {
                calib_samples: q.calib,
                tokens_per_frame: 0,
                seed: 1,
            },
        )?;
        let base = r.run_curve(&IoPolicy::TopK, &ds, &SPARSITIES, q.frames)?;
        let ours = r.run_curve(&IoPolicy::Chunking, &ds, &SPARSITIES, q.frames)?;
        let (avg, max) = speedup_at_matched_accuracy(&base, &ours);
        t.row(vec![
            label.into(),
            format!("{}", p.saturation_bytes(0.99) / 1024),
            format!("{avg:.2}x"),
            format!("{max:.2}x"),
        ]);
    }
    Ok(vec![t])
}

// ----------------------------------------------------- device profile dump

/// Supplementary: calibrated device profiles (sanity context for all
/// storage figures).
pub fn devices(_q: Quality) -> anyhow::Result<Vec<Table>> {
    let mut t = Table::new(
        "Calibrated device profiles",
        &["device", "peak_bw", "iops_ceiling", "cmd_overhead", "saturation@99%"],
    );
    for p in [DeviceProfile::nano(), DeviceProfile::agx(), DeviceProfile::macbook()] {
        t.row(vec![
            p.name.clone(),
            fmt_bw(p.peak_bw),
            format!("{:.0}/s", p.iops_ceiling),
            fmt_secs(p.cmd_overhead),
            format!("{} KB", p.saturation_bytes(0.99) / 1024),
        ]);
    }
    Ok(vec![t])
}
