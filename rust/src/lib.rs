//! # neuron-chunking
//!
//! Production-style reproduction of **"VLM in a flash: I/O-Efficient
//! Sparsification of Vision-Language Model via Neuron Chunking"**.
//!
//! The crate is the Layer-3 (Rust) coordinator of a three-layer stack:
//!
//! * **L3 (this crate)** — the serving system: flash storage engine +
//!   simulator, chunk-based latency model, utility-guided chunk selection,
//!   hot–cold reordering, the [`plan`] I/O-planning layer (cross-matrix
//!   batching, extent merging, page alignment, latency-estimated
//!   [`ReadPlan`]s), the session-based serving engine with double-buffered
//!   next-layer prefetch, frame-append/decode scheduler, KV-cache manager,
//!   and the per-matrix sparsification pipeline. Nothing here ever calls
//!   Python at serving time.
//! * **L2 (python/compile/model.py)** — the VLM block compute graph in
//!   JAX, AOT-lowered to HLO text artifacts consumed by [`runtime`].
//! * **L1 (python/compile/kernels/)** — Pallas kernels (gathered matmul,
//!   fused SwiGLU gate/up, masked MHA) inside the L2 graph.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index mapping every paper table/figure to a module + bench target.

pub mod benchlib;
pub mod cache;
pub mod coordinator;
pub mod experiments;
pub mod latency;
pub mod model;
pub mod plan;
pub mod proptest;
pub mod reorder;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod serving;
pub mod sparsify;
pub mod stats;
pub mod storage;
pub mod workload;

pub use latency::{Chunk, ContiguityDistribution, LatencyTable};
pub use plan::{
    CoalescePolicy, DeviceSubPlan, FuseScratch, FusedCopy, FusedPlan, IoPlanner, PlanReceipt,
    PlanRequest, PlannedRead, ReadPlan, ShardedPlan,
};
pub use sparsify::{SelectionMask, Selector};
pub use storage::{
    DevicePool, DeviceProfile, FlashDevice, PoolStats, SimulatedSsd, StripeLayout, StripePolicy,
};
