//! Contiguity distribution: mask → multiset of maximal-run ("chunk")
//! sizes. E.g. selecting rows {1,2,4,6,7} yields chunks {1,2},{4},{6,7} —
//! one chunk of size 1 and two of size 2 (paper §3).

/// A maximal contiguous run of selected rows: rows `start .. start+len`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Chunk {
    pub start: usize,
    pub len: usize,
}

impl Chunk {
    pub fn new(start: usize, len: usize) -> Self {
        debug_assert!(len > 0);
        Self { start, len }
    }

    #[inline]
    pub fn end(&self) -> usize {
        self.start + self.len
    }

    #[inline]
    pub fn overlaps(&self, other: &Chunk) -> bool {
        self.start < other.end() && other.start < self.end()
    }
}

/// Extract maximal contiguous runs from a boolean selection mask.
pub fn chunks_from_mask(mask: &[bool]) -> Vec<Chunk> {
    let mut chunks = Vec::new();
    chunks_from_mask_into(mask, &mut chunks);
    chunks
}

/// Allocation-free variant of [`chunks_from_mask`]: clears `out` and
/// refills it, reusing its capacity (the serving hot path runs this per
/// matrix per token).
pub fn chunks_from_mask_into(mask: &[bool], out: &mut Vec<Chunk>) {
    out.clear();
    let mut i = 0;
    while i < mask.len() {
        if mask[i] {
            let start = i;
            while i < mask.len() && mask[i] {
                i += 1;
            }
            out.push(Chunk::new(start, i - start));
        } else {
            i += 1;
        }
    }
}

/// Frequency distribution of chunk sizes — the paper's compact
/// representation of a flash access pattern.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ContiguityDistribution {
    /// `counts[s]` = number of chunks of size `s` (index 0 unused).
    counts: Vec<u64>,
}

impl ContiguityDistribution {
    pub fn from_mask(mask: &[bool]) -> Self {
        Self::from_chunks(&chunks_from_mask(mask))
    }

    pub fn from_chunks(chunks: &[Chunk]) -> Self {
        let max = chunks.iter().map(|c| c.len).max().unwrap_or(0);
        let mut counts = vec![0u64; max + 1];
        for c in chunks {
            counts[c.len] += 1;
        }
        Self { counts }
    }

    /// Number of chunks of exactly size `s`.
    pub fn count(&self, s: usize) -> u64 {
        self.counts.get(s).copied().unwrap_or(0)
    }

    /// Total number of chunks.
    pub fn num_chunks(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Total number of selected rows.
    pub fn num_rows(&self) -> u64 {
        self.counts
            .iter()
            .enumerate()
            .map(|(s, &c)| s as u64 * c)
            .sum()
    }

    /// Mean chunk size (rows per chunk); NaN if empty.
    pub fn mean_chunk(&self) -> f64 {
        let n = self.num_chunks();
        if n == 0 {
            return f64::NAN;
        }
        self.num_rows() as f64 / n as f64
    }

    /// Most frequent chunk size (largest on ties); 0 if empty.
    pub fn mode_chunk(&self) -> usize {
        self.counts
            .iter()
            .enumerate()
            .skip(1)
            .filter(|(_, &c)| c > 0)
            .max_by(|a, b| a.1.cmp(b.1).then(a.0.cmp(&b.0)))
            .map(|(s, _)| s)
            .unwrap_or(0)
    }

    /// Largest observed chunk size.
    pub fn max_chunk(&self) -> usize {
        self.counts.len().saturating_sub(1)
    }

    /// Iterate (size, count) for sizes with nonzero count.
    pub fn iter(&self) -> impl Iterator<Item = (usize, u64)> + '_ {
        self.counts
            .iter()
            .enumerate()
            .skip(1)
            .filter(|(_, &c)| c > 0)
            .map(|(s, &c)| (s, c))
    }

    /// CDF over *rows* by chunk size: fraction of selected rows living in
    /// chunks of size <= s (Fig 12's contiguity CDF).
    pub fn row_cdf(&self) -> Vec<(usize, f64)> {
        let total = self.num_rows().max(1) as f64;
        let mut acc = 0u64;
        self.iter()
            .map(|(s, c)| {
                acc += s as u64 * c;
                (s, acc as f64 / total)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mask_of(indices: &[usize], n: usize) -> Vec<bool> {
        let mut m = vec![false; n];
        for &i in indices {
            m[i] = true;
        }
        m
    }

    #[test]
    fn paper_example() {
        // {1,2,4,6,7} -> chunks {1,2},{4},{6,7}: one size-1, two size-2.
        let mask = mask_of(&[1, 2, 4, 6, 7], 9);
        let chunks = chunks_from_mask(&mask);
        assert_eq!(
            chunks,
            vec![Chunk::new(1, 2), Chunk::new(4, 1), Chunk::new(6, 2)]
        );
        let d = ContiguityDistribution::from_mask(&mask);
        assert_eq!(d.count(1), 1);
        assert_eq!(d.count(2), 2);
        assert_eq!(d.num_chunks(), 3);
        assert_eq!(d.num_rows(), 5);
    }

    #[test]
    fn empty_mask() {
        let d = ContiguityDistribution::from_mask(&[false; 10]);
        assert_eq!(d.num_chunks(), 0);
        assert_eq!(d.num_rows(), 0);
        assert!(d.mean_chunk().is_nan());
        assert_eq!(d.mode_chunk(), 0);
    }

    #[test]
    fn full_mask_single_chunk() {
        let d = ContiguityDistribution::from_mask(&[true; 64]);
        assert_eq!(d.num_chunks(), 1);
        assert_eq!(d.count(64), 1);
        assert_eq!(d.mean_chunk(), 64.0);
        assert_eq!(d.mode_chunk(), 64);
    }

    #[test]
    fn alternating_mask_all_singletons() {
        let mask: Vec<bool> = (0..20).map(|i| i % 2 == 0).collect();
        let d = ContiguityDistribution::from_mask(&mask);
        assert_eq!(d.count(1), 10);
        assert_eq!(d.mean_chunk(), 1.0);
    }

    #[test]
    fn boundary_runs() {
        let mask = mask_of(&[0, 1, 8, 9], 10);
        let chunks = chunks_from_mask(&mask);
        assert_eq!(chunks, vec![Chunk::new(0, 2), Chunk::new(8, 2)]);
    }

    #[test]
    fn chunk_overlap_logic() {
        let a = Chunk::new(0, 4);
        assert!(a.overlaps(&Chunk::new(3, 2)));
        assert!(!a.overlaps(&Chunk::new(4, 2)));
        assert!(a.overlaps(&Chunk::new(0, 1)));
        assert!(Chunk::new(2, 10).overlaps(&a));
    }

    #[test]
    fn mode_prefers_larger_on_tie() {
        // one chunk of size 1 and one of size 3 -> tie in count; mode
        // should pick the larger size (matches visualization intent).
        let mask = mask_of(&[0, 2, 3, 4], 6);
        let d = ContiguityDistribution::from_mask(&mask);
        assert_eq!(d.mode_chunk(), 3);
    }

    #[test]
    fn row_cdf_monotone_ending_at_one() {
        let mask = mask_of(&[0, 1, 2, 5, 7, 8], 10);
        let d = ContiguityDistribution::from_mask(&mask);
        let cdf = d.row_cdf();
        assert!(cdf.windows(2).all(|w| w[0].1 <= w[1].1));
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn distribution_ignores_layout() {
        // Same chunk sizes at different positions -> identical distribution.
        let d1 = ContiguityDistribution::from_mask(&mask_of(&[0, 1, 5], 10));
        let d2 = ContiguityDistribution::from_mask(&mask_of(&[3, 7, 8], 10));
        assert_eq!(d1, d2);
    }
}
