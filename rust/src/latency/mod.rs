//! The paper's core abstraction: the **contiguity distribution** (§3) and
//! the **chunk-based latency model** (§3.1).
//!
//! A selection mask over neuron rows is reduced to the multiset of its
//! maximal contiguous run lengths ("chunks"), discarding spatial layout.
//! Total flash-read latency is then estimated as `Σ T[sᵢ]` where `T[s]` is
//! an offline-profiled per-chunk-size latency lookup table.

mod contiguity;
mod table;

pub use contiguity::{chunks_from_mask, chunks_from_mask_into, Chunk, ContiguityDistribution};
pub use table::LatencyTable;
