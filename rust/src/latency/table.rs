//! Chunk-size → latency lookup table (the paper's `T[s]`, §3.1).
//!
//! Built by [`crate::storage::Profiler`] via the Appendix-D microbenchmark
//! (throughput-saturating batches of equal-size chunks at fixed strides).
//! Estimates the latency of an arbitrary access pattern as the sum of its
//! chunks' table entries, interpolating between profiled sizes.

use crate::latency::{Chunk, ContiguityDistribution};

/// Per-chunk-size latency lookup table, keyed in bytes.
#[derive(Clone, Debug)]
pub struct LatencyTable {
    /// Profiling granularity in bytes (paper: 1 KB increments).
    step_bytes: usize,
    /// `entries[i]` = per-chunk latency in seconds for size `(i+1)*step`.
    entries: Vec<f64>,
    /// Bytes per neuron row (converts row chunks -> byte sizes).
    row_bytes: usize,
}

impl LatencyTable {
    pub fn new(step_bytes: usize, entries: Vec<f64>, row_bytes: usize) -> Self {
        assert!(step_bytes > 0 && !entries.is_empty() && row_bytes > 0);
        Self {
            step_bytes,
            entries,
            row_bytes,
        }
    }

    /// Re-key the table for a different row size (same device profile).
    pub fn with_row_bytes(&self, row_bytes: usize) -> Self {
        Self {
            step_bytes: self.step_bytes,
            entries: self.entries.clone(),
            row_bytes,
        }
    }

    /// Pool-effective table over heterogeneous members: the expected
    /// `T[s]` for a chunk whose placement follows the stripe shares
    /// (`weights`, e.g. per-member byte shares). Entries are the
    /// weighted mean of the member tables' (interpolated/extrapolated)
    /// latencies on a common grid — the smallest member step up to the
    /// largest member range. Selection utility uses this, so chunk
    /// selection prices a fast+slow pool between its extremes; exact
    /// per-member tables still price each sharded sub-plan.
    pub fn blended(tables: &[LatencyTable], weights: &[u64]) -> LatencyTable {
        assert!(!tables.is_empty() && tables.len() == weights.len());
        let step = tables.iter().map(|t| t.step_bytes()).min().unwrap();
        let max = tables.iter().map(|t| t.max_bytes()).max().unwrap();
        let total: u64 = weights.iter().sum();
        let n = (max / step).max(1);
        let entries: Vec<f64> = (1..=n)
            .map(|i| {
                let b = i * step;
                tables
                    .iter()
                    .zip(weights)
                    .map(|(t, &w)| {
                        let w = if total > 0 {
                            w as f64 / total as f64
                        } else {
                            1.0 / tables.len() as f64
                        };
                        w * t.latency_bytes(b)
                    })
                    .sum()
            })
            .collect();
        LatencyTable::new(step, entries, tables[0].row_bytes())
    }

    pub fn row_bytes(&self) -> usize {
        self.row_bytes
    }

    pub fn step_bytes(&self) -> usize {
        self.step_bytes
    }

    /// Largest profiled size in bytes (the throughput-saturation point).
    pub fn max_bytes(&self) -> usize {
        self.step_bytes * self.entries.len()
    }

    /// Latency (seconds) of one contiguous read of `bytes`, linearly
    /// interpolated between profiled sizes; beyond the profiled range the
    /// marginal cost is extrapolated at the saturated per-byte rate
    /// (bandwidth-bound regime — the defining property of saturation).
    pub fn latency_bytes(&self, bytes: usize) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        let step = self.step_bytes as f64;
        let max = self.max_bytes();
        if bytes >= max {
            let last = *self.entries.last().unwrap();
            let per_byte = last / max as f64;
            return last + per_byte * (bytes - max) as f64;
        }
        // Position between entries (entry i covers size (i+1)*step).
        let pos = bytes as f64 / step;
        if pos <= 1.0 {
            // Below the first profiled size: scale the first entry's
            // per-byte cost but keep the fixed floor dominated shape by
            // linear interpolation from (0, e0*frac0)... use e0 scaled by
            // size is wrong for overhead-bound reads; clamp to e0 * mix.
            let e0 = self.entries[0];
            // Overhead-bound: latency barely drops below the 1-step entry.
            return e0 * (0.5 + 0.5 * pos);
        }
        let lo = (pos.floor() as usize - 1).min(self.entries.len() - 2);
        let frac = pos - (lo + 1) as f64;
        self.entries[lo] * (1.0 - frac) + self.entries[lo + 1] * frac
    }

    /// Latency of a chunk of `rows` neuron rows.
    pub fn latency_rows(&self, rows: usize) -> f64 {
        self.latency_bytes(rows * self.row_bytes)
    }

    /// Paper §3.1: `L_total = Σ T[sᵢ]` over the pattern's chunks.
    pub fn estimate_chunks(&self, chunks: &[Chunk]) -> f64 {
        chunks.iter().map(|c| self.latency_rows(c.len)).sum()
    }

    /// Cache-aware `L_total`: chunks are priced after subtracting rows
    /// resident in a RAM cache (`resident[r]` = physical row `r` is
    /// cached), so cached spans carry (near-)zero estimated latency and
    /// only the miss runs pay the table. This is the pricing view of the
    /// shared [`crate::cache::ChunkCache`]: zeroing a resident row's
    /// importance before selection (what `NC_CACHE_PRICING=1` does) is
    /// equivalent to giving it zero latency in the §3.1 utility — both
    /// make selection treat residency as free. Rows past `resident.len()`
    /// are treated as misses.
    pub fn estimate_chunks_with_resident(&self, chunks: &[Chunk], resident: &[bool]) -> f64 {
        let mut total = 0.0;
        for c in chunks {
            let mut run = 0usize;
            for r in c.start..c.end() {
                if resident.get(r).copied().unwrap_or(false) {
                    total += self.latency_rows(run);
                    run = 0;
                } else {
                    run += 1;
                }
            }
            total += self.latency_rows(run);
        }
        total
    }

    pub fn estimate_mask(&self, mask: &[bool]) -> f64 {
        self.estimate_chunks(&crate::latency::chunks_from_mask(mask))
    }

    pub fn estimate_dist(&self, dist: &ContiguityDistribution) -> f64 {
        dist.iter()
            .map(|(s, c)| self.latency_rows(s) * c as f64)
            .sum()
    }

    /// Effective throughput (bytes/s) for uniform chunks of `bytes`.
    pub fn throughput_at(&self, bytes: usize) -> f64 {
        let l = self.latency_bytes(bytes);
        if l <= 0.0 {
            f64::INFINITY
        } else {
            bytes as f64 / l
        }
    }

    /// Smallest profiled size reaching `frac` (e.g. 0.99) of the peak
    /// profiled throughput — the paper's saturation point / max chunk size
    /// for candidate generation (§3.2.2).
    pub fn saturation_bytes(&self, frac: f64) -> usize {
        let peak = (1..=self.entries.len())
            .map(|i| self.throughput_at(i * self.step_bytes))
            .fold(0.0f64, f64::max);
        for i in 1..=self.entries.len() {
            let s = i * self.step_bytes;
            if self.throughput_at(s) >= frac * peak {
                return s;
            }
        }
        self.max_bytes()
    }

    /// Serialize to a simple text format (offline env has no serde).
    pub fn to_text(&self) -> String {
        let mut s = format!(
            "latency_table v1\nstep_bytes {}\nrow_bytes {}\n",
            self.step_bytes, self.row_bytes
        );
        for e in &self.entries {
            s.push_str(&format!("{e:.12e}\n"));
        }
        s
    }

    pub fn from_text(text: &str) -> anyhow::Result<Self> {
        let mut lines = text.lines();
        let header = lines.next().unwrap_or_default();
        anyhow::ensure!(header == "latency_table v1", "bad header: {header}");
        let parse_kv = |line: &str, key: &str| -> anyhow::Result<usize> {
            let (k, v) = line
                .split_once(' ')
                .ok_or_else(|| anyhow::anyhow!("bad line: {line}"))?;
            anyhow::ensure!(k == key, "expected {key}, got {k}");
            Ok(v.parse()?)
        };
        let step_bytes = parse_kv(lines.next().unwrap_or_default(), "step_bytes")?;
        let row_bytes = parse_kv(lines.next().unwrap_or_default(), "row_bytes")?;
        let entries: Vec<f64> = lines
            .filter(|l| !l.is_empty())
            .map(|l| l.parse::<f64>())
            .collect::<Result<_, _>>()?;
        anyhow::ensure!(!entries.is_empty(), "no entries");
        Ok(Self::new(step_bytes, entries, row_bytes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic table: latency = 50us + bytes/(1 GB/s), 1 KB steps to 64 KB.
    fn table() -> LatencyTable {
        let step = 1024;
        let entries = (1..=64)
            .map(|i| 50e-6 + (i * step) as f64 / 1e9)
            .collect();
        LatencyTable::new(step, entries, 1024)
    }

    #[test]
    fn exact_at_profiled_sizes() {
        let t = table();
        for i in [1usize, 2, 10, 64] {
            let expect = 50e-6 + (i * 1024) as f64 / 1e9;
            assert!((t.latency_bytes(i * 1024) - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn interpolates_between_sizes() {
        let t = table();
        let l = t.latency_bytes(1536); // halfway 1 KB..2 KB
        let lo = t.latency_bytes(1024);
        let hi = t.latency_bytes(2048);
        assert!(lo < l && l < hi);
        assert!((l - (lo + hi) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn extrapolates_at_saturated_rate() {
        let t = table();
        let at_max = t.latency_bytes(64 * 1024);
        let beyond = t.latency_bytes(128 * 1024);
        assert!(beyond > at_max);
        // Marginal cost equals saturated per-byte cost.
        let per_byte = at_max / (64.0 * 1024.0);
        assert!((beyond - (at_max + per_byte * 64.0 * 1024.0)).abs() < 1e-9);
    }

    #[test]
    fn zero_bytes_zero_latency() {
        assert_eq!(table().latency_bytes(0), 0.0);
    }

    #[test]
    fn additive_over_chunks_matches_paper_model() {
        let t = table();
        let chunks = vec![Chunk::new(0, 2), Chunk::new(5, 1), Chunk::new(9, 2)];
        let want =
            2.0 * t.latency_rows(2) + t.latency_rows(1);
        assert!((t.estimate_chunks(&chunks) - want).abs() < 1e-12);
    }

    #[test]
    fn resident_rows_price_as_free_and_split_runs() {
        let t = table();
        let chunks = vec![Chunk::new(0, 4), Chunk::new(8, 2)];
        // No residency: identical to the plain estimate.
        let none = vec![false; 16];
        assert!(
            (t.estimate_chunks_with_resident(&chunks, &none) - t.estimate_chunks(&chunks)).abs()
                < 1e-15
        );
        // Everything resident: free.
        let all = vec![true; 16];
        assert_eq!(t.estimate_chunks_with_resident(&chunks, &all), 0.0);
        // Resident row 1 splits the 4-run into 1 + 2; chunk at 8 unsplit.
        let mut some = vec![false; 16];
        some[1] = true;
        let want = t.latency_rows(1) + t.latency_rows(2) + t.latency_rows(2);
        assert!((t.estimate_chunks_with_resident(&chunks, &some) - want).abs() < 1e-15);
        // Residency never makes a pattern more expensive (fewer/shorter
        // miss runs under an overhead-bearing table).
        assert!(t.estimate_chunks_with_resident(&chunks, &some) <= t.estimate_chunks(&chunks));
        // Rows beyond the residency slice are misses, not panics.
        let short = vec![true; 2];
        let priced = t.estimate_chunks_with_resident(&chunks, &short);
        assert!(priced > 0.0 && priced < t.estimate_chunks(&chunks));
    }

    #[test]
    fn mask_and_dist_estimates_agree() {
        let t = table();
        let mask = [true, true, false, true, false, true, true, true];
        let d = ContiguityDistribution::from_mask(&mask);
        assert!((t.estimate_mask(&mask) - t.estimate_dist(&d)).abs() < 1e-12);
    }

    #[test]
    fn fragmentation_costs_more() {
        // Same row count, scattered vs contiguous: scattered must cost more
        // under any overhead-bearing table (the paper's Fig 4b effect).
        let t = table();
        let contiguous = [true; 16];
        let scattered: Vec<bool> = (0..32).map(|i| i % 2 == 0).collect();
        assert!(t.estimate_mask(&scattered) > t.estimate_mask(&contiguous));
    }

    #[test]
    fn throughput_monotone_in_chunk_size() {
        let t = table();
        let mut prev = 0.0;
        for i in 1..=64 {
            let tp = t.throughput_at(i * 1024);
            assert!(tp >= prev);
            prev = tp;
        }
    }

    #[test]
    fn saturation_point_detected() {
        let t = table();
        let sat = t.saturation_bytes(0.99);
        // With 50us overhead + 1GB/s, 99% of peak(64KB tput) requires
        // a large chunk; must be within the profiled range and > 1 KB.
        assert!(sat > 1024 && sat <= 64 * 1024);
        // Throughput there really is >= 99% of the peak.
        let peak = t.throughput_at(64 * 1024);
        assert!(t.throughput_at(sat) >= 0.99 * peak);
    }

    #[test]
    fn text_round_trip() {
        let t = table();
        let text = t.to_text();
        let t2 = LatencyTable::from_text(&text).unwrap();
        assert_eq!(t.step_bytes(), t2.step_bytes());
        assert_eq!(t.row_bytes(), t2.row_bytes());
        for b in [512usize, 1024, 5000, 65536, 100000] {
            assert!((t.latency_bytes(b) - t2.latency_bytes(b)).abs() < 1e-15);
        }
    }

    #[test]
    fn from_text_rejects_garbage() {
        assert!(LatencyTable::from_text("nope").is_err());
        assert!(LatencyTable::from_text("latency_table v1\nstep_bytes 0").is_err());
    }

    #[test]
    fn blended_table_sits_between_members() {
        let fast = table(); // 50us + 1 GB/s
        let slow = LatencyTable::new(
            1024,
            (1..=64)
                .map(|i| 100e-6 + (i * 1024) as f64 / 0.5e9)
                .collect(),
            1024,
        );
        let mix = LatencyTable::blended(&[fast.clone(), slow.clone()], &[1, 1]);
        for b in [1024usize, 8192, 65536] {
            let l = mix.latency_bytes(b);
            assert!(l >= fast.latency_bytes(b) * 0.999, "mix below fast at {b}");
            assert!(l <= slow.latency_bytes(b) * 1.001, "mix above slow at {b}");
        }
        // Homogeneous blend reproduces the member table (to float noise).
        let same = LatencyTable::blended(&[fast.clone(), fast.clone()], &[3, 1]);
        for b in [2048usize, 30000, 65536] {
            let (a, want) = (same.latency_bytes(b), fast.latency_bytes(b));
            assert!((a - want).abs() <= 1e-9 * want.abs(), "{a} vs {want}");
        }
    }

    #[test]
    fn rekey_row_bytes() {
        let t = table().with_row_bytes(2048);
        assert_eq!(t.row_bytes(), 2048);
        assert!((t.latency_rows(1) - t.latency_bytes(2048)).abs() < 1e-15);
    }
}
