//! Deterministic pseudo-random number generation (offline substrate for
//! the unavailable `rand`/`rand_distr` crates).
//!
//! `Rng` is xoshiro256++ seeded via SplitMix64 — fast, high-quality, and
//! fully reproducible across runs, which every simulator and workload
//! generator in this crate relies on (benchmarks must be re-runnable
//! bit-for-bit).

/// xoshiro256++ PRNG with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the full state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here
        // (bias < 2^-53 for the n values we use).
        (self.f64() * n as f64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal: exp(N(mu, sigma)).
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), sorted.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm.
        let mut chosen = std::collections::BTreeSet::new();
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.lognormal(0.0, 1.5) > 0.0);
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(11);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range(3, 6);
            assert!((3..=6).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 6;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(17);
        for _ in 0..100 {
            let s = r.sample_indices(100, 30);
            assert_eq!(s.len(), 30);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&i| i < 100));
        }
    }

    #[test]
    fn sample_indices_full() {
        let mut r = Rng::new(19);
        let s = r.sample_indices(10, 10);
        assert_eq!(s, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn bool_probability() {
        let mut r = Rng::new(23);
        let hits = (0..100_000).filter(|_| r.bool(0.3)).count();
        assert!((hits as f64 / 100_000.0 - 0.3).abs() < 0.01);
    }
}
