//! Conventional magnitude top-k sparsification — the paper's baseline
//! (§4.1, following TEAL/CATS/LLM-in-a-Flash): select the `R` rows with
//! the largest importance, ignoring storage layout entirely.

use crate::latency::LatencyTable;
use crate::sparsify::{SelectScratch, SelectionMask, Selector};

#[derive(Clone, Copy, Debug, Default)]
pub struct TopK;

impl Selector for TopK {
    fn name(&self) -> &str {
        "topk"
    }

    fn select(
        &self,
        importance: &[f32],
        budget: usize,
        table: &LatencyTable,
    ) -> SelectionMask {
        let mut scratch = SelectScratch::default();
        let mut out = SelectionMask::default();
        self.select_into(importance, budget, table, &mut scratch, &mut out);
        out
    }

    fn select_into(
        &self,
        importance: &[f32],
        budget: usize,
        _table: &LatencyTable,
        scratch: &mut SelectScratch,
        out: &mut SelectionMask,
    ) {
        let n = importance.len();
        let k = budget.min(n);
        if k == 0 {
            out.reset(n);
            return;
        }
        if k == n {
            out.set_full(n);
            return;
        }
        // Partial selection: select_nth_unstable on indices (O(n)
        // expected) keeps the hot path allocation-free (the index buffer
        // comes from the scratch arena).
        let idx = &mut scratch.idx;
        idx.clear();
        idx.extend(0..n as u32);
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            importance[b as usize].total_cmp(&importance[a as usize])
        });
        out.reset(n);
        for &i in &idx[..k] {
            out.mask[i as usize] = true;
        }
        out.recompute_chunks();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> LatencyTable {
        LatencyTable::new(1024, vec![50e-6, 51e-6, 52e-6, 53e-6], 1024)
    }

    #[test]
    fn selects_largest() {
        let imp = [0.1f32, 5.0, 0.2, 4.0, 3.0];
        let sm = TopK.select(&imp, 3, &table());
        assert_eq!(sm.indices(), vec![1, 3, 4]);
    }

    #[test]
    fn budget_zero_and_full() {
        let imp = [1.0f32; 8];
        assert_eq!(TopK.select(&imp, 0, &table()).rows(), 0);
        assert_eq!(TopK.select(&imp, 8, &table()).rows(), 8);
        assert_eq!(TopK.select(&imp, 99, &table()).rows(), 8);
    }

    #[test]
    fn exact_budget() {
        let imp: Vec<f32> = (0..100).map(|i| (i as f32 * 37.0) % 11.0).collect();
        for k in [1usize, 5, 50, 99] {
            assert_eq!(TopK.select(&imp, k, &table()).rows(), k);
        }
    }

    #[test]
    fn captured_importance_is_maximal() {
        // No other k-subset captures more importance than top-k.
        let imp = [3.0f32, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let sm = TopK.select(&imp, 4, &table());
        let mut sorted = imp.to_vec();
        sorted.sort_by(|a, b| b.total_cmp(a));
        let best: f64 = sorted[..4].iter().map(|&v| v as f64).sum();
        assert!((sm.captured_importance(&imp) - best).abs() < 1e-6);
    }

    #[test]
    fn scattered_importance_fragments() {
        // Alternating importance -> top-k picks every other row: worst-case
        // contiguity (the phenomenon motivating the paper).
        let imp: Vec<f32> = (0..64)
            .map(|i| if i % 2 == 0 { 1.0 } else { 0.0 })
            .collect();
        let sm = TopK.select(&imp, 32, &table());
        assert_eq!(sm.chunks.len(), 32);
        assert!(sm.chunks.iter().all(|c| c.len == 1));
    }
}
