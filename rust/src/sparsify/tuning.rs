//! Hyperparameter selection for chunk selection (Appendix H, Fig 13 +
//! Table 2).
//!
//! The paper sweeps (chunk_sz_start_in_kb, jump_cap_in_kb) per weight-
//! matrix shape, rejects configurations whose selection runtime exceeds
//! 2 ms, and picks from the feasible lower-left (fine-grained) region.
//! [`sweep`] reproduces that procedure against our selector; [`paper_table2`]
//! records the paper's published picks for the paper-model shapes.

use std::time::Instant;

use crate::latency::LatencyTable;
use crate::rng::Rng;
use crate::sparsify::{ChunkSelect, ChunkSelectConfig, Selector};

/// The paper's 2 ms per-matrix runtime gate.
pub const RUNTIME_GATE_MS: f64 = 2.0;

/// One sweep measurement point.
#[derive(Clone, Copy, Debug)]
pub struct SweepPoint {
    pub start_kb: f64,
    pub jump_cap_kb: f64,
    pub runtime_ms: f64,
    pub feasible: bool,
}

/// Paper Table 2 entry: chosen hyperparameters per matrix shape/device.
#[derive(Clone, Copy, Debug)]
pub struct Table2Entry {
    pub rows: usize,
    pub cols: usize,
    pub agx_chunk_kb: f64,
    pub agx_jump_kb: f64,
    pub nano_chunk_kb: f64,
    pub nano_jump_kb: f64,
}

/// The paper's published per-shape hyperparameters (Appendix H, Table 2).
pub fn paper_table2() -> Vec<Table2Entry> {
    let e = |rows, cols, ac, aj, nc, nj| Table2Entry {
        rows,
        cols,
        agx_chunk_kb: ac,
        agx_jump_kb: aj,
        nano_chunk_kb: nc,
        nano_jump_kb: nj,
    };
    vec![
        e(3584, 3584, 20.0, 20.0, 24.0, 36.0),
        e(8960, 1536, 16.0, 16.0, 20.0, 20.0),
        e(896, 4864, 8.0, 8.0, 8.0, 8.0),
        e(4096, 1024, 12.0, 12.0, 16.0, 16.0),
        e(3584, 18944, 8.0, 8.0, 8.0, 8.0),
        e(4096, 4096, 20.0, 20.0, 24.0, 24.0),
        e(18944, 3584, 32.0, 32.0, 36.0, 36.0),
        e(1536, 1536, 16.0, 12.0, 16.0, 12.0),
        e(1536, 256, 8.0, 8.0, 8.0, 8.0),
        e(896, 128, 8.0, 8.0, 8.0, 8.0),
        e(14336, 4096, 32.0, 32.0, 40.0, 36.0),
        e(4864, 896, 12.0, 16.0, 20.0, 16.0),
        e(3584, 512, 8.0, 12.0, 8.0, 12.0),
        e(896, 896, 8.0, 8.0, 8.0, 8.0),
        e(4096, 14336, 8.0, 8.0, 8.0, 8.0),
        e(1536, 8960, 8.0, 8.0, 8.0, 8.0),
    ]
}

/// Lookup the paper's chosen config for a shape on a device, if published.
pub fn paper_config_for(
    rows: usize,
    cols: usize,
    device: &str,
    saturation_kb: f64,
) -> Option<ChunkSelectConfig> {
    paper_table2()
        .into_iter()
        .find(|e| e.rows == rows && e.cols == cols)
        .map(|e| {
            let (c, j) = if device == "agx" {
                (e.agx_chunk_kb, e.agx_jump_kb)
            } else {
                (e.nano_chunk_kb, e.nano_jump_kb)
            };
            ChunkSelectConfig::new(c, j, saturation_kb)
        })
}

/// Measure selection runtime for one configuration on random importance
/// (valid per Appendix H: >80% of runtime is data-independent sorting).
pub fn measure_runtime_ms(
    config: ChunkSelectConfig,
    rows: usize,
    row_bytes: usize,
    table: &LatencyTable,
    trials: usize,
    seed: u64,
) -> f64 {
    let table = table.with_row_bytes(row_bytes);
    let selector = ChunkSelect::new(config);
    let mut rng = Rng::new(seed);
    let importance: Vec<f32> = (0..rows).map(|_| rng.f32()).collect();
    let budget = (rows as f64 * 0.9) as usize; // sparsity 0.1: worst case
    let mut times = Vec::with_capacity(trials);
    for _ in 0..trials {
        let t0 = Instant::now();
        let sm = selector.select(&importance, budget, &table);
        std::hint::black_box(sm.rows());
        times.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    crate::stats::median(&times)
}

/// Reproduce the Fig 13 sweep for one matrix shape: grid over start size
/// and jump cap (4 KB increments like the paper), mark 2 ms feasibility.
pub fn sweep(
    rows: usize,
    row_bytes: usize,
    table: &LatencyTable,
    saturation_kb: f64,
    grid_kb: &[f64],
    trials: usize,
) -> Vec<SweepPoint> {
    let mut out = Vec::new();
    for &start in grid_kb {
        for &jump in grid_kb {
            let cfg = ChunkSelectConfig::new(start, jump, saturation_kb);
            let rt = measure_runtime_ms(cfg, rows, row_bytes, table, trials, 7);
            out.push(SweepPoint {
                start_kb: start,
                jump_cap_kb: jump,
                runtime_ms: rt,
                feasible: rt <= RUNTIME_GATE_MS,
            });
        }
    }
    out
}

/// The paper's two-stage pick: among feasible points, prefer the
/// lower-left (small start, small jump = widest search coverage), with a
/// small safety margin from the infeasible boundary.
pub fn pick_config(points: &[SweepPoint], saturation_kb: f64) -> Option<ChunkSelectConfig> {
    points
        .iter()
        .filter(|p| p.feasible && p.runtime_ms <= 0.8 * RUNTIME_GATE_MS)
        .min_by(|a, b| {
            (a.start_kb + a.jump_cap_kb)
                .total_cmp(&(b.start_kb + b.jump_cap_kb))
                .then(a.runtime_ms.total_cmp(&b.runtime_ms))
        })
        .map(|p| ChunkSelectConfig::new(p.start_kb, p.jump_cap_kb, saturation_kb))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> LatencyTable {
        let entries = (1..=348).map(|i| (i as f64) * 0.29e-6 + 90e-6).collect();
        LatencyTable::new(1024, entries, 1024)
    }

    #[test]
    fn table2_has_all_16_shapes() {
        assert_eq!(paper_table2().len(), 16);
    }

    #[test]
    fn paper_config_lookup() {
        let c = paper_config_for(18944, 3584, "agx", 236.0).unwrap();
        assert_eq!(c.min_kb, 32.0);
        assert_eq!(c.jump_cap_kb, 32.0);
        let c = paper_config_for(18944, 3584, "nano", 348.0).unwrap();
        assert_eq!(c.min_kb, 36.0);
        assert!(paper_config_for(1, 1, "agx", 236.0).is_none());
    }

    #[test]
    fn runtime_measured_positive() {
        let rt = measure_runtime_ms(
            ChunkSelectConfig::new(8.0, 8.0, 64.0),
            2048,
            1024,
            &table(),
            3,
            1,
        );
        assert!(rt > 0.0 && rt < 1000.0);
    }

    #[test]
    fn coarser_configs_run_faster() {
        let t = table();
        let fine = measure_runtime_ms(
            ChunkSelectConfig::new(1.0, 1.0, 128.0),
            8192,
            1024,
            &t,
            3,
            2,
        );
        let coarse = measure_runtime_ms(
            ChunkSelectConfig::new(32.0, 32.0, 128.0),
            8192,
            1024,
            &t,
            3,
            2,
        );
        assert!(coarse < fine, "coarse {coarse} fine {fine}");
    }

    #[test]
    fn pick_prefers_lower_left_feasible() {
        let pts = vec![
            SweepPoint {
                start_kb: 4.0,
                jump_cap_kb: 4.0,
                runtime_ms: 3.0,
                feasible: false,
            },
            SweepPoint {
                start_kb: 8.0,
                jump_cap_kb: 8.0,
                runtime_ms: 1.2,
                feasible: true,
            },
            SweepPoint {
                start_kb: 16.0,
                jump_cap_kb: 16.0,
                runtime_ms: 0.4,
                feasible: true,
            },
        ];
        let c = pick_config(&pts, 236.0).unwrap();
        assert_eq!(c.min_kb, 8.0);
    }

    #[test]
    fn pick_none_when_all_infeasible() {
        let pts = vec![SweepPoint {
            start_kb: 4.0,
            jump_cap_kb: 4.0,
            runtime_ms: 5.0,
            feasible: false,
        }];
        assert!(pick_config(&pts, 236.0).is_none());
    }
}
