//! LLM-in-a-Flash row–column **bundling** baseline (Appendix L, Table 3).
//!
//! LLMFlash stores the weights touched by one neuron across projection
//! matrices adjacently (up-projection column + down-projection row), so a
//! selected neuron costs one contiguous read of `bundle_rows` rows.
//! Selection itself stays magnitude top-k over neurons. The result:
//! bundled reads have fixed, modest contiguity (~2 rows ≈ 74 KB on the
//! paper's models — about half the saturating chunk size on Jetson), and
//! neurons scattered by top-k stay scattered. The paper shows this helps
//! sometimes (LLaVA-0.5B) and hurts elsewhere — pattern-dependent, unlike
//! explicit contiguity optimization.

use crate::latency::{Chunk, LatencyTable};
use crate::sparsify::{SelectionMask, Selector};

#[derive(Clone, Copy, Debug)]
pub struct Bundling {
    /// Rows fused per neuron bundle (2 = up+down, 3 = q/k/v).
    pub bundle_rows: usize,
}

impl Bundling {
    pub fn new(bundle_rows: usize) -> Self {
        assert!(bundle_rows >= 1);
        Self { bundle_rows }
    }
}

impl Selector for Bundling {
    fn name(&self) -> &str {
        "bundling"
    }

    /// Interpret the row space as ⌈n/b⌉ bundles of `b` adjacent rows; rank
    /// bundles by summed importance; take whole bundles until the budget
    /// is filled.
    fn select(
        &self,
        importance: &[f32],
        budget: usize,
        _table: &LatencyTable,
    ) -> SelectionMask {
        let n = importance.len();
        let b = self.bundle_rows;
        let budget = budget.min(n);
        if budget == 0 || n == 0 {
            return SelectionMask::empty(n);
        }
        let nb = n.div_ceil(b);
        let mut scores: Vec<(f64, usize)> = (0..nb)
            .map(|i| {
                let lo = i * b;
                let hi = (lo + b).min(n);
                let s: f64 = importance[lo..hi].iter().map(|&v| v as f64).sum();
                (s, i)
            })
            .collect();
        scores.sort_unstable_by(|a, b| b.0.total_cmp(&a.0));
        let mut mask = vec![false; n];
        let mut selected = 0usize;
        for &(_, i) in &scores {
            let lo = i * b;
            let hi = (lo + b).min(n);
            let len = hi - lo;
            if selected + len > budget {
                continue;
            }
            mask[lo..hi].iter_mut().for_each(|m| *m = true);
            selected += len;
            if selected + 1 > budget {
                break;
            }
        }
        SelectionMask::from_mask(mask)
    }
}

/// Contiguity statistics of a bundled selection — helper for Table 3
/// analysis (bundled chunks have size >= bundle_rows unless merged).
pub fn min_chunk_rows(chunks: &[Chunk]) -> usize {
    chunks.iter().map(|c| c.len).min().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> LatencyTable {
        LatencyTable::new(1024, vec![50e-6, 51e-6, 52e-6, 53e-6], 1024)
    }

    #[test]
    fn selects_whole_bundles() {
        let imp = [9.0f32, 9.0, 0.1, 0.1, 5.0, 5.0, 0.2, 0.2];
        let sm = Bundling::new(2).select(&imp, 4, &table());
        assert_eq!(sm.indices(), vec![0, 1, 4, 5]);
        assert!(min_chunk_rows(&sm.chunks) >= 2);
    }

    #[test]
    fn respects_budget_with_whole_bundles_only() {
        let imp = [1.0f32; 10];
        let sm = Bundling::new(3).select(&imp, 7, &table());
        // 3-row bundles: can fit 2 bundles (6 rows) under budget 7... plus
        // the tail bundle (10 % 3 = 1 row) may fit too -> 7 rows.
        assert!(sm.rows() <= 7);
        assert!(sm.rows() >= 6);
    }

    #[test]
    fn adjacent_bundles_merge_into_larger_chunks() {
        let imp = [1.0f32; 8];
        let sm = Bundling::new(2).select(&imp, 8, &table());
        assert_eq!(sm.chunks.len(), 1);
        assert_eq!(sm.chunks[0].len, 8);
    }

    #[test]
    fn bundling_dilutes_importance_vs_topk() {
        use crate::sparsify::TopK;
        // Scattered high-importance neurons: bundling drags in their
        // low-importance partners, capturing less importance per row.
        let mut imp = vec![0.0f32; 64];
        for i in (0..64).step_by(2) {
            imp[i] = 1.0;
        }
        let t = table();
        let ours = Bundling::new(2).select(&imp, 16, &t);
        let topk = TopK.select(&imp, 16, &t);
        assert!(topk.captured_importance(&imp) > ours.captured_importance(&imp));
    }

    #[test]
    fn bundle_one_equals_topk_importance() {
        use crate::sparsify::TopK;
        let imp: Vec<f32> = (0..32).map(|i| ((i * 7) % 13) as f32).collect();
        let t = table();
        let a = Bundling::new(1).select(&imp, 10, &t);
        let b = TopK.select(&imp, 10, &t);
        assert!(
            (a.captured_importance(&imp) - b.captured_importance(&imp)).abs() < 1e-6
        );
    }
}
