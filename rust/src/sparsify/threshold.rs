//! CATS-style threshold sparsification (§B.2 alternative): keep every row
//! whose importance exceeds a fixed threshold, capped by the budget.

use crate::latency::LatencyTable;
use crate::sparsify::{SelectionMask, Selector, TopK};

#[derive(Clone, Copy, Debug)]
pub struct Threshold {
    pub threshold: f32,
}

impl Threshold {
    pub fn new(threshold: f32) -> Self {
        Self { threshold }
    }

    /// Calibrate a threshold achieving `sparsity` on a sample importance
    /// distribution (the CATS calibration step).
    pub fn calibrated(samples: &[f32], sparsity: f64) -> Self {
        assert!((0.0..=1.0).contains(&sparsity));
        let mut v: Vec<f32> = samples.to_vec();
        v.sort_by(|a, b| a.total_cmp(b));
        let cut = ((v.len() as f64) * sparsity) as usize;
        let threshold = if cut == 0 {
            f32::NEG_INFINITY
        } else if cut >= v.len() {
            f32::INFINITY
        } else {
            v[cut]
        };
        Self { threshold }
    }
}

impl Selector for Threshold {
    fn name(&self) -> &str {
        "threshold"
    }

    fn select(
        &self,
        importance: &[f32],
        budget: usize,
        table: &LatencyTable,
    ) -> SelectionMask {
        let passing = importance.iter().filter(|&&v| v >= self.threshold).count();
        if passing > budget {
            // Over budget: fall back to top-k among passing rows (cap).
            return TopK.select(importance, budget, table);
        }
        let mask: Vec<bool> = importance.iter().map(|&v| v >= self.threshold).collect();
        SelectionMask::from_mask(mask)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> LatencyTable {
        LatencyTable::new(1024, vec![50e-6, 51e-6], 1024)
    }

    #[test]
    fn keeps_rows_above_threshold() {
        let imp = [0.1f32, 0.9, 0.5, 0.95];
        let sm = Threshold::new(0.5).select(&imp, 10, &table());
        assert_eq!(sm.indices(), vec![1, 2, 3]);
    }

    #[test]
    fn budget_caps_selection() {
        let imp = [1.0f32; 10];
        let sm = Threshold::new(0.5).select(&imp, 4, &table());
        assert_eq!(sm.rows(), 4);
    }

    #[test]
    fn calibration_hits_target_sparsity() {
        let samples: Vec<f32> = (0..1000).map(|i| i as f32 / 1000.0).collect();
        let t = Threshold::calibrated(&samples, 0.7);
        let kept = samples.iter().filter(|&&v| v >= t.threshold).count();
        assert!((kept as f64 / 1000.0 - 0.3).abs() < 0.01);
    }

    #[test]
    fn calibration_extremes() {
        let samples = [0.5f32; 10];
        assert_eq!(
            Threshold::calibrated(&samples, 0.0).threshold,
            f32::NEG_INFINITY
        );
        assert_eq!(Threshold::calibrated(&samples, 1.0).threshold, f32::INFINITY);
    }
}
