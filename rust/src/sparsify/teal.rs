//! TEAL-style profiling-based layerwise sparsity allocation (§4.1).
//!
//! Both the baseline and neuron chunking consume per-matrix sparsity
//! levels determined offline from a calibration set: a shared quantile
//! threshold on *normalized* importance lets matrices with flatter
//! distributions keep more rows while spiky ones are cut harder — which
//! reproduces the paper's observation (Appendix F) that some matrices end
//! up with very high or very low sparsity at a given effective level.

/// Per-matrix calibration statistics: a sample of importance values.
#[derive(Clone, Debug)]
pub struct MatrixCalibration {
    pub name: String,
    /// Row count of the matrix (weights per-matrix sparsity -> budget).
    pub rows: usize,
    /// Sampled importance values from the calibration set.
    pub samples: Vec<f32>,
}

/// Allocates per-matrix sparsity levels for a global effective target.
#[derive(Clone, Debug)]
pub struct SparsityAllocator {
    calibrations: Vec<MatrixCalibration>,
    /// Per-matrix normalized (mean-1) sorted samples.
    normalized: Vec<Vec<f32>>,
}

impl SparsityAllocator {
    pub fn new(calibrations: Vec<MatrixCalibration>) -> Self {
        let normalized = calibrations
            .iter()
            .map(|c| {
                let mean = c.samples.iter().map(|&v| v as f64).sum::<f64>()
                    / c.samples.len().max(1) as f64;
                let mut v: Vec<f32> = c
                    .samples
                    .iter()
                    .map(|&x| if mean > 0.0 { (x as f64 / mean) as f32 } else { x })
                    .collect();
                v.sort_by(|a, b| a.total_cmp(b));
                v
            })
            .collect();
        Self {
            calibrations,
            normalized,
        }
    }

    /// Sparsity of matrix `m` under normalized threshold `t`.
    fn sparsity_at(&self, m: usize, t: f32) -> f64 {
        let v = &self.normalized[m];
        if v.is_empty() {
            return 0.0;
        }
        let below = v.partition_point(|&x| x < t);
        below as f64 / v.len() as f64
    }

    /// Row-weighted effective sparsity under threshold `t`.
    fn effective_sparsity(&self, t: f32) -> f64 {
        let total: usize = self.calibrations.iter().map(|c| c.rows).sum();
        if total == 0 {
            return 0.0;
        }
        self.calibrations
            .iter()
            .enumerate()
            .map(|(m, c)| self.sparsity_at(m, t) * c.rows as f64)
            .sum::<f64>()
            / total as f64
    }

    /// Binary-search the shared threshold achieving the target effective
    /// sparsity; return per-matrix sparsity levels.
    pub fn allocate(&self, target: f64) -> Vec<f64> {
        assert!((0.0..=1.0).contains(&target));
        if self.calibrations.is_empty() {
            return Vec::new();
        }
        let (mut lo, mut hi) = (0.0f32, 1.0f32);
        // Expand hi until it overshoots.
        while self.effective_sparsity(hi) < target && hi < 1e9 {
            hi *= 2.0;
        }
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            if self.effective_sparsity(mid) < target {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let t = 0.5 * (lo + hi);
        (0..self.calibrations.len())
            .map(|m| self.sparsity_at(m, t))
            .collect()
    }

    /// Budgets (rows to keep) per matrix for a target effective sparsity.
    pub fn budgets(&self, target: f64) -> Vec<usize> {
        self.allocate(target)
            .iter()
            .zip(&self.calibrations)
            .map(|(&s, c)| ((1.0 - s) * c.rows as f64).round() as usize)
            .collect()
    }

    pub fn names(&self) -> Vec<&str> {
        self.calibrations.iter().map(|c| c.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn calib(name: &str, rows: usize, sigma: f64, seed: u64) -> MatrixCalibration {
        let mut rng = Rng::new(seed);
        MatrixCalibration {
            name: name.into(),
            rows,
            samples: (0..4000)
                .map(|_| rng.lognormal(0.0, sigma) as f32)
                .collect(),
        }
    }

    #[test]
    fn hits_effective_target() {
        let a = SparsityAllocator::new(vec![
            calib("q", 1024, 0.5, 1),
            calib("gate", 1024, 1.5, 2),
            calib("down", 3072, 1.0, 3),
        ]);
        for target in [0.2, 0.4, 0.6] {
            let alloc = a.allocate(target);
            let total = 1024 + 1024 + 3072;
            let eff = (alloc[0] * 1024.0 + alloc[1] * 1024.0 + alloc[2] * 3072.0)
                / total as f64;
            assert!((eff - target).abs() < 0.02, "target {target} got {eff}");
        }
    }

    #[test]
    fn spiky_matrices_get_more_sparsity() {
        // Higher-sigma lognormal = spikier distribution = more mass in few
        // rows = higher sparsity at a shared normalized threshold.
        let a = SparsityAllocator::new(vec![
            calib("flat", 1000, 0.3, 7),
            calib("spiky", 1000, 2.0, 8),
        ]);
        let alloc = a.allocate(0.5);
        assert!(
            alloc[1] > alloc[0] + 0.1,
            "spiky {} flat {}",
            alloc[1],
            alloc[0]
        );
    }

    #[test]
    fn budgets_complement_sparsity() {
        let a = SparsityAllocator::new(vec![calib("m", 500, 1.0, 9)]);
        let s = a.allocate(0.3)[0];
        let b = a.budgets(0.3)[0];
        assert_eq!(b, ((1.0 - s) * 500.0).round() as usize);
    }

    #[test]
    fn zero_and_full_targets() {
        let a = SparsityAllocator::new(vec![calib("m", 100, 1.0, 11)]);
        assert!(a.allocate(0.0)[0] < 0.01);
        let b = a.budgets(0.0)[0];
        assert!(b >= 99);
    }

    #[test]
    fn monotone_in_target() {
        let a = SparsityAllocator::new(vec![
            calib("x", 800, 0.8, 13),
            calib("y", 800, 1.2, 14),
        ]);
        let mut prev = vec![0.0, 0.0];
        for t in [0.1, 0.3, 0.5, 0.7] {
            let cur = a.allocate(t);
            assert!(cur[0] >= prev[0] - 1e-9 && cur[1] >= prev[1] - 1e-9);
            prev = cur;
        }
    }
}
