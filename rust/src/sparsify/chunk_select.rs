//! Utility-guided multi-scale chunk selection — Algorithm 1 of the paper
//! (§3.2 + Appendix E).
//!
//! Stages:
//! 1. **Candidate generation** — slide windows of sizes
//!    `r_min..=r_max` (step `Δr`) over the row index space; stride between
//!    window starts is `min(r, jump_cap)` (non-overlapping by default,
//!    overlapping once the size exceeds the jump cap).
//! 2. **Evaluation** — utility = (prefix-sum benefit) / `T[r]` from the
//!    profiled latency table.
//! 3. **Greedy selection** — sort candidates by utility descending, take
//!    non-overlapping chunks while the budget lasts.
//!
//! The paper sorts on GPU (80% of its runtime); here a four-pass 8-bit
//! LSD radix sort on bit-keyed `(score_bits, start, len)` tuples plays
//! that role and the 2 ms/matrix budget is enforced in benches (Fig 13
//! reproduction).

use crate::latency::LatencyTable;
use crate::sparsify::{SelectScratch, SelectionMask, Selector};

/// Hyperparameters of Algorithm 1, in KB like the paper's Appendix H.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChunkSelectConfig {
    /// Smallest candidate chunk size in KB (`chunk_sz_start_in_kb`).
    pub min_kb: f64,
    /// Largest candidate size in KB — the device saturation point.
    pub max_kb: f64,
    /// Size increment in KB (paper sets step = start size).
    pub step_kb: f64,
    /// Maximum stride between candidate starts in KB (`jump_cap_in_kb`).
    pub jump_cap_kb: f64,
}

impl ChunkSelectConfig {
    /// Paper default shape: step = start, max from the device saturation
    /// point embedded in the latency table.
    pub fn new(min_kb: f64, jump_cap_kb: f64, max_kb: f64) -> Self {
        Self {
            min_kb,
            max_kb,
            step_kb: min_kb,
            jump_cap_kb,
        }
    }

    /// Convert to row units for a given row size (Algorithm 1 line 1).
    pub fn to_rows(&self, row_bytes: usize) -> RowParams {
        let row_kb = row_bytes as f64 / 1024.0;
        let to_rows = |kb: f64| ((kb / row_kb).floor() as usize).max(1);
        RowParams {
            r_min: to_rows(self.min_kb),
            r_max: to_rows(self.max_kb),
            r_step: to_rows(self.step_kb),
            jump_cap: to_rows(self.jump_cap_kb),
        }
    }
}

/// Row-unit parameters after conversion.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RowParams {
    pub r_min: usize,
    pub r_max: usize,
    pub r_step: usize,
    pub jump_cap: usize,
}

/// The paper's chunk selector.
#[derive(Clone, Debug)]
pub struct ChunkSelect {
    pub config: ChunkSelectConfig,
}

impl ChunkSelect {
    pub fn new(config: ChunkSelectConfig) -> Self {
        Self { config }
    }

    /// Reasonable defaults for a device table: min/step 8 KB (or one row),
    /// jump cap 8 KB, max = saturation point.
    pub fn for_table(table: &LatencyTable) -> Self {
        let sat_kb = table.saturation_bytes(0.99) as f64 / 1024.0;
        Self::new(ChunkSelectConfig::new(8.0, 8.0, sat_kb))
    }

    /// Stage 1+2: generate scored candidates. Exposed for benches/tests.
    pub fn candidates(
        &self,
        importance: &[f32],
        table: &LatencyTable,
    ) -> Vec<(f32, u32, u32)> {
        let mut cumsum = Vec::new();
        let mut keyed = Vec::new();
        self.candidates_into(importance, table, &mut cumsum, &mut keyed);
        keyed
            .iter()
            .map(|&(bits, i, r)| (f32::from_bits(bits), i, r))
            .collect()
    }

    /// Allocation-free candidate generation, emitting radix-ready
    /// `(score_bits, start, len)` tuples directly (scores are
    /// non-negative, so IEEE-754 bit patterns order identically to the
    /// float values — no intermediate float-keyed copy).
    pub fn candidates_into(
        &self,
        importance: &[f32],
        table: &LatencyTable,
        cumsum: &mut Vec<f64>,
        out: &mut Vec<(u32, u32, u32)>,
    ) {
        let n = importance.len();
        let p = self.config.to_rows(table.row_bytes());
        let r_max = p.r_max.min(n);

        // Prefix sums for O(1) window benefit (Algorithm 1 line 2).
        cumsum.clear();
        let mut acc = 0.0f64;
        cumsum.push(0.0);
        for &v in importance {
            acc += v as f64;
            cumsum.push(acc);
        }

        out.clear();
        let mut r = p.r_min.min(r_max);
        while r <= r_max {
            let cost = table.latency_rows(r);
            let inv_cost = if cost > 0.0 { 1.0 / cost } else { 0.0 };
            let stride = r.min(p.jump_cap).max(1);
            let mut i = 0usize;
            while i + r <= n {
                let benefit = cumsum[i + r] - cumsum[i];
                out.push((((benefit * inv_cost) as f32).to_bits(), i as u32, r as u32));
                i += stride;
            }
            // Always include the right-aligned window so trailing rows are
            // reachable at every size.
            if n >= r && (n - r) % stride != 0 {
                let i = n - r;
                let benefit = cumsum[i + r] - cumsum[i];
                out.push((((benefit * inv_cost) as f32).to_bits(), i as u32, r as u32));
            }
            if r == r_max {
                break;
            }
            r = (r + p.r_step).min(r_max);
        }
    }
}

/// Descending stable LSD radix sort on the first tuple element (four
/// 8-bit counting-sort passes) — the CPU analogue of the paper's GPU
/// radix sort (Appendix H: >80% of selection runtime is this sort).
/// `scratch` is the double buffer; it is resized (not reallocated once
/// warm) and left holding garbage.
fn radix_sort_desc(v: &mut Vec<(u32, u32, u32)>, scratch: &mut Vec<(u32, u32, u32)>) {
    let n = v.len();
    if n < 64 {
        v.sort_unstable_by(|a, b| b.0.cmp(&a.0));
        return;
    }
    scratch.clear();
    scratch.resize(n, (0, 0, 0));
    // Four passes over 8-bit digits (256 counters live in L1, unlike a
    // 64 K-counter 16-bit variant which thrashes cache for n ~ 10^4..5).
    // An even pass count leaves the sorted data back in `v`.
    for shift in [0u32, 8, 16, 24] {
        let mut counts = [0u32; 256];
        for item in v.iter() {
            counts[((item.0 >> shift) & 0xFF) as usize] += 1;
        }
        // Prefix offsets in descending digit order.
        let mut acc = 0u32;
        for d in (0..256).rev() {
            let c = counts[d];
            counts[d] = acc;
            acc += c;
        }
        for item in v.iter() {
            let d = ((item.0 >> shift) & 0xFF) as usize;
            scratch[counts[d] as usize] = *item;
            counts[d] += 1;
        }
        std::mem::swap(v, scratch);
    }
}

impl Selector for ChunkSelect {
    fn name(&self) -> &str {
        "chunk_select"
    }

    fn select(
        &self,
        importance: &[f32],
        budget: usize,
        table: &LatencyTable,
    ) -> SelectionMask {
        let mut scratch = SelectScratch::default();
        let mut out = SelectionMask::default();
        self.select_into(importance, budget, table, &mut scratch, &mut out);
        out
    }

    fn select_into(
        &self,
        importance: &[f32],
        budget: usize,
        table: &LatencyTable,
        scratch: &mut SelectScratch,
        out: &mut SelectionMask,
    ) {
        let n = importance.len();
        let budget = budget.min(n);
        if budget == 0 || n == 0 {
            out.reset(n);
            return;
        }
        if budget == n {
            out.set_full(n);
            return;
        }

        // Stage 1+2: bit-keyed candidates straight into the sort buffer.
        self.candidates_into(importance, table, &mut scratch.cumsum, &mut scratch.cands);
        // Stage 3: sort by utility descending. The paper uses a
        // data-independent GPU radix sort; we mirror it with an LSD radix
        // sort on the score's IEEE-754 bits (non-negative floats order
        // identically to their bit patterns). O(n) vs O(n log n): ~6x
        // faster than pdqsort on the 18944-row shape (§Perf log).
        radix_sort_desc(&mut scratch.cands, &mut scratch.radix);

        out.reset(n);
        let mask = &mut out.mask;
        let mut selected = 0usize;
        // Once the remaining budget is below the smallest candidate size,
        // nothing further can be placed — break instead of scanning the
        // tail of the sorted list (§Perf: the tail scan dominated greedy).
        let min_len = self.config.to_rows(table.row_bytes()).r_min.min(n);
        for &(_, start, len) in scratch.cands.iter() {
            if budget - selected < min_len {
                break;
            }
            let (start, len) = (start as usize, len as usize);
            if len > budget - selected {
                continue; // would exceed the remaining budget
            }
            // Overlap check with early termination (Algorithm 1 line 15).
            if mask[start..start + len].iter().any(|&m| m) {
                continue;
            }
            mask[start..start + len].iter_mut().for_each(|m| *m = true);
            selected += len;
            if selected >= budget {
                break;
            }
        }
        // Merge adjacent selected runs into maximal chunks for reporting.
        out.recompute_chunks();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latency::Chunk;
    use crate::rng::Rng;

    /// Table with strong contiguity preference: 100us overhead + 1 GB/s,
    /// 1 KB rows, profiled to 64 KB.
    fn table() -> LatencyTable {
        let entries = (1..=64)
            .map(|i| 100e-6 + (i * 1024) as f64 / 1e9)
            .collect();
        LatencyTable::new(1024, entries, 1024)
    }

    fn cfg() -> ChunkSelectConfig {
        ChunkSelectConfig::new(1.0, 4.0, 64.0)
    }

    #[test]
    fn row_conversion_matches_paper_line1() {
        let c = ChunkSelectConfig::new(8.0, 16.0, 236.0);
        let p = c.to_rows(4096); // 4 KB rows
        assert_eq!(p.r_min, 2);
        assert_eq!(p.r_step, 2);
        assert_eq!(p.jump_cap, 4);
        assert_eq!(p.r_max, 59);
        // Sub-row sizes clamp to 1 row.
        let p2 = ChunkSelectConfig::new(1.0, 1.0, 64.0).to_rows(4096);
        assert_eq!(p2.r_min, 1);
        assert_eq!(p2.jump_cap, 1);
    }

    #[test]
    fn respects_budget() {
        let mut rng = Rng::new(5);
        let imp: Vec<f32> = (0..512).map(|_| rng.f32()).collect();
        for budget in [16usize, 100, 300, 511] {
            let sm = ChunkSelect::new(cfg()).select(&imp, budget, &table());
            assert!(sm.rows() <= budget, "budget {budget} rows {}", sm.rows());
            // Greedy should come close to the budget (within one max chunk).
            assert!(sm.rows() + 64 >= budget.min(512));
            sm.validate().unwrap();
        }
    }

    #[test]
    fn prefers_contiguous_region_over_scattered_peaks() {
        // 8 isolated high peaks (1.0 each, far apart) vs a contiguous run
        // of 8 rows at 0.9: under a strongly overhead-bound table the run
        // has far better importance/latency.
        let mut imp = vec![0.0f32; 256];
        for i in 0..8 {
            imp[i * 32] = 1.0;
        }
        for i in 100..108 {
            imp[i] = 0.9;
        }
        let sm = ChunkSelect::new(cfg()).select(&imp, 8, &table());
        assert_eq!(sm.chunks.len(), 1, "{:?}", sm.chunks);
        assert_eq!(sm.chunks[0], Chunk::new(100, 8));
    }

    #[test]
    fn topk_beats_it_on_importance_but_not_utility() {
        use crate::sparsify::TopK;
        let mut rng = Rng::new(17);
        let imp: Vec<f32> = (0..512).map(|_| rng.f32().powi(3)).collect();
        let t = table();
        let budget = 128;
        let ours = ChunkSelect::new(cfg()).select(&imp, budget, &t);
        let base = TopK.select(&imp, budget, &t);
        // top-k captures >= importance by construction...
        assert!(
            base.captured_importance(&imp) >= ours.captured_importance(&imp) - 1e-3
        );
        // ...but at (much) worse estimated latency.
        assert!(t.estimate_chunks(&ours.chunks) < t.estimate_chunks(&base.chunks));
        // And ours wins on the paper's utility objective.
        let utility = |sm: &SelectionMask| {
            sm.captured_importance(&imp) / t.estimate_chunks(&sm.chunks)
        };
        assert!(utility(&ours) > utility(&base));
    }

    #[test]
    fn no_overlapping_chunks() {
        let mut rng = Rng::new(23);
        let imp: Vec<f32> = (0..300).map(|_| rng.f32()).collect();
        let sm = ChunkSelect::new(cfg()).select(&imp, 150, &table());
        for w in sm.chunks.windows(2) {
            assert!(w[0].end() <= w[1].start);
        }
    }

    #[test]
    fn trailing_rows_reachable() {
        // High importance only at the tail; right-aligned candidates must
        // cover it even when n % stride != 0.
        let mut imp = vec![0.0f32; 250];
        for v in imp[244..].iter_mut() {
            *v = 1.0;
        }
        let sm = ChunkSelect::new(ChunkSelectConfig::new(6.0, 6.0, 64.0))
            .select(&imp, 6, &table());
        assert!(
            sm.indices().iter().any(|&i| i >= 244),
            "tail not covered: {:?}",
            sm.chunks
        );
    }

    #[test]
    fn empty_inputs() {
        let sm = ChunkSelect::new(cfg()).select(&[], 10, &table());
        assert_eq!(sm.rows(), 0);
        let imp = vec![1.0f32; 10];
        assert_eq!(ChunkSelect::new(cfg()).select(&imp, 0, &table()).rows(), 0);
    }

    #[test]
    fn full_budget_selects_everything() {
        let imp = vec![1.0f32; 64];
        let sm = ChunkSelect::new(cfg()).select(&imp, 64, &table());
        assert_eq!(sm.rows(), 64);
        assert_eq!(sm.chunks.len(), 1);
    }

    #[test]
    fn uniform_importance_yields_large_chunks() {
        // With flat importance, utility is maximized by saturation-size
        // chunks (amortized overhead) — mean chunk size should be large.
        let imp = vec![1.0f32; 1024];
        let sm = ChunkSelect::new(cfg()).select(&imp, 512, &table());
        let d = crate::latency::ContiguityDistribution::from_chunks(&sm.chunks);
        assert!(d.mean_chunk() >= 32.0, "mean chunk {}", d.mean_chunk());
    }

    #[test]
    fn candidates_cover_all_sizes() {
        let imp = vec![1.0f32; 128];
        let t = table();
        let cands = ChunkSelect::new(ChunkSelectConfig::new(1.0, 2.0, 8.0))
            .candidates(&imp, &t);
        let mut sizes: Vec<u32> = cands.iter().map(|c| c.2).collect();
        sizes.sort_unstable();
        sizes.dedup();
        assert_eq!(sizes, vec![1, 2, 3, 4, 5, 6, 7, 8]);
    }

    #[test]
    fn utility_error_scale_invariance() {
        // §3.2.2: a proportional latency-model error must not change the
        // selection (utility ranks are scale-invariant).
        let mut rng = Rng::new(31);
        let imp: Vec<f32> = (0..256).map(|_| rng.f32()).collect();
        let t1 = table();
        let scaled: Vec<f64> = (1..=64)
            .map(|i| 2.5 * (100e-6 + (i * 1024) as f64 / 1e9))
            .collect();
        let t2 = LatencyTable::new(1024, scaled, 1024);
        let a = ChunkSelect::new(cfg()).select(&imp, 100, &t1);
        let b = ChunkSelect::new(cfg()).select(&imp, 100, &t2);
        assert_eq!(a.indices(), b.indices());
    }
}
