//! Neuron selection policies.
//!
//! * [`TopK`] — the conventional magnitude-based baseline (TEAL/CATS
//!   style, §B.2): pick the `R` rows with largest |activation|.
//! * [`Threshold`] — CATS-style fixed-threshold variant.
//! * [`ChunkSelect`] — the paper's utility-guided chunk selection
//!   (Algorithm 1): multi-scale candidate windows scored by
//!   importance-per-latency, greedy non-overlapping selection.
//! * [`Bundling`] — LLM-in-a-Flash row–column bundling baseline
//!   (Appendix L / Table 3).
//! * [`teal::SparsityAllocator`] — profiling-based layerwise sparsity
//!   levels shared by baseline and ours (§4.1).

mod bundling;
mod chunk_select;
pub mod teal;
mod threshold;
mod topk;
pub mod tuning;

pub use bundling::{min_chunk_rows, Bundling};
pub use chunk_select::{ChunkSelect, ChunkSelectConfig};
pub use threshold::Threshold;
pub use topk::TopK;

use crate::latency::{chunks_from_mask, chunks_from_mask_into, Chunk, LatencyTable};

/// Result of a selection: boolean mask + its maximal chunks.
#[derive(Clone, Debug, Default)]
pub struct SelectionMask {
    pub mask: Vec<bool>,
    pub chunks: Vec<Chunk>,
}

impl SelectionMask {
    pub fn from_mask(mask: Vec<bool>) -> Self {
        let chunks = chunks_from_mask(&mask);
        Self { mask, chunks }
    }

    pub fn empty(n: usize) -> Self {
        Self {
            mask: vec![false; n],
            chunks: Vec::new(),
        }
    }

    pub fn full(n: usize) -> Self {
        Self::from_mask(vec![true; n])
    }

    /// Reset in place to an all-false mask of `n` rows, reusing capacity.
    pub fn reset(&mut self, n: usize) {
        self.mask.clear();
        self.mask.resize(n, false);
        self.chunks.clear();
    }

    /// Reset in place to an all-true mask of `n` rows, reusing capacity.
    pub fn set_full(&mut self, n: usize) {
        self.mask.clear();
        self.mask.resize(n, true);
        self.chunks.clear();
        if n > 0 {
            self.chunks.push(Chunk::new(0, n));
        }
    }

    /// Recompute `chunks` from `mask` in place (after direct mask edits).
    pub fn recompute_chunks(&mut self) {
        chunks_from_mask_into(&self.mask, &mut self.chunks);
    }

    /// Number of selected rows.
    pub fn rows(&self) -> usize {
        self.chunks.iter().map(|c| c.len).sum()
    }

    /// Selected row indices in ascending order.
    pub fn indices(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.rows());
        for c in &self.chunks {
            out.extend(c.start..c.end());
        }
        out
    }

    /// Total importance captured by the selection.
    pub fn captured_importance(&self, importance: &[f32]) -> f64 {
        self.chunks
            .iter()
            .map(|c| {
                importance[c.start..c.end()]
                    .iter()
                    .map(|&v| v as f64)
                    .sum::<f64>()
            })
            .sum()
    }

    /// Internal consistency: chunks are sorted, non-overlapping, maximal,
    /// and agree with the mask. (Used by tests and debug assertions.)
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.chunks == chunks_from_mask(&self.mask),
            "chunks/mask mismatch"
        );
        Ok(())
    }
}

/// Reusable selection working memory. Selectors that implement
/// [`Selector::select_into`] draw all their temporaries from here so the
/// steady-state serving path performs no heap allocations (buffers grow to
/// their high-water mark during warm-up, then stabilize).
#[derive(Clone, Debug, Default)]
pub struct SelectScratch {
    /// Bit-keyed `(score_bits, start, len)` candidate tuples.
    pub cands: Vec<(u32, u32, u32)>,
    /// Radix-sort double buffer.
    pub radix: Vec<(u32, u32, u32)>,
    /// Importance prefix sums.
    pub cumsum: Vec<f64>,
    /// Row-index scratch (top-k partial selection).
    pub idx: Vec<u32>,
}

/// A neuron-selection policy.
///
/// `importance` is the per-row score (mean |activation| over tokens);
/// `budget` is the maximum number of rows to select (the paper's `R`);
/// `table` is the device latency model (ignored by latency-blind
/// baselines).
pub trait Selector: Send + Sync {
    fn name(&self) -> &str;

    fn select(
        &self,
        importance: &[f32],
        budget: usize,
        table: &LatencyTable,
    ) -> SelectionMask;

    /// Allocation-free variant: write the selection into `out`, drawing
    /// temporaries from `scratch`. The default implementation falls back
    /// to [`Selector::select`] (allocating); hot-path selectors override
    /// it.
    fn select_into(
        &self,
        importance: &[f32],
        budget: usize,
        table: &LatencyTable,
        scratch: &mut SelectScratch,
        out: &mut SelectionMask,
    ) {
        let _ = scratch;
        *out = self.select(importance, budget, table);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selection_mask_roundtrip() {
        let mask = vec![true, true, false, true, false];
        let sm = SelectionMask::from_mask(mask);
        assert_eq!(sm.rows(), 3);
        assert_eq!(sm.indices(), vec![0, 1, 3]);
        sm.validate().unwrap();
    }

    #[test]
    fn captured_importance_sums_selected() {
        let sm = SelectionMask::from_mask(vec![true, false, true]);
        let imp = [1.0f32, 10.0, 2.5];
        assert!((sm.captured_importance(&imp) - 3.5).abs() < 1e-9);
    }

    #[test]
    fn empty_and_full() {
        assert_eq!(SelectionMask::empty(4).rows(), 0);
        assert_eq!(SelectionMask::full(4).rows(), 4);
        assert_eq!(SelectionMask::full(4).chunks.len(), 1);
    }

    #[test]
    fn validate_catches_mismatch() {
        let mut sm = SelectionMask::from_mask(vec![true, false]);
        sm.chunks = vec![Chunk::new(0, 2)];
        assert!(sm.validate().is_err());
    }
}
