//! Row permutations with forward/inverse application, used to rewrite the
//! weight layout offline and to permute activation vectors at runtime.

/// A permutation over `n` row indices.
///
/// Convention: `fwd[new_pos] = old_pos` — position `i` of the reordered
/// layout holds the original row `fwd[i]`. `apply` moves data from
/// original order into the new layout; `inv` maps original index → new
/// position (the runtime activation permutation).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Permutation {
    fwd: Vec<u32>,
    inv: Vec<u32>,
}

impl Permutation {
    pub fn identity(n: usize) -> Self {
        let fwd: Vec<u32> = (0..n as u32).collect();
        Self {
            inv: fwd.clone(),
            fwd,
        }
    }

    /// Build from `fwd[new_pos] = old_pos`; validates bijectivity.
    pub fn from_fwd(fwd: Vec<u32>) -> anyhow::Result<Self> {
        let n = fwd.len();
        let mut inv = vec![u32::MAX; n];
        for (new_pos, &old) in fwd.iter().enumerate() {
            anyhow::ensure!((old as usize) < n, "index {old} out of range {n}");
            anyhow::ensure!(
                inv[old as usize] == u32::MAX,
                "duplicate index {old} in permutation"
            );
            inv[old as usize] = new_pos as u32;
        }
        Ok(Self { fwd, inv })
    }

    pub fn len(&self) -> usize {
        self.fwd.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fwd.is_empty()
    }

    pub fn is_identity(&self) -> bool {
        self.fwd.iter().enumerate().all(|(i, &v)| i as u32 == v)
    }

    /// Original row index stored at reordered position `new_pos`.
    #[inline]
    pub fn old_of(&self, new_pos: usize) -> usize {
        self.fwd[new_pos] as usize
    }

    /// Reordered position of original row `old_pos`.
    #[inline]
    pub fn new_of(&self, old_pos: usize) -> usize {
        self.inv[old_pos] as usize
    }

    /// Reorder a slice of per-row values into the new layout:
    /// `out[new_pos] = data[fwd[new_pos]]`.
    pub fn apply<T: Copy>(&self, data: &[T]) -> Vec<T> {
        assert_eq!(data.len(), self.len());
        self.fwd.iter().map(|&old| data[old as usize]).collect()
    }

    /// Allocation-free [`Permutation::apply`]: clears `out` and refills it,
    /// reusing its capacity.
    pub fn apply_into<T: Copy>(&self, data: &[T], out: &mut Vec<T>) {
        assert_eq!(data.len(), self.len());
        out.clear();
        out.extend(self.fwd.iter().map(|&old| data[old as usize]));
    }

    /// Inverse reorder: `out[old_pos] = data[inv[old_pos]]`.
    pub fn apply_inv<T: Copy>(&self, data: &[T]) -> Vec<T> {
        assert_eq!(data.len(), self.len());
        self.inv.iter().map(|&new| data[new as usize]).collect()
    }

    /// Reorder fixed-size rows of a flat buffer (weight-matrix rewrite).
    pub fn apply_rows<T: Copy + Default>(&self, data: &[T], row_width: usize) -> Vec<T> {
        assert_eq!(data.len(), self.len() * row_width);
        let mut out = vec![T::default(); data.len()];
        for (new_pos, &old) in self.fwd.iter().enumerate() {
            let src = old as usize * row_width;
            let dst = new_pos * row_width;
            out[dst..dst + row_width].copy_from_slice(&data[src..src + row_width]);
        }
        out
    }

    /// Compose: apply `self` then `other` (other ∘ self).
    pub fn then(&self, other: &Permutation) -> Permutation {
        assert_eq!(self.len(), other.len());
        let fwd: Vec<u32> = other.fwd.iter().map(|&mid| self.fwd[mid as usize]).collect();
        Permutation::from_fwd(fwd).expect("composition of bijections")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_round_trip() {
        let p = Permutation::identity(5);
        assert!(p.is_identity());
        let data = [10, 20, 30, 40, 50];
        assert_eq!(p.apply(&data), data.to_vec());
        assert_eq!(p.apply_inv(&data), data.to_vec());
    }

    #[test]
    fn apply_then_inverse_is_identity() {
        let p = Permutation::from_fwd(vec![2, 0, 3, 1]).unwrap();
        let data = [1.0f32, 2.0, 3.0, 4.0];
        let reordered = p.apply(&data);
        assert_eq!(reordered, vec![3.0, 1.0, 4.0, 2.0]);
        assert_eq!(p.apply_inv(&reordered), data.to_vec());
    }

    #[test]
    fn old_new_consistency() {
        let p = Permutation::from_fwd(vec![3, 1, 0, 2]).unwrap();
        for new_pos in 0..4 {
            assert_eq!(p.new_of(p.old_of(new_pos)), new_pos);
        }
    }

    #[test]
    fn rejects_non_bijective() {
        assert!(Permutation::from_fwd(vec![0, 0, 1]).is_err());
        assert!(Permutation::from_fwd(vec![0, 5]).is_err());
    }

    #[test]
    fn apply_rows_moves_whole_rows() {
        let p = Permutation::from_fwd(vec![1, 0]).unwrap();
        let data = [1u8, 2, 3, 10, 20, 30];
        assert_eq!(p.apply_rows(&data, 3), vec![10, 20, 30, 1, 2, 3]);
    }

    #[test]
    fn composition() {
        let a = Permutation::from_fwd(vec![1, 2, 0]).unwrap(); // rotate
        let b = Permutation::from_fwd(vec![2, 1, 0]).unwrap(); // reverse
        let c = a.then(&b);
        let data = [10, 20, 30];
        assert_eq!(c.apply(&data), b.apply(&a.apply(&data)));
    }
}
