//! Ripple-style co-activation reordering (Appendix G comparison).
//!
//! Builds a greedy chain: start from the most frequently active neuron,
//! repeatedly append the unplaced neuron with the highest co-activation
//! count with the current chain tail. This approximates Ripple's
//! correlation-aware neuron placement without its link-structure
//! machinery; Appendix G finds it performs on par with hot–cold
//! reordering, which is exactly what our Fig 12 bench shows.
//!
//! Complexity: O(n²) pairwise counts over a (sub)sampled calibration set —
//! acceptable offline for the matrix sizes in play; the paper makes the
//! same offline/runtime split.

use crate::reorder::Permutation;

#[derive(Clone, Copy, Debug)]
pub struct CoActivationReorder {
    /// Neurons considered "active" per sample: top `active_frac` fraction.
    pub active_frac: f64,
}

impl Default for CoActivationReorder {
    fn default() -> Self {
        Self { active_frac: 0.5 }
    }
}

impl CoActivationReorder {
    /// Binary activation matrix (samples × neurons) from importance.
    fn binarize(&self, samples: &[Vec<f32>], n: usize) -> Vec<Vec<bool>> {
        let k = ((n as f64 * self.active_frac) as usize).clamp(1, n);
        samples
            .iter()
            .map(|s| {
                assert_eq!(s.len(), n);
                let mut idx: Vec<u32> = (0..n as u32).collect();
                idx.select_nth_unstable_by(k - 1, |&a, &b| {
                    s[b as usize].total_cmp(&s[a as usize])
                });
                let mut row = vec![false; n];
                for &i in &idx[..k] {
                    row[i as usize] = true;
                }
                row
            })
            .collect()
    }

    pub fn build(&self, samples: &[Vec<f32>], n: usize) -> Permutation {
        if n == 0 {
            return Permutation::identity(0);
        }
        let acts = self.binarize(samples, n);
        // Co-activation counts, packed upper-triangular would halve memory;
        // n here is a few thousand at most offline, keep it simple.
        let mut co = vec![0u32; n * n];
        let mut freq = vec![0u32; n];
        for row in &acts {
            let on: Vec<usize> = (0..n).filter(|&i| row[i]).collect();
            for &i in &on {
                freq[i] += 1;
            }
            for (ai, &i) in on.iter().enumerate() {
                for &j in &on[ai + 1..] {
                    co[i * n + j] += 1;
                    co[j * n + i] += 1;
                }
            }
        }
        // Greedy chain.
        let mut placed = vec![false; n];
        let mut order: Vec<u32> = Vec::with_capacity(n);
        let start = (0..n).max_by_key(|&i| freq[i]).unwrap();
        order.push(start as u32);
        placed[start] = true;
        for _ in 1..n {
            let tail = *order.last().unwrap() as usize;
            let mut best = usize::MAX;
            let mut best_score = (0u32, 0u32);
            for j in 0..n {
                if placed[j] {
                    continue;
                }
                let score = (co[tail * n + j], freq[j]);
                if best == usize::MAX || score > best_score {
                    best = j;
                    best_score = score;
                }
            }
            order.push(best as u32);
            placed[best] = true;
        }
        Permutation::from_fwd(order).expect("chain is a bijection")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Two disjoint co-activating groups; the chain must keep each group
    /// contiguous.
    #[test]
    fn groups_stay_contiguous() {
        let n = 16;
        let group_a: Vec<usize> = vec![0, 3, 5, 9, 12, 14];
        let mut rng = Rng::new(3);
        let mut samples = Vec::new();
        for _ in 0..60 {
            let a_active = rng.bool(0.5);
            let sample: Vec<f32> = (0..n)
                .map(|i| {
                    let in_a = group_a.contains(&i);
                    if in_a == a_active {
                        0.8 + 0.2 * rng.f32()
                    } else {
                        0.2 * rng.f32()
                    }
                })
                .collect();
            samples.push(sample);
        }
        let perm = CoActivationReorder::default().build(&samples, n);
        // Positions of group A in the new layout must be contiguous.
        let mut pos: Vec<usize> = group_a.iter().map(|&i| perm.new_of(i)).collect();
        pos.sort_unstable();
        let span = pos.last().unwrap() - pos.first().unwrap() + 1;
        assert_eq!(span, group_a.len(), "group A scattered: {pos:?}");
    }

    #[test]
    fn is_a_valid_permutation() {
        let mut rng = Rng::new(9);
        let samples: Vec<Vec<f32>> = (0..20)
            .map(|_| (0..32).map(|_| rng.f32()).collect())
            .collect();
        let p = CoActivationReorder::default().build(&samples, 32);
        assert_eq!(p.len(), 32);
        let mut seen: Vec<usize> = (0..32).map(|i| p.old_of(i)).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn empty_is_fine() {
        let p = CoActivationReorder::default().build(&[], 0);
        assert!(p.is_empty());
    }
}
