//! Offline neuron reordering (§3.3, Appendix F/G).
//!
//! Rows of a weight matrix are permuted offline so that frequently-active
//! neurons cluster, improving the contiguity of runtime selections. The
//! runtime applies the same permutation to the activation vector (a cheap
//! gather the paper measures at ~1.5 ms per layer on Nano).
//!
//! Two schemes:
//! * [`HotColdReorder`] — sort by activation frequency (the paper's
//!   choice: simple, and empirically on par with co-activation methods).
//! * [`CoActivationReorder`] — Ripple-style greedy correlation chaining
//!   (the stronger-looking but costlier alternative of Appendix G).

mod coactivation;
mod hotcold;
mod permutation;

pub use coactivation::CoActivationReorder;
pub use hotcold::HotColdReorder;
pub use permutation::Permutation;

/// Count per-neuron activation frequency over a calibration set: a neuron
/// is "active" in a sample when its importance is in the top half
/// (paper §3.3: top 50% by importance counts as active).
pub fn activation_frequency(samples: &[Vec<f32>], n: usize) -> Vec<f64> {
    let mut freq = vec![0.0f64; n];
    if samples.is_empty() {
        return freq;
    }
    let mut scratch: Vec<f32> = Vec::with_capacity(n);
    for s in samples {
        assert_eq!(s.len(), n, "sample length mismatch");
        scratch.clear();
        scratch.extend_from_slice(s);
        let k = n / 2;
        if k == 0 {
            continue;
        }
        // Threshold = k-th largest value.
        scratch.select_nth_unstable_by(k - 1, |a, b| b.total_cmp(a));
        let thresh = scratch[k - 1];
        for (i, &v) in s.iter().enumerate() {
            if v >= thresh {
                freq[i] += 1.0;
            }
        }
    }
    let m = samples.len() as f64;
    freq.iter_mut().for_each(|f| *f /= m);
    freq
}

/// Drift between a calibrated activation profile and a live one: total
/// variation distance between the two distributions after normalizing
/// each to sum 1 (`0.5 * Σ|a − b|`, so 0 = identical, 1 = disjoint).
///
/// Used by the runtime cache layer to decide when the offline hot/cold
/// layout has gone stale enough to warrant an online re-reorder. Empty
/// or all-zero inputs score 0 (no evidence of drift).
pub fn drift_score(baseline: &[f64], live: &[f64]) -> f64 {
    assert_eq!(baseline.len(), live.len(), "profile length mismatch");
    let bs: f64 = baseline.iter().sum();
    let ls: f64 = live.iter().sum();
    if bs <= 0.0 || ls <= 0.0 {
        return 0.0;
    }
    0.5 * baseline
        .iter()
        .zip(live)
        .map(|(&b, &l)| (b / bs - l / ls).abs())
        .sum::<f64>()
}

/// Fraction of hot (always-active, >99%) and cold (<1%) neurons — the
/// Fig 11 annotations.
pub fn hot_cold_fractions(freq: &[f64]) -> (f64, f64) {
    let n = freq.len().max(1) as f64;
    let hot = freq.iter().filter(|&&f| f > 0.99).count() as f64 / n;
    let cold = freq.iter().filter(|&&f| f < 0.01).count() as f64 / n;
    (hot, cold)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frequency_counts_top_half() {
        // 4 neurons; neuron 3 always highest, neuron 0 always lowest.
        let samples = vec![
            vec![0.1f32, 0.5, 0.6, 0.9],
            vec![0.2, 0.7, 0.4, 0.8],
            vec![0.0, 0.6, 0.5, 1.0],
        ];
        let f = activation_frequency(&samples, 4);
        assert_eq!(f[3], 1.0);
        assert_eq!(f[0], 0.0);
        // Exactly half the neurons are active per sample.
        for s in 0..3 {
            let _ = s;
        }
        assert!((f.iter().sum::<f64>() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn empty_samples() {
        let f = activation_frequency(&[], 5);
        assert_eq!(f, vec![0.0; 5]);
    }

    #[test]
    fn drift_score_bounds() {
        // Identical profiles (up to scale) → 0.
        let a = vec![1.0, 2.0, 3.0];
        let b = vec![10.0, 20.0, 30.0];
        assert!(drift_score(&a, &b).abs() < 1e-12);
        // Disjoint mass → 1.
        let c = vec![1.0, 0.0];
        let d = vec![0.0, 5.0];
        assert!((drift_score(&c, &d) - 1.0).abs() < 1e-12);
        // No evidence → 0.
        assert_eq!(drift_score(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
        assert_eq!(drift_score(&[], &[]), 0.0);
        // Partial shift lands strictly between.
        let e = vec![0.5, 0.5];
        let f = vec![0.75, 0.25];
        let s = drift_score(&e, &f);
        assert!(s > 0.0 && s < 1.0);
    }

    #[test]
    fn hot_cold_fraction_counts() {
        let freq = vec![1.0, 1.0, 0.5, 0.0, 0.005];
        let (hot, cold) = hot_cold_fractions(&freq);
        assert!((hot - 0.4).abs() < 1e-9);
        assert!((cold - 0.4).abs() < 1e-9);
    }
}
