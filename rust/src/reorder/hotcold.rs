//! Hot–cold reordering (§3.3): permute rows in decreasing order of
//! activation frequency, measured on a calibration set. Frequently
//! activated neurons end up adjacent, so runtime selections over them
//! form larger chunks.

use crate::reorder::{activation_frequency, Permutation};

#[derive(Clone, Copy, Debug, Default)]
pub struct HotColdReorder;

impl HotColdReorder {
    /// Build the permutation from calibration importance samples.
    pub fn build(&self, samples: &[Vec<f32>], n: usize) -> Permutation {
        let freq = activation_frequency(samples, n);
        Self::from_frequency(&freq)
    }

    /// Build directly from activation frequencies (stable sort keeps
    /// original order among ties, minimizing unnecessary movement).
    pub fn from_frequency(freq: &[f64]) -> Permutation {
        let mut idx: Vec<u32> = (0..freq.len() as u32).collect();
        idx.sort_by(|&a, &b| {
            freq[b as usize]
                .total_cmp(&freq[a as usize])
                .then(a.cmp(&b))
        });
        Permutation::from_fwd(idx).expect("sorted indices are a bijection")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latency::ContiguityDistribution;
    use crate::rng::Rng;
    use crate::sparsify::{Selector, TopK};

    #[test]
    fn sorts_by_frequency_desc() {
        let freq = vec![0.1, 0.9, 0.5, 0.9];
        let p = HotColdReorder::from_frequency(&freq);
        // new layout: positions hold old rows [1, 3, 2, 0] (tie 1 before 3).
        assert_eq!(p.old_of(0), 1);
        assert_eq!(p.old_of(1), 3);
        assert_eq!(p.old_of(2), 2);
        assert_eq!(p.old_of(3), 0);
    }

    #[test]
    fn improves_contiguity_for_hot_cold_populations() {
        // Synthetic population: 30% hot neurons scattered at random
        // positions activate in (almost) every sample; the rest are cold.
        // After reordering, a top-k selection of the hot set must be one
        // near-contiguous block.
        let n = 256;
        let mut rng = Rng::new(77);
        let mut hot = vec![false; n];
        let mut placed = 0;
        while placed < 77 {
            let i = rng.below(n);
            if !hot[i] {
                hot[i] = true;
                placed += 1;
            }
        }
        let gen_sample = |rng: &mut Rng| -> Vec<f32> {
            (0..n)
                .map(|i| {
                    if hot[i] {
                        0.6 + 0.4 * rng.f32()
                    } else {
                        0.4 * rng.f32()
                    }
                })
                .collect()
        };
        let calib: Vec<Vec<f32>> = (0..40).map(|_| gen_sample(&mut rng)).collect();
        let perm = HotColdReorder.build(&calib, n);

        let table = crate::latency::LatencyTable::new(
            1024,
            (1..=64).map(|i| 50e-6 + i as f64 * 1e-6).collect(),
            1024,
        );
        let mut mean_before = 0.0;
        let mut mean_after = 0.0;
        for _ in 0..10 {
            let imp = gen_sample(&mut rng);
            let before = TopK.select(&imp, 77, &table);
            let imp_re = perm.apply(&imp);
            let after = TopK.select(&imp_re, 77, &table);
            mean_before += ContiguityDistribution::from_chunks(&before.chunks).mean_chunk();
            mean_after += ContiguityDistribution::from_chunks(&after.chunks).mean_chunk();
        }
        assert!(
            mean_after > 2.0 * mean_before,
            "reordering should cluster hot rows: before {mean_before} after {mean_after}"
        );
    }

    #[test]
    fn deterministic() {
        let freq = vec![0.3, 0.3, 0.9, 0.1];
        assert_eq!(
            HotColdReorder::from_frequency(&freq),
            HotColdReorder::from_frequency(&freq)
        );
    }
}
