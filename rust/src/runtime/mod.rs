//! Stage-graph runtime: executes the L2 compute artifacts the engine
//! invokes per layer (qkv+attention, SwiGLU gate/up, projection+residual).
//!
//! Two interchangeable backends behind the same [`XlaRuntime`] API:
//!
//! * **Host reference executor** (default) — a pure-Rust implementation of
//!   the exact semantics of `python/compile/kernels/ref.py`, keyed by the
//!   artifact *kind* recorded in the manifest. Needs no external crates
//!   and no compiled artifacts: when `artifacts/manifest.tsv` is absent it
//!   synthesizes the manifest from the runnable [`crate::model::ModelSpec`]s
//!   (same budget-bucket rule as `python/compile/model.py`).
//! * **PJRT/XLA** (`--features pjrt`) — loads the HLO-text artifacts
//!   produced by `python/compile/aot.py` and executes them on the CPU PJRT
//!   client. Requires the external `xla` crate and a built `artifacts/`
//!   directory; see `runtime/pjrt.rs`.
//!
//! Either way, Python is never on the request path.

mod exec;
mod manifest;
#[cfg(feature = "pjrt")]
mod pjrt;

pub use manifest::{ArtifactMeta, Manifest, ModelMeta};

// The host executor's scratch/output types are the engine's calling
// convention for both backends (the PJRT shim adapts onto them), so they
// are exported unconditionally — as is the per-stream operand bundle of
// the batched decode kernels.
pub use exec::{ExecScratch, StageOutputs, StreamCtx};

#[cfg(not(feature = "pjrt"))]
pub use exec::XlaRuntime;
#[cfg(feature = "pjrt")]
pub use pjrt::XlaRuntime;

/// A 2-D (or 1-D) f32 host tensor exchanged with the runtime.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Self { dims, data }
    }

    pub fn zeros(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        Self {
            dims,
            data: vec![0.0; n],
        }
    }

    pub fn rows(&self) -> usize {
        self.dims[0]
    }
}

/// A borrowed tensor handed to the runtime: shape + data slice, no copy.
/// This is how the engine feeds arena-staged activations and in-place
/// weight buffers to the executor without cloning them into [`Tensor`]s.
/// Rank is 1 or 2; rank-1 views keep the length in `dims[0]`.
#[derive(Clone, Copy, Debug)]
pub struct TensorView<'a> {
    pub dims: [usize; 2],
    pub rank: usize,
    pub data: &'a [f32],
}

impl<'a> TensorView<'a> {
    pub fn mat(rows: usize, cols: usize, data: &'a [f32]) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self {
            dims: [rows, cols],
            rank: 2,
            data,
        }
    }

    pub fn vec1(len: usize, data: &'a [f32]) -> Self {
        assert_eq!(data.len(), len);
        Self {
            dims: [len, 0],
            rank: 1,
            data,
        }
    }

    pub fn from_tensor(t: &'a Tensor) -> Self {
        assert!(!t.dims.is_empty() && t.dims.len() <= 2, "views are rank 1/2");
        if t.dims.len() == 2 {
            Self::mat(t.dims[0], t.dims[1], &t.data)
        } else {
            Self::vec1(t.dims[0], &t.data)
        }
    }

    /// Shape check against a manifest input spec.
    pub fn matches(&self, spec: &[usize]) -> bool {
        spec.len() == self.rank && spec.iter().zip(self.dims.iter()).all(|(a, b)| a == b)
    }

    /// Owned copy (allocates — for cold paths and the PJRT shim, which
    /// stages owned literals anyway; the host executor reads views in
    /// place instead).
    pub fn to_tensor(&self) -> Tensor {
        let dims = if self.rank == 1 {
            vec![self.dims[0]]
        } else {
            vec![self.dims[0], self.dims[1]]
        };
        Tensor::new(dims, self.data.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.rows(), 2);
        let z = Tensor::zeros(vec![4, 4]);
        assert_eq!(z.data.len(), 16);
    }

    #[test]
    #[should_panic]
    fn tensor_rejects_bad_len() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }
}
