//! XLA/PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the only place the compute graph touches Rust; Python is never
//! on the request path. Executables are compiled lazily on first use and
//! cached per (kind, budget-bucket).

mod manifest;

pub use manifest::{ArtifactMeta, Manifest, ModelMeta};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

/// A 2-D (or 1-D) f32 host tensor exchanged with the runtime.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Self { dims, data }
    }

    pub fn zeros(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        Self {
            dims,
            data: vec![0.0; n],
        }
    }

    pub fn rows(&self) -> usize {
        self.dims[0]
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        let dims: Vec<i64> = self.dims.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }

    fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Ok(Self::new(dims, data))
    }
}

/// PJRT CPU runtime with a lazy executable cache.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
    pub manifest: Manifest,
    execs: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl XlaRuntime {
    /// Open the artifact directory (expects `manifest.tsv` inside).
    pub fn open(artifact_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&artifact_dir.join("manifest.tsv"))
            .with_context(|| format!("loading manifest from {artifact_dir:?}"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            client,
            artifact_dir: artifact_dir.to_path_buf(),
            manifest,
            execs: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) an artifact by name.
    fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.execs.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .manifest
            .artifact(name)
            .with_context(|| format!("unknown artifact {name}"))?;
        let path = self.artifact_dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        self.execs
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile every artifact of a model (warm start for serving).
    pub fn warmup(&self, model: &str) -> Result<usize> {
        let names: Vec<String> = self
            .manifest
            .artifacts
            .iter()
            .filter(|a| a.model == model)
            .map(|a| a.name.clone())
            .collect();
        for n in &names {
            self.executable(n)?;
        }
        Ok(names.len())
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.execs.lock().unwrap().len()
    }

    /// Execute an artifact with the given inputs; validates shapes against
    /// the manifest and unwraps the output tuple.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let meta = self
            .manifest
            .artifact(name)
            .with_context(|| format!("unknown artifact {name}"))?
            .clone();
        anyhow::ensure!(
            inputs.len() == meta.inputs.len(),
            "{name}: expected {} inputs, got {}",
            meta.inputs.len(),
            inputs.len()
        );
        for (i, (t, spec)) in inputs.iter().zip(&meta.inputs).enumerate() {
            anyhow::ensure!(
                &t.dims == spec,
                "{name}: input {i} shape {:?} != manifest {:?}",
                t.dims,
                spec
            );
        }
        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        anyhow::ensure!(
            parts.len() == meta.outputs,
            "{name}: got {} outputs, manifest says {}",
            parts.len(),
            meta.outputs
        );
        parts.iter().map(Tensor::from_literal).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_dir() -> PathBuf {
        let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        assert!(
            p.join("manifest.tsv").exists(),
            "run `make artifacts` first"
        );
        p
    }

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.rows(), 2);
        let z = Tensor::zeros(vec![4, 4]);
        assert_eq!(z.data.len(), 16);
    }

    #[test]
    #[should_panic]
    fn tensor_rejects_bad_len() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn opens_and_lists_manifest() {
        let rt = XlaRuntime::open(&artifact_dir()).unwrap();
        assert!(rt.manifest.artifacts.len() >= 30);
        assert!(rt.manifest.model("tiny").is_some());
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    }

    #[test]
    fn executes_projres_matches_host_matmul() {
        let rt = XlaRuntime::open(&artifact_dir()).unwrap();
        let m = rt.manifest.model("tiny").unwrap().clone();
        let r = m.d_buckets[0]; // full bucket
        let name = format!("projres_tiny_r{r}");
        let t = m.t;
        let mut rng = crate::rng::Rng::new(3);
        let a = Tensor::new(
            vec![t, r],
            (0..t * r).map(|_| rng.normal() as f32 * 0.3).collect(),
        );
        let w = Tensor::new(
            vec![r, m.d],
            (0..r * m.d).map(|_| rng.normal() as f32 * 0.3).collect(),
        );
        let res = Tensor::new(
            vec![t, m.d],
            (0..t * m.d).map(|_| rng.normal() as f32 * 0.3).collect(),
        );
        let out = rt.execute(&name, &[a.clone(), w.clone(), res.clone()]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].dims, vec![t, m.d]);
        // Host reference.
        for ti in 0..t {
            for j in 0..m.d {
                let mut acc = res.data[ti * m.d + j] as f64;
                for k in 0..r {
                    acc += a.data[ti * r + k] as f64 * w.data[k * m.d + j] as f64;
                }
                let got = out[0].data[ti * m.d + j] as f64;
                assert!(
                    (got - acc).abs() < 1e-3,
                    "mismatch at ({ti},{j}): {got} vs {acc}"
                );
            }
        }
    }

    #[test]
    fn shape_validation_rejects_wrong_input() {
        let rt = XlaRuntime::open(&artifact_dir()).unwrap();
        let m = rt.manifest.model("tiny").unwrap().clone();
        let r = m.d_buckets[0];
        let name = format!("projres_tiny_r{r}");
        let bad = Tensor::zeros(vec![1, 1]);
        assert!(rt.execute(&name, &[bad.clone(), bad.clone(), bad]).is_err());
    }

    #[test]
    fn executable_cache_reuses() {
        let rt = XlaRuntime::open(&artifact_dir()).unwrap();
        let m = rt.manifest.model("tiny").unwrap().clone();
        let r = *m.h_buckets.last().unwrap();
        let name = format!("projres_tiny_r{r}");
        let a = Tensor::zeros(vec![m.t, r]);
        let w = Tensor::zeros(vec![r, m.d]);
        let res = Tensor::zeros(vec![m.t, m.d]);
        rt.execute(&name, &[a.clone(), w.clone(), res.clone()]).unwrap();
        let cached = rt.cached();
        rt.execute(&name, &[a, w, res]).unwrap();
        assert_eq!(rt.cached(), cached);
    }
}
