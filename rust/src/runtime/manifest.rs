//! Parser for `artifacts/manifest.tsv` — the flat mirror of
//! `manifest.json` emitted by `python/compile/aot.py` (the offline
//! environment has no JSON crate; TSV keeps the Rust side dependency-free).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

/// Model block dims as compiled (mirror of python ModelDims).
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub d: usize,
    pub h: usize,
    pub nh: usize,
    pub t: usize,
    pub c: usize,
    pub layers: usize,
    /// Budget buckets over the hidden dim, descending.
    pub d_buckets: Vec<usize>,
    /// Budget buckets over the MLP dim, descending.
    pub h_buckets: Vec<usize>,
}

impl ModelMeta {
    /// Smallest compiled bucket >= `rows` for dim buckets `bs` (ascending
    /// fallback to the largest if `rows` exceeds all buckets).
    pub fn bucket_for(bs: &[usize], rows: usize) -> usize {
        bs.iter()
            .copied()
            .filter(|&b| b >= rows)
            .min()
            .unwrap_or_else(|| bs.iter().copied().max().unwrap())
    }
}

/// One compiled artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub model: String,
    pub r: usize,
    pub t: usize,
    pub outputs: usize,
    pub inputs: Vec<Vec<usize>>,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub models: HashMap<String, ModelMeta>,
    pub artifacts: Vec<ArtifactMeta>,
    by_name: HashMap<String, usize>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?}"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut m = Manifest::default();
        for (lineno, line) in text.lines().enumerate() {
            if line.is_empty() {
                continue;
            }
            let fields: Vec<&str> = line.split('\t').collect();
            let ctx = || format!("manifest line {}", lineno + 1);
            match fields[0] {
                "model" => {
                    anyhow::ensure!(fields.len() == 10, "{}: bad model row", ctx());
                    let name = fields[1].to_string();
                    let parse_list = |s: &str| -> Result<Vec<usize>> {
                        s.split(',')
                            .map(|x| x.parse::<usize>().map_err(Into::into))
                            .collect()
                    };
                    m.models.insert(
                        name.clone(),
                        ModelMeta {
                            name,
                            d: fields[2].parse()?,
                            h: fields[3].parse()?,
                            nh: fields[4].parse()?,
                            t: fields[5].parse()?,
                            c: fields[6].parse()?,
                            layers: fields[7].parse()?,
                            d_buckets: parse_list(fields[8])?,
                            h_buckets: parse_list(fields[9])?,
                        },
                    );
                }
                "artifact" => {
                    anyhow::ensure!(fields.len() == 9, "{}: bad artifact row", ctx());
                    let inputs: Vec<Vec<usize>> = fields[8]
                        .split(';')
                        .map(|shape| {
                            if shape == "scalar" {
                                Ok(Vec::new())
                            } else {
                                shape
                                    .split(',')
                                    .map(|d| d.parse::<usize>().map_err(Into::into))
                                    .collect::<Result<Vec<usize>>>()
                            }
                        })
                        .collect::<Result<_>>()?;
                    let art = ArtifactMeta {
                        name: fields[1].to_string(),
                        file: fields[2].to_string(),
                        kind: fields[3].to_string(),
                        model: fields[4].to_string(),
                        r: fields[5].parse()?,
                        t: fields[6].parse()?,
                        outputs: fields[7].parse()?,
                        inputs,
                    };
                    m.by_name.insert(art.name.clone(), m.artifacts.len());
                    m.artifacts.push(art);
                }
                other => anyhow::bail!("{}: unknown row type {other}", ctx()),
            }
        }
        anyhow::ensure!(!m.artifacts.is_empty(), "manifest has no artifacts");
        Ok(m)
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactMeta> {
        self.by_name.get(name).map(|&i| &self.artifacts[i])
    }

    pub fn model(&self, name: &str) -> Option<&ModelMeta> {
        self.models.get(name)
    }

    /// Artifact name for a (kind, model, bucket).
    pub fn artifact_name(kind: &str, model: &str, r: usize) -> String {
        format!("{kind}_{model}_r{r}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "model\ttiny\t64\t192\t4\t8\t32\t2\t64,48,32,16\t192,144,96,64,48\nartifact\tqkv_append_tiny_r64\tqkv_append_tiny_r64.hlo.txt\tqkv_append\ttiny\t64\t8\t3\t8,64;64,64;64,64;64,64;32,64;32,64;32\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let model = m.model("tiny").unwrap();
        assert_eq!(model.d, 64);
        assert_eq!(model.d_buckets, vec![64, 48, 32, 16]);
        let a = m.artifact("qkv_append_tiny_r64").unwrap();
        assert_eq!(a.inputs.len(), 7);
        assert_eq!(a.inputs[0], vec![8, 64]);
        assert_eq!(a.inputs[6], vec![32]);
        assert_eq!(a.outputs, 3);
    }

    #[test]
    fn bucket_rounding() {
        let bs = vec![64, 48, 32, 16];
        assert_eq!(ModelMeta::bucket_for(&bs, 1), 16);
        assert_eq!(ModelMeta::bucket_for(&bs, 16), 16);
        assert_eq!(ModelMeta::bucket_for(&bs, 17), 32);
        assert_eq!(ModelMeta::bucket_for(&bs, 49), 64);
        assert_eq!(ModelMeta::bucket_for(&bs, 99), 64); // over max: clamp
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("nonsense\tfoo\n").is_err());
        assert!(Manifest::parse("").is_err());
    }

    #[test]
    fn artifact_name_format() {
        assert_eq!(
            Manifest::artifact_name("gateup", "small", 192),
            "gateup_small_r192"
        );
    }

    #[test]
    fn real_manifest_parses() {
        let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.tsv");
        if p.exists() {
            let m = Manifest::load(&p).unwrap();
            assert!(m.models.contains_key("tiny"));
            assert!(m.models.contains_key("small"));
            for a in &m.artifacts {
                assert!(
                    Path::new(env!("CARGO_MANIFEST_DIR"))
                        .join("artifacts")
                        .join(&a.file)
                        .exists(),
                    "missing {}",
                    a.file
                );
            }
        }
    }
}
