//! PJRT/XLA backend (`--features pjrt`): loads the HLO-text artifacts
//! produced by `python/compile/aot.py` and executes them on the CPU PJRT
//! client. Executables are compiled lazily on first use and cached per
//! (kind, budget-bucket).
//!
//! Builds against the compile-time stub in `vendor/xla` by default (CI
//! type-checks this backend with `cargo check --features pjrt`); actually
//! running it requires swapping in the external `xla` crate (registry
//! access) and a built `artifacts/` directory containing `manifest.tsv`
//! plus the `.hlo.txt` files.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::runtime::{ExecScratch, Manifest, StageOutputs, StreamCtx, Tensor, TensorView};

fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(&t.data);
    let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    Ok(Tensor::new(dims, data))
}

/// PJRT CPU runtime with a lazy executable cache.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
    pub manifest: Manifest,
    execs: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl XlaRuntime {
    /// Open the artifact directory (expects `manifest.tsv` inside).
    pub fn open(artifact_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&artifact_dir.join("manifest.tsv"))
            .with_context(|| format!("loading manifest from {artifact_dir:?}"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            client,
            artifact_dir: artifact_dir.to_path_buf(),
            manifest,
            execs: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) an artifact by name.
    fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.execs.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .manifest
            .artifact(name)
            .with_context(|| format!("unknown artifact {name}"))?;
        let path = self.artifact_dir.join(&meta.file);
        let proto =
            xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        self.execs
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile every artifact of a model (warm start for serving).
    pub fn warmup(&self, model: &str) -> Result<usize> {
        let names: Vec<String> = self
            .manifest
            .artifacts
            .iter()
            .filter(|a| a.model == model)
            .map(|a| a.name.clone())
            .collect();
        for n in &names {
            self.executable(n)?;
        }
        Ok(names.len())
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.execs.lock().unwrap().len()
    }

    /// Execute an artifact with the given inputs; validates shapes against
    /// the manifest and unwraps the output tuple.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let meta = self
            .manifest
            .artifact(name)
            .with_context(|| format!("unknown artifact {name}"))?
            .clone();
        anyhow::ensure!(
            inputs.len() == meta.inputs.len(),
            "{name}: expected {} inputs, got {}",
            meta.inputs.len(),
            inputs.len()
        );
        for (i, (t, spec)) in inputs.iter().zip(&meta.inputs).enumerate() {
            anyhow::ensure!(
                &t.dims == spec,
                "{name}: input {i} shape {:?} != manifest {:?}",
                t.dims,
                spec
            );
        }
        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        anyhow::ensure!(
            parts.len() == meta.outputs,
            "{name}: got {} outputs, manifest says {}",
            parts.len(),
            meta.outputs
        );
        parts.iter().map(from_literal).collect()
    }

    /// Borrowed-input execution with the engine's calling convention
    /// (same signature as the host executor's `execute_into`). The PJRT
    /// path stages owned literals anyway, so this shim copies the views
    /// into tensors and ignores `threads`/`scratch` (XLA manages its own
    /// parallelism and buffers).
    pub fn execute_into(
        &self,
        name: &str,
        inputs: &[TensorView],
        _threads: usize,
        _scratch: &mut ExecScratch,
        outs: &mut StageOutputs,
    ) -> Result<()> {
        let owned: Vec<Tensor> = inputs.iter().map(TensorView::to_tensor).collect();
        let results = self.execute(name, &owned)?;
        anyhow::ensure!(
            results.len() <= outs.out.len(),
            "{name}: {} outputs exceed the stage-output capacity {}",
            results.len(),
            outs.out.len()
        );
        outs.n = results.len();
        for (i, t) in results.into_iter().enumerate() {
            outs.dims[i] = [
                t.dims.first().copied().unwrap_or(1),
                t.dims.get(1).copied().unwrap_or(1),
            ];
            outs.out[i] = t.data;
        }
        Ok(())
    }

    /// Multi-stream decode execution with the host executor's calling
    /// convention ([`StreamCtx`] per stream, activations stacked
    /// `[n, bucket]`, outputs stacked in stream order). The fixed-shape
    /// HLO artifacts have no `[n, bucket]` entry point, so this shim runs
    /// the solo artifact once per stream — trivially bit-identical to the
    /// solo path, which is the batched kernels' contract.
    #[allow(clippy::too_many_arguments)]
    pub fn execute_batched_into(
        &self,
        name: &str,
        xs: &[f32],
        weights: &[TensorView],
        streams: &[StreamCtx],
        threads: usize,
        scratch: &mut ExecScratch,
        outs: &mut StageOutputs,
    ) -> Result<()> {
        let meta = self
            .manifest
            .artifact(name)
            .with_context(|| format!("unknown artifact {name}"))?
            .clone();
        let n = streams.len();
        anyhow::ensure!(n >= 1, "{name}: batched execution needs >= 1 stream");
        anyhow::ensure!(
            meta.t == 1,
            "{name}: batched execution covers decode stages only (t = 1)"
        );
        let bucket = meta
            .inputs
            .first()
            .and_then(|s| s.get(1))
            .copied()
            .with_context(|| format!("{name}: malformed activation input spec"))?;
        anyhow::ensure!(
            xs.len() == n * bucket,
            "{name}: stacked activations must be [n={n}, bucket={bucket}]"
        );
        let mut solo = StageOutputs::default();
        for (i, st) in streams.iter().enumerate() {
            let mut inputs: Vec<TensorView> = Vec::with_capacity(meta.inputs.len());
            inputs.push(TensorView::mat(1, bucket, &xs[i * bucket..(i + 1) * bucket]));
            inputs.extend_from_slice(weights);
            match meta.kind.as_str() {
                "qkv_decode" => {
                    let d = weights
                        .first()
                        .map(|w| w.dims[1])
                        .with_context(|| format!("{name}: missing weight inputs"))?;
                    let c = st.kmask.len();
                    inputs.push(TensorView::mat(c, d, st.kc));
                    inputs.push(TensorView::mat(c, d, st.vc));
                    inputs.push(TensorView::vec1(c, st.kmask));
                }
                "projres_dec" => {
                    let d = weights
                        .first()
                        .map(|w| w.dims[1])
                        .with_context(|| format!("{name}: missing weight inputs"))?;
                    inputs.push(TensorView::mat(1, d, st.residual));
                }
                "gateup_dec" => {}
                other => {
                    anyhow::bail!("{name}: artifact kind {other} has no batched decode path")
                }
            }
            self.execute_into(name, &inputs, threads, scratch, &mut solo)?;
            if i == 0 {
                outs.n = solo.n;
                for k in 0..solo.n {
                    outs.out[k].clear();
                    outs.dims[k] = [n, solo.dims[k][1]];
                }
            }
            for k in 0..solo.n {
                outs.out[k].extend_from_slice(&solo.out[k]);
            }
        }
        Ok(())
    }
}
