//! Host reference executor: the default runtime backend.
//!
//! Implements the exact stage semantics of `python/compile/kernels/ref.py`
//! in pure Rust, dispatching on the artifact `kind` recorded in the
//! manifest. f64 accumulation keeps dense outputs permutation-stable (the
//! engine's reorder tests compare outputs across different summation
//! orders at 1e-3 tolerance).
//!
//! ## Blocked, parallel, bit-stable kernels
//!
//! The inner loops are cache-blocked (column tiles for matmul, heads for
//! attention) and optionally fan out over `std::thread::scope` worker
//! threads. Every output element's f64 reduction runs in a fixed order —
//! ascending contraction index per output column, ascending slot per
//! attention head — regardless of tiling or thread count, so outputs are
//! **bit-identical** at any `threads` value and to the historical scalar
//! executor (the determinism integration test pins this down).
//!
//! The [`ExecScratch`]/[`StageOutputs`] pair makes the steady-state
//! execute path allocation-free: all temporaries and outputs live in
//! caller-owned buffers that are resized once during warm-up.

use std::collections::HashSet;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::model::ModelSpec;
use crate::runtime::{Manifest, Tensor, TensorView};

/// Large-negative mask value (not -inf: keeps softmax finite) — mirrors
/// `ref.py::NEG_INF`.
const NEG_INF: f64 = -1e9;

/// Column-tile width of the blocked matmul: one tile's f64 accumulators
/// for all token rows stay resident in L1.
const MATMUL_TILE: usize = 64;

/// Minimum multiply-accumulate count before a matmul fans out over
/// threads (below this, `thread::scope` setup costs more than the work).
const PAR_MIN_OPS: usize = 1 << 15;

/// Minimum score-matrix volume (`t * slots * d`) before attention fans
/// out over heads.
const PAR_MIN_ATTN: usize = 1 << 14;

/// Largest per-head dim the attention kernel's stack accumulator covers.
const MAX_HEAD_DIM: usize = 128;

/// Reference runtime with the same API as the PJRT backend.
pub struct XlaRuntime {
    pub manifest: Manifest,
    /// Names "compiled" so far (warmup/caching accounting parity with the
    /// PJRT backend's executable cache).
    compiled: Mutex<HashSet<String>>,
}

impl XlaRuntime {
    /// Open the artifact directory. If `manifest.tsv` exists it is loaded
    /// (so a PJRT-built artifact set drives the same shapes); otherwise the
    /// manifest is synthesized from the runnable model specs.
    pub fn open(artifact_dir: &Path) -> Result<Self> {
        let path = artifact_dir.join("manifest.tsv");
        let manifest = if path.exists() {
            Manifest::load(&path).with_context(|| format!("loading manifest from {path:?}"))?
        } else {
            Manifest::parse(&synthesized_manifest_tsv())?
        };
        Ok(Self {
            manifest,
            compiled: Mutex::new(HashSet::new()),
        })
    }

    pub fn platform(&self) -> String {
        "host-reference".to_string()
    }

    /// Pre-"compile" every artifact of a model (API parity; the reference
    /// executor has no real compile step).
    pub fn warmup(&self, model: &str) -> Result<usize> {
        let names: Vec<String> = self
            .manifest
            .artifacts
            .iter()
            .filter(|a| a.model == model)
            .map(|a| a.name.clone())
            .collect();
        let mut cache = self.compiled.lock().unwrap();
        for n in &names {
            cache.insert(n.clone());
        }
        Ok(names.len())
    }

    /// Number of distinct artifacts executed or warmed so far.
    pub fn cached(&self) -> usize {
        self.compiled.lock().unwrap().len()
    }

    /// Execute an artifact with the given inputs; validates shapes against
    /// the manifest. Allocating convenience wrapper over
    /// [`XlaRuntime::execute_into`] (single-threaded).
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let views: Vec<TensorView> = inputs.iter().map(TensorView::from_tensor).collect();
        let mut scratch = ExecScratch::default();
        let mut outs = StageOutputs::default();
        self.execute_into(name, &views, 1, &mut scratch, &mut outs)?;
        Ok((0..outs.n)
            .map(|i| Tensor::new(outs.dims[i].to_vec(), std::mem::take(&mut outs.out[i])))
            .collect())
    }

    /// Execute an artifact over borrowed input views, writing outputs and
    /// temporaries into caller-owned reusable buffers. `threads` bounds
    /// the kernel worker count (1 = inline, no spawning); outputs are
    /// bit-identical at every thread count.
    pub fn execute_into(
        &self,
        name: &str,
        inputs: &[TensorView],
        threads: usize,
        scratch: &mut ExecScratch,
        outs: &mut StageOutputs,
    ) -> Result<()> {
        let meta = self
            .manifest
            .artifact(name)
            .with_context(|| format!("unknown artifact {name}"))?;
        anyhow::ensure!(
            inputs.len() == meta.inputs.len(),
            "{name}: expected {} inputs, got {}",
            meta.inputs.len(),
            inputs.len()
        );
        for (i, (t, spec)) in inputs.iter().zip(&meta.inputs).enumerate() {
            anyhow::ensure!(
                t.matches(spec),
                "{name}: input {i} shape {:?} (rank {}) != manifest {:?}",
                t.dims,
                t.rank,
                spec
            );
        }
        {
            // Insert allocates the key only on the first execution;
            // steady-state serving stays allocation-free.
            let mut cache = self.compiled.lock().unwrap();
            if !cache.contains(name) {
                cache.insert(name.to_string());
            }
        }
        let model = self
            .manifest
            .model(&meta.model)
            .with_context(|| format!("{name}: unknown model {}", meta.model))?;
        let threads = threads.max(1);
        match meta.kind.as_str() {
            "qkv_append" | "qkv_decode" => {
                let (xs, wq, wk, wv, kc, vc, kmask) = (
                    &inputs[0], &inputs[1], &inputs[2], &inputs[3], &inputs[4], &inputs[5],
                    &inputs[6],
                );
                let t = xs.dims[0];
                let bucket = xs.dims[1];
                let d = wq.dims[1];
                let c = kc.dims[0];
                // Q feeds attention only; K/V are stage outputs 1 and 2.
                scratch.q.clear();
                scratch.q.resize(t * d, 0.0);
                matmul_into(xs.data, t, bucket, wq.data, d, &mut scratch.q, &mut scratch.acc, threads);
                outs.out[1].clear();
                outs.out[1].resize(t * d, 0.0);
                matmul_into(xs.data, t, bucket, wk.data, d, &mut outs.out[1], &mut scratch.acc, threads);
                outs.out[2].clear();
                outs.out[2].resize(t * d, 0.0);
                matmul_into(xs.data, t, bucket, wv.data, d, &mut outs.out[2], &mut scratch.acc, threads);
                // keys/vals = concat(cache, new); mask = concat(mask, 1s).
                scratch.keys.clear();
                scratch.keys.extend_from_slice(kc.data);
                scratch.keys.extend_from_slice(&outs.out[1]);
                scratch.vals.clear();
                scratch.vals.extend_from_slice(vc.data);
                scratch.vals.extend_from_slice(&outs.out[2]);
                scratch.mask.clear();
                scratch.mask.extend_from_slice(kmask.data);
                scratch.mask.resize(c + t, 1.0);
                outs.out[0].clear();
                outs.out[0].resize(t * d, 0.0);
                mha_attention_into(
                    &scratch.q,
                    &scratch.keys,
                    &scratch.vals,
                    &scratch.mask,
                    t,
                    c + t,
                    d,
                    model.nh,
                    &mut scratch.scores,
                    &mut outs.out[0],
                    threads,
                );
                outs.dims[0] = [t, d];
                outs.dims[1] = [t, d];
                outs.dims[2] = [t, d];
                outs.n = 3;
            }
            "gateup" | "gateup_dec" => {
                let (xs, wg, wu) = (&inputs[0], &inputs[1], &inputs[2]);
                let t = xs.dims[0];
                let bucket = xs.dims[1];
                let h = wg.dims[1];
                outs.out[0].clear();
                outs.out[0].resize(t * h, 0.0);
                matmul_into(xs.data, t, bucket, wg.data, h, &mut outs.out[0], &mut scratch.acc, threads);
                scratch.tmp.clear();
                scratch.tmp.resize(t * h, 0.0);
                matmul_into(xs.data, t, bucket, wu.data, h, &mut scratch.tmp, &mut scratch.acc, threads);
                swiglu_into(&mut outs.out[0], &scratch.tmp, threads);
                outs.dims[0] = [t, h];
                outs.n = 1;
            }
            "projres" | "projres_dec" => {
                let (xs, w, res) = (&inputs[0], &inputs[1], &inputs[2]);
                let t = xs.dims[0];
                let bucket = xs.dims[1];
                let d = w.dims[1];
                outs.out[0].clear();
                outs.out[0].resize(t * d, 0.0);
                matmul_into(xs.data, t, bucket, w.data, d, &mut outs.out[0], &mut scratch.acc, threads);
                for (o, &rv) in outs.out[0].iter_mut().zip(res.data) {
                    *o += rv;
                }
                outs.dims[0] = [t, d];
                outs.n = 1;
            }
            other => anyhow::bail!("{name}: unknown artifact kind {other}"),
        }
        anyhow::ensure!(
            outs.n == meta.outputs,
            "{name}: produced {} outputs, manifest says {}",
            outs.n,
            meta.outputs
        );
        Ok(())
    }
}

impl XlaRuntime {
    /// Multi-stream decode execution: run a decode-stage artifact (t = 1)
    /// for `streams.len()` streams that **share the weight tile**, with
    /// their activation rows stacked `[n, bucket]` in `xs`. Outputs land
    /// stacked `[n, ·]` in stream order.
    ///
    /// Matmul rows are computed independently, each in the same f64
    /// reduction order as the solo path, and attention runs per stream
    /// over its own KV operands, so every stream's output rows are
    /// **bit-identical** to `n` solo [`XlaRuntime::execute_into`] calls —
    /// at any thread count. This is what lets the batch decode driver run
    /// one kernel dispatch per weight tile instead of one per stream
    /// without perturbing a single bit of any stream's output.
    ///
    /// `weights` are the artifact's shared weight inputs (3 for
    /// `qkv_decode`, 2 for `gateup_dec`, 1 for `projres_dec`), validated
    /// against the manifest; per-stream operands (KV caches, residual
    /// rows) arrive in `streams`.
    #[allow(clippy::too_many_arguments)]
    pub fn execute_batched_into(
        &self,
        name: &str,
        xs: &[f32],
        weights: &[TensorView],
        streams: &[StreamCtx],
        threads: usize,
        scratch: &mut ExecScratch,
        outs: &mut StageOutputs,
    ) -> Result<()> {
        let meta = self
            .manifest
            .artifact(name)
            .with_context(|| format!("unknown artifact {name}"))?;
        let n = streams.len();
        anyhow::ensure!(n >= 1, "{name}: batched execution needs >= 1 stream");
        anyhow::ensure!(
            meta.t == 1,
            "{name}: batched execution covers decode stages only (t = 1)"
        );
        let expected_weights = match meta.kind.as_str() {
            "qkv_decode" => 3,
            "gateup_dec" => 2,
            "projres_dec" => 1,
            other => anyhow::bail!("{name}: artifact kind {other} has no batched decode path"),
        };
        anyhow::ensure!(
            weights.len() == expected_weights,
            "{name}: expected {expected_weights} shared weight inputs, got {}",
            weights.len()
        );
        for (i, (w, spec)) in weights.iter().zip(meta.inputs.iter().skip(1)).enumerate() {
            anyhow::ensure!(
                w.matches(spec),
                "{name}: weight {i} shape {:?} != manifest {:?}",
                w.dims,
                spec
            );
        }
        let bucket = meta.inputs[0][1];
        anyhow::ensure!(
            xs.len() == n * bucket,
            "{name}: stacked activations must be [n={n}, bucket={bucket}]"
        );
        {
            let mut cache = self.compiled.lock().unwrap();
            if !cache.contains(name) {
                cache.insert(name.to_string());
            }
        }
        let model = self
            .manifest
            .model(&meta.model)
            .with_context(|| format!("{name}: unknown model {}", meta.model))?;
        let threads = threads.max(1);
        match meta.kind.as_str() {
            "qkv_decode" => {
                let (wq, wk, wv) = (&weights[0], &weights[1], &weights[2]);
                let d = wq.dims[1];
                scratch.q.clear();
                scratch.q.resize(n * d, 0.0);
                matmul_into(xs, n, bucket, wq.data, d, &mut scratch.q, &mut scratch.acc, threads);
                outs.out[1].clear();
                outs.out[1].resize(n * d, 0.0);
                matmul_into(xs, n, bucket, wk.data, d, &mut outs.out[1], &mut scratch.acc, threads);
                outs.out[2].clear();
                outs.out[2].resize(n * d, 0.0);
                matmul_into(xs, n, bucket, wv.data, d, &mut outs.out[2], &mut scratch.acc, threads);
                outs.out[0].clear();
                outs.out[0].resize(n * d, 0.0);
                for (i, st) in streams.iter().enumerate() {
                    let c = st.kmask.len();
                    anyhow::ensure!(
                        st.kc.len() == c * d && st.vc.len() == c * d,
                        "{name}: stream {i} KV operands must be [c={c}, d={d}]"
                    );
                    scratch.keys.clear();
                    scratch.keys.extend_from_slice(st.kc);
                    scratch.keys.extend_from_slice(&outs.out[1][i * d..(i + 1) * d]);
                    scratch.vals.clear();
                    scratch.vals.extend_from_slice(st.vc);
                    scratch.vals.extend_from_slice(&outs.out[2][i * d..(i + 1) * d]);
                    scratch.mask.clear();
                    scratch.mask.extend_from_slice(st.kmask);
                    scratch.mask.resize(c + 1, 1.0);
                    mha_attention_into(
                        &scratch.q[i * d..(i + 1) * d],
                        &scratch.keys,
                        &scratch.vals,
                        &scratch.mask,
                        1,
                        c + 1,
                        d,
                        model.nh,
                        &mut scratch.scores,
                        &mut outs.out[0][i * d..(i + 1) * d],
                        threads,
                    );
                }
                outs.dims[0] = [n, d];
                outs.dims[1] = [n, d];
                outs.dims[2] = [n, d];
                outs.n = 3;
            }
            "gateup_dec" => {
                let (wg, wu) = (&weights[0], &weights[1]);
                let h = wg.dims[1];
                outs.out[0].clear();
                outs.out[0].resize(n * h, 0.0);
                matmul_into(xs, n, bucket, wg.data, h, &mut outs.out[0], &mut scratch.acc, threads);
                scratch.tmp.clear();
                scratch.tmp.resize(n * h, 0.0);
                matmul_into(xs, n, bucket, wu.data, h, &mut scratch.tmp, &mut scratch.acc, threads);
                swiglu_into(&mut outs.out[0], &scratch.tmp, threads);
                outs.dims[0] = [n, h];
                outs.n = 1;
            }
            "projres_dec" => {
                let w = &weights[0];
                let d = w.dims[1];
                outs.out[0].clear();
                outs.out[0].resize(n * d, 0.0);
                matmul_into(xs, n, bucket, w.data, d, &mut outs.out[0], &mut scratch.acc, threads);
                for (i, st) in streams.iter().enumerate() {
                    anyhow::ensure!(
                        st.residual.len() == d,
                        "{name}: stream {i} residual must be [d={d}]"
                    );
                    for (o, &rv) in outs.out[0][i * d..(i + 1) * d].iter_mut().zip(st.residual) {
                        *o += rv;
                    }
                }
                outs.dims[0] = [n, d];
                outs.n = 1;
            }
            _ => unreachable!("kind validated above"),
        }
        Ok(())
    }
}

/// Per-stream operands of one batched decode-stage execution: the weight
/// tile is shared across the batch, these are the operands that differ
/// per stream. Unused operands stay empty (`gateup_dec` needs none).
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamCtx<'a> {
    /// Cached keys `[c, d]` (qkv stages only).
    pub kc: &'a [f32],
    /// Cached values `[c, d]` (qkv stages only).
    pub vc: &'a [f32],
    /// Cache validity mask `[c]` (qkv stages only).
    pub kmask: &'a [f32],
    /// Residual row `[d]` (projres stages only).
    pub residual: &'a [f32],
}

/// Reusable executor working memory. All kernel temporaries live here so
/// the steady-state execute path performs no heap allocations (buffers
/// grow to their high-water mark during warm-up, then stabilize).
#[derive(Clone, Debug, Default)]
pub struct ExecScratch {
    /// Blocked-matmul f64 accumulator (single-thread path).
    acc: Vec<f64>,
    /// Q projection (attention input).
    q: Vec<f32>,
    /// Concatenated cache + new keys.
    keys: Vec<f32>,
    /// Concatenated cache + new values.
    vals: Vec<f32>,
    /// Concatenated validity mask.
    mask: Vec<f32>,
    /// Per-head attention score rows (`nh * slots`).
    scores: Vec<f64>,
    /// Second matmul output (up-projection).
    tmp: Vec<f32>,
}

impl ExecScratch {
    /// Pre-reserve worst-case kernel temporaries for a `t`-row dispatch
    /// over hidden dim `d`, MLP dim `h`, `slots` KV cache slots and `nh`
    /// attention heads. `reserve` is a no-op once capacity suffices, so
    /// callers that must stay allocation-free (the batch decode arena)
    /// can bound these buffers up front instead of relying on a warm-up
    /// dispatch of every shape.
    pub fn reserve(&mut self, t: usize, d: usize, h: usize, slots: usize, nh: usize) {
        self.acc.reserve(t * MATMUL_TILE);
        self.q.reserve(t * d);
        self.keys.reserve((slots + t) * d);
        self.vals.reserve((slots + t) * d);
        self.mask.reserve(slots + t);
        self.scores.reserve(nh * (slots + t));
        self.tmp.reserve(t * h);
    }
}

/// Reusable stage outputs: up to three output buffers plus their shapes.
#[derive(Clone, Debug, Default)]
pub struct StageOutputs {
    pub out: [Vec<f32>; 3],
    pub dims: [[usize; 2]; 3],
    /// Number of valid outputs for the last executed stage.
    pub n: usize,
}

/// Raw pointer wrapper that is Send/Sync; used for disjoint-range writes
/// from scoped worker threads (same pattern as `storage::real`).
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// `out[t, n] = a[t, r] @ b[r, n]` with f64 accumulation, cache-blocked
/// over [`MATMUL_TILE`]-wide column tiles and optionally parallel over
/// tiles. Every output element's reduction runs over `k` ascending with
/// the same zero-skip as the scalar reference executor, so results are
/// bit-identical at any tile split or thread count.
pub(crate) fn matmul_into(
    a: &[f32],
    t: usize,
    r: usize,
    b: &[f32],
    n: usize,
    out: &mut [f32],
    acc: &mut Vec<f64>,
    threads: usize,
) {
    assert_eq!(a.len(), t * r, "matmul lhs shape");
    assert_eq!(b.len(), r * n, "matmul rhs shape");
    assert_eq!(out.len(), t * n, "matmul out shape");
    if t == 0 || n == 0 {
        return;
    }
    let tiles = n.div_ceil(MATMUL_TILE);
    if threads <= 1 || tiles < 2 || t * r * n < PAR_MIN_OPS {
        acc.clear();
        acc.resize(t * MATMUL_TILE, 0.0);
        let out_ptr = out.as_mut_ptr();
        for tile in 0..tiles {
            // Safety: single caller, in-bounds tile ranges of `out`.
            unsafe { matmul_tile(a, t, r, b, n, tile, acc, out_ptr) };
        }
        return;
    }
    let workers = threads.min(tiles);
    let out_ptr = SendPtr(out.as_mut_ptr());
    std::thread::scope(|s| {
        for w in 0..workers {
            let ptr = out_ptr;
            s.spawn(move || {
                let mut acc = vec![0.0f64; t * MATMUL_TILE];
                let mut tile = w;
                while tile < tiles {
                    // Safety: tiles are disjoint column ranges of `out`;
                    // each thread writes only its own tiles and `out`
                    // outlives the scope.
                    unsafe { matmul_tile(a, t, r, b, n, tile, &mut acc, ptr.0) };
                    tile += workers;
                }
            });
        }
    });
}

/// One column tile of the blocked matmul. `out` is written through a raw
/// pointer so parallel callers can share the buffer across disjoint
/// tiles.
///
/// Safety: the caller must guarantee `out` points to a live `[t, n]`
/// buffer and that no other thread touches columns
/// `[tile*MATMUL_TILE, (tile+1)*MATMUL_TILE)` concurrently.
unsafe fn matmul_tile(
    a: &[f32],
    t: usize,
    r: usize,
    b: &[f32],
    n: usize,
    tile: usize,
    acc: &mut [f64],
    out: *mut f32,
) {
    let j0 = tile * MATMUL_TILE;
    let j1 = (j0 + MATMUL_TILE).min(n);
    let tw = j1 - j0;
    let acc = &mut acc[..t * tw];
    acc.fill(0.0);
    for k in 0..r {
        let brow = &b[k * n + j0..k * n + j1];
        for ti in 0..t {
            let av = a[ti * r + k];
            if av == 0.0 {
                continue; // zero-padded budget rows contribute nothing
            }
            axpy_row(&mut acc[ti * tw..(ti + 1) * tw], brow, av as f64);
        }
    }
    for ti in 0..t {
        for e in 0..tw {
            *out.add(ti * n + j0 + e) = acc[ti * tw + e] as f32;
        }
    }
}

/// `acc[j] += s * b[j]` across one row — the innermost axpy of both the
/// blocked matmul and the attention value accumulation. Every output
/// element is independent and computed by exactly one mul + one add, so
/// the lane-blocked form below performs the identical operation in the
/// identical order per element: results are **bit-identical** with or
/// without the `simd` feature (the fallback contract DESIGN.md §12
/// documents). Reductions *across* elements (e.g. the q·k dot) are never
/// vectorized — reassociating a sum would change its rounding.
#[cfg(feature = "simd")]
#[inline(always)]
fn axpy_row(acc: &mut [f64], b: &[f32], s: f64) {
    const LANES: usize = 8;
    debug_assert_eq!(acc.len(), b.len());
    let blocks = acc.len() / LANES * LANES;
    let (ah, at) = acc.split_at_mut(blocks);
    let (bh, bt) = b.split_at(blocks);
    for (ac, bc) in ah.chunks_exact_mut(LANES).zip(bh.chunks_exact(LANES)) {
        // Fixed-width lane block with no cross-lane dependency: LLVM
        // lowers this to packed f64 mul/add (f32x8 widened) on AVX/NEON.
        for l in 0..LANES {
            ac[l] += s * bc[l] as f64;
        }
    }
    for (a, &v) in at.iter_mut().zip(bt) {
        *a += s * v as f64;
    }
}

#[cfg(not(feature = "simd"))]
#[inline(always)]
fn axpy_row(acc: &mut [f64], b: &[f32], s: f64) {
    for (a, &v) in acc.iter_mut().zip(b) {
        *a += s * v as f64;
    }
}

fn silu(x: f64) -> f64 {
    x / (1.0 + (-x).exp())
}

/// Elementwise `gate[i] = silu(gate[i]) * up[i]` over one slice; the
/// lane-blocked variant keeps per-element math identical (see
/// [`axpy_row`] for the bit-identity argument).
#[cfg(feature = "simd")]
#[inline(always)]
fn swiglu_slice(gate: &mut [f32], up: &[f32]) {
    const LANES: usize = 8;
    let blocks = gate.len() / LANES * LANES;
    let (gh, gt) = gate.split_at_mut(blocks);
    let (uh, ut) = up.split_at(blocks);
    for (gc, uc) in gh.chunks_exact_mut(LANES).zip(uh.chunks_exact(LANES)) {
        for l in 0..LANES {
            gc[l] = (silu(gc[l] as f64) * uc[l] as f64) as f32;
        }
    }
    for (g, &u) in gt.iter_mut().zip(ut) {
        *g = (silu(*g as f64) * u as f64) as f32;
    }
}

#[cfg(not(feature = "simd"))]
#[inline(always)]
fn swiglu_slice(gate: &mut [f32], up: &[f32]) {
    for (g, &u) in gate.iter_mut().zip(up) {
        *g = (silu(*g as f64) * u as f64) as f32;
    }
}

/// `gate[i] = silu(gate[i]) * up[i]` in f64, elementwise — optionally
/// parallel over even splits (bit-identical: per-element math is
/// independent of the split).
fn swiglu_into(gate: &mut [f32], up: &[f32], threads: usize) {
    assert_eq!(gate.len(), up.len(), "swiglu operand shapes");
    let n = gate.len();
    if threads <= 1 || n < 4096 {
        swiglu_slice(gate, up);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (gs, us) in gate.chunks_mut(chunk).zip(up.chunks(chunk)) {
            s.spawn(move || swiglu_slice(gs, us));
        }
    });
}

/// Multi-head attention of `t` query tokens over `s` key/value slots —
/// mirror of `ref.py::mha_attention` (max-subtracted softmax), blocked
/// and optionally parallel over heads. Heads are fully independent and
/// each head's math is identical at any thread count, so outputs are
/// bit-identical to the serial executor.
#[allow(clippy::too_many_arguments)]
pub(crate) fn mha_attention_into(
    q: &[f32],
    keys: &[f32],
    vals: &[f32],
    mask: &[f32],
    t: usize,
    s: usize,
    d: usize,
    nh: usize,
    scores: &mut Vec<f64>,
    out: &mut [f32],
    threads: usize,
) {
    assert_eq!(d % nh, 0, "head split {d} % {nh}");
    assert_eq!(out.len(), t * d, "attention out shape");
    let hd = d / nh;
    assert!(hd <= MAX_HEAD_DIM, "head dim {hd} exceeds {MAX_HEAD_DIM}");
    scores.clear();
    scores.resize(nh * s, 0.0);
    if threads <= 1 || nh < 2 || t * s * d < PAR_MIN_ATTN {
        let out_ptr = out.as_mut_ptr();
        for (h, sc) in scores.chunks_mut(s).enumerate() {
            // Safety: single caller, heads write disjoint columns.
            unsafe { attn_head(q, keys, vals, mask, t, s, d, hd, h, sc, out_ptr) };
        }
        return;
    }
    let workers = threads.min(nh);
    let per = nh.div_ceil(workers);
    let out_ptr = SendPtr(out.as_mut_ptr());
    std::thread::scope(|sp| {
        for (wi, block) in scores.chunks_mut(per * s).enumerate() {
            let ptr = out_ptr;
            sp.spawn(move || {
                for (e, sc) in block.chunks_mut(s).enumerate() {
                    let h = wi * per + e;
                    // Safety: each head owns a disjoint column range of
                    // `out`, which outlives the scope.
                    unsafe { attn_head(q, keys, vals, mask, t, s, d, hd, h, sc, ptr.0) };
                }
            });
        }
    });
}

/// One attention head (exact `ref.py` math, f64 throughout).
///
/// Safety: the caller must guarantee `out` points to a live `[t, d]`
/// buffer and that no other thread touches head `h`'s columns
/// `[h*hd, (h+1)*hd)` concurrently.
#[allow(clippy::too_many_arguments)]
unsafe fn attn_head(
    q: &[f32],
    keys: &[f32],
    vals: &[f32],
    mask: &[f32],
    t: usize,
    s: usize,
    d: usize,
    hd: usize,
    h: usize,
    scores: &mut [f64],
    out: *mut f32,
) {
    debug_assert_eq!(scores.len(), s);
    let off = h * hd;
    let scale = 1.0 / (hd as f64).sqrt();
    let mut acc = [0.0f64; MAX_HEAD_DIM];
    for ti in 0..t {
        let qrow = &q[ti * d + off..ti * d + off + hd];
        let mut max = f64::MIN;
        for (j, sc) in scores.iter_mut().enumerate() {
            let krow = &keys[j * d + off..j * d + off + hd];
            let dot: f64 = qrow
                .iter()
                .zip(krow)
                .map(|(&a, &b)| a as f64 * b as f64)
                .sum();
            let v = dot * scale + (1.0 - mask[j] as f64) * NEG_INF;
            *sc = v;
            max = max.max(v);
        }
        let mut denom = 0.0f64;
        for sc in scores.iter_mut() {
            *sc = (*sc - max).exp();
            denom += *sc;
        }
        let accs = &mut acc[..hd];
        accs.fill(0.0);
        for (j, &p) in scores.iter().enumerate() {
            let vrow = &vals[j * d + off..j * d + off + hd];
            axpy_row(accs, vrow, p / denom);
        }
        for (e, &v) in accs.iter().enumerate() {
            *out.add(ti * d + off + e) = v as f32;
        }
    }
}

// ------------------------------------------------- manifest synthesis

/// Round half-to-even (Python `round` semantics — the bucket grid depends
/// on it: `round(192*0.375/16) = round(4.5) = 4`).
fn round_half_even(x: f64) -> i64 {
    let floor = x.floor();
    let frac = x - floor;
    let f = floor as i64;
    if frac > 0.5 {
        f + 1
    } else if frac < 0.5 {
        f
    } else if f % 2 == 0 {
        f
    } else {
        f + 1
    }
}

/// Budget buckets over dim `n` — mirror of `python/compile/model.py
/// ModelDims.buckets`.
pub fn budget_buckets(n: usize) -> Vec<usize> {
    let fractions = [1.0, 0.75, 0.5, 0.375, 0.25];
    let mut out = Vec::new();
    for f in fractions {
        let r = (round_half_even(n as f64 * f / 16.0) * 16).max(16) as usize;
        let r = r.min(n);
        if !out.contains(&r) {
            out.push(r);
        }
    }
    out
}

/// Build the manifest TSV for all runnable models — the same rows
/// `python/compile/aot.py` writes, minus the (unneeded) HLO files.
pub fn synthesized_manifest_tsv() -> String {
    let mut tsv = String::new();
    for spec in [ModelSpec::tiny(), ModelSpec::small(), ModelSpec::base()] {
        let (name, d, h, t, c) = (
            spec.name.clone(),
            spec.d,
            spec.h,
            spec.tokens_per_frame,
            spec.cache_slots,
        );
        let d_buckets = budget_buckets(d);
        let h_buckets = budget_buckets(h);
        let list = |b: &[usize]| {
            b.iter()
                .map(|x| x.to_string())
                .collect::<Vec<_>>()
                .join(",")
        };
        tsv.push_str(&format!(
            "model\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\n",
            name,
            d,
            h,
            spec.nh,
            t,
            c,
            spec.layers,
            list(&d_buckets),
            list(&h_buckets)
        ));
        let shapes = |dims: &[Vec<usize>]| {
            dims.iter()
                .map(|s| {
                    if s.is_empty() {
                        "scalar".to_string()
                    } else {
                        s.iter()
                            .map(|x| x.to_string())
                            .collect::<Vec<_>>()
                            .join(",")
                    }
                })
                .collect::<Vec<_>>()
                .join(";")
        };
        let mut artifact =
            |kind: &str, r: usize, tt: usize, outputs: usize, inputs: &[Vec<usize>]| {
                let aname = Manifest::artifact_name(kind, &name, r);
                tsv.push_str(&format!(
                    "artifact\t{}\t{}.hlo.txt\t{}\t{}\t{}\t{}\t{}\t{}\n",
                    aname,
                    aname,
                    kind,
                    name,
                    r,
                    tt,
                    outputs,
                    shapes(inputs)
                ));
            };
        for &r in &d_buckets {
            for (tt, stage) in [(t, "qkv_append"), (1, "qkv_decode")] {
                artifact(
                    stage,
                    r,
                    tt,
                    3,
                    &[
                        vec![tt, r],
                        vec![r, d],
                        vec![r, d],
                        vec![r, d],
                        vec![c, d],
                        vec![c, d],
                        vec![c],
                    ],
                );
            }
            for (tt, stage) in [(t, "gateup"), (1, "gateup_dec")] {
                artifact(stage, r, tt, 1, &[vec![tt, r], vec![r, h], vec![r, h]]);
            }
        }
        let mut proj: Vec<usize> = d_buckets
            .iter()
            .chain(h_buckets.iter())
            .copied()
            .collect();
        proj.sort_unstable();
        proj.dedup();
        for &r in &proj {
            for (tt, stage) in [(t, "projres"), (1, "projres_dec")] {
                artifact(stage, r, tt, 1, &[vec![tt, r], vec![r, d], vec![tt, d]]);
            }
        }
    }
    tsv
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn rt() -> XlaRuntime {
        // Any directory without a manifest.tsv falls back to synthesis.
        XlaRuntime::open(&PathBuf::from("artifacts")).unwrap()
    }

    #[test]
    fn axpy_and_swiglu_match_scalar_reference() {
        // Bit-identity of the (possibly lane-blocked) kernels against the
        // plain scalar loop — the `simd` feature must be invisible in
        // outputs. Odd length exercises the remainder tail.
        let n = 53;
        let b: Vec<f32> = (0..n).map(|i| ((i * 37 % 101) as f32 - 50.0) * 0.173).collect();
        let mut acc = vec![0.25f64; n];
        let mut reference = acc.clone();
        axpy_row(&mut acc, &b, -1.375);
        for (r, &v) in reference.iter_mut().zip(&b) {
            *r += -1.375 * v as f64;
        }
        assert_eq!(acc, reference);

        let mut gate: Vec<f32> = b.iter().map(|&v| v * 0.5).collect();
        let mut gate_ref = gate.clone();
        swiglu_slice(&mut gate, &b);
        for (g, &u) in gate_ref.iter_mut().zip(&b) {
            *g = (silu(*g as f64) * u as f64) as f32;
        }
        assert_eq!(gate, gate_ref);
    }

    #[test]
    fn buckets_match_python_grid() {
        // Mirrors ModelDims.buckets incl. the round-half-even tie at
        // 192 * 0.375 / 16 = 4.5.
        assert_eq!(budget_buckets(64), vec![64, 48, 32, 16]);
        assert_eq!(budget_buckets(192), vec![192, 144, 96, 64, 48]);
        assert_eq!(budget_buckets(256), vec![256, 192, 128, 96, 64]);
        assert_eq!(budget_buckets(768), vec![768, 576, 384, 288, 192]);
    }

    #[test]
    fn opens_and_lists_manifest() {
        let rt = rt();
        assert!(rt.manifest.artifacts.len() >= 30);
        assert!(rt.manifest.model("tiny").is_some());
        assert!(rt.manifest.model("small").is_some());
        assert!(rt.manifest.model("base").is_some());
        assert_eq!(rt.platform(), "host-reference");
    }

    #[test]
    fn executes_projres_matches_host_matmul() {
        let rt = rt();
        let m = rt.manifest.model("tiny").unwrap().clone();
        let r = m.d_buckets[0];
        let name = format!("projres_tiny_r{r}");
        let t = m.t;
        let mut rng = crate::rng::Rng::new(3);
        let a = Tensor::new(
            vec![t, r],
            (0..t * r).map(|_| rng.normal() as f32 * 0.3).collect(),
        );
        let w = Tensor::new(
            vec![r, m.d],
            (0..r * m.d).map(|_| rng.normal() as f32 * 0.3).collect(),
        );
        let res = Tensor::new(
            vec![t, m.d],
            (0..t * m.d).map(|_| rng.normal() as f32 * 0.3).collect(),
        );
        let out = rt
            .execute(&name, &[a.clone(), w.clone(), res.clone()])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].dims, vec![t, m.d]);
        for ti in 0..t {
            for j in 0..m.d {
                let mut acc = res.data[ti * m.d + j] as f64;
                for k in 0..r {
                    acc += a.data[ti * r + k] as f64 * w.data[k * m.d + j] as f64;
                }
                let got = out[0].data[ti * m.d + j] as f64;
                assert!(
                    (got - acc).abs() < 1e-3,
                    "mismatch at ({ti},{j}): {got} vs {acc}"
                );
            }
        }
    }

    #[test]
    fn gateup_matches_silu_formula() {
        let rt = rt();
        let m = rt.manifest.model("tiny").unwrap().clone();
        let r = *m.d_buckets.last().unwrap();
        let name = format!("gateup_dec_tiny_r{r}");
        let xs = Tensor::new(vec![1, r], (0..r).map(|i| 0.01 * i as f32).collect());
        let wg = Tensor::new(vec![r, m.h], vec![0.02; r * m.h]);
        let wu = Tensor::new(vec![r, m.h], vec![0.03; r * m.h]);
        let out = rt.execute(&name, &[xs.clone(), wg, wu]).unwrap();
        let g: f64 = xs.data.iter().map(|&x| x as f64 * 0.02).sum();
        let u: f64 = xs.data.iter().map(|&x| x as f64 * 0.03).sum();
        let want = (g / (1.0 + (-g).exp())) * u;
        assert!(
            (out[0].data[0] as f64 - want).abs() < 1e-4,
            "{} vs {want}",
            out[0].data[0]
        );
    }

    #[test]
    fn masked_cache_slots_are_ignored() {
        let rt = rt();
        let m = rt.manifest.model("tiny").unwrap().clone();
        let r = m.d_buckets[0];
        let name = format!("qkv_append_tiny_r{r}");
        let mut rng = crate::rng::Rng::new(7);
        let xs = Tensor::new(
            vec![m.t, r],
            (0..m.t * r).map(|_| rng.normal() as f32 * 0.2).collect(),
        );
        let w = |seed: u64| {
            let mut rng = crate::rng::Rng::new(seed);
            Tensor::new(
                vec![r, m.d],
                (0..r * m.d).map(|_| rng.normal() as f32 * 0.2).collect(),
            )
        };
        let (wq, wk, wv) = (w(1), w(2), w(3));
        let mask = Tensor::zeros(vec![m.c]);
        let clean = rt
            .execute(
                &name,
                &[
                    xs.clone(),
                    wq.clone(),
                    wk.clone(),
                    wv.clone(),
                    Tensor::zeros(vec![m.c, m.d]),
                    Tensor::zeros(vec![m.c, m.d]),
                    mask.clone(),
                ],
            )
            .unwrap();
        // Garbage in masked cache slots must not change the output.
        let garbage = Tensor::new(vec![m.c, m.d], vec![7.5; m.c * m.d]);
        let dirty = rt
            .execute(
                &name,
                &[xs, wq, wk, wv, garbage.clone(), garbage, mask],
            )
            .unwrap();
        for (a, b) in clean[0].data.iter().zip(&dirty[0].data) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn batched_decode_rows_match_solo_execution() {
        let rt = rt();
        let m = rt.manifest.model("tiny").unwrap().clone();
        let r = m.d_buckets[1];
        let n = 3usize;
        let mut rng = crate::rng::Rng::new(11);
        let mut fill = |len: usize| -> Vec<f32> {
            (0..len).map(|_| rng.normal() as f32 * 0.2).collect()
        };
        let xs = fill(n * r);
        // --- gateup_dec: stacked rows == per-row solo runs ---
        let wg = fill(r * m.h);
        let wu = fill(r * m.h);
        let name = format!("gateup_dec_tiny_r{r}");
        let weights = [
            TensorView::mat(r, m.h, &wg),
            TensorView::mat(r, m.h, &wu),
        ];
        let streams = vec![StreamCtx::default(); n];
        let mut scratch = ExecScratch::default();
        let mut outs = StageOutputs::default();
        rt.execute_batched_into(&name, &xs, &weights, &streams, 2, &mut scratch, &mut outs)
            .unwrap();
        assert_eq!(outs.n, 1);
        assert_eq!(outs.dims[0], [n, m.h]);
        for i in 0..n {
            let solo = rt
                .execute(
                    &name,
                    &[
                        Tensor::new(vec![1, r], xs[i * r..(i + 1) * r].to_vec()),
                        Tensor::new(vec![r, m.h], wg.clone()),
                        Tensor::new(vec![r, m.h], wu.clone()),
                    ],
                )
                .unwrap();
            assert_eq!(
                &outs.out[0][i * m.h..(i + 1) * m.h],
                solo[0].data.as_slice(),
                "gateup stream {i} diverged"
            );
        }
        // --- projres_dec: per-stream residuals ---
        let w = fill(r * m.d);
        let residuals: Vec<Vec<f32>> = (0..n).map(|_| fill(m.d)).collect();
        let name = format!("projres_dec_tiny_r{r}");
        let weights = [TensorView::mat(r, m.d, &w)];
        let streams: Vec<StreamCtx> = residuals
            .iter()
            .map(|res| StreamCtx {
                residual: res,
                ..StreamCtx::default()
            })
            .collect();
        rt.execute_batched_into(&name, &xs, &weights, &streams, 1, &mut scratch, &mut outs)
            .unwrap();
        for i in 0..n {
            let solo = rt
                .execute(
                    &name,
                    &[
                        Tensor::new(vec![1, r], xs[i * r..(i + 1) * r].to_vec()),
                        Tensor::new(vec![r, m.d], w.clone()),
                        Tensor::new(vec![1, m.d], residuals[i].clone()),
                    ],
                )
                .unwrap();
            assert_eq!(
                &outs.out[0][i * m.d..(i + 1) * m.d],
                solo[0].data.as_slice(),
                "projres stream {i} diverged"
            );
        }
        // --- qkv_decode: per-stream KV caches ---
        let (wq, wk, wv) = (fill(r * m.d), fill(r * m.d), fill(r * m.d));
        let kvs: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = (0..n)
            .map(|i| {
                let mut mask = vec![0.0f32; m.c];
                for s in mask.iter_mut().take(i + 1) {
                    *s = 1.0;
                }
                (fill(m.c * m.d), fill(m.c * m.d), mask)
            })
            .collect();
        let name = format!("qkv_decode_tiny_r{r}");
        let weights = [
            TensorView::mat(r, m.d, &wq),
            TensorView::mat(r, m.d, &wk),
            TensorView::mat(r, m.d, &wv),
        ];
        let streams: Vec<StreamCtx> = kvs
            .iter()
            .map(|(kc, vc, mask)| StreamCtx {
                kc,
                vc,
                kmask: mask,
                ..StreamCtx::default()
            })
            .collect();
        rt.execute_batched_into(&name, &xs, &weights, &streams, 4, &mut scratch, &mut outs)
            .unwrap();
        assert_eq!(outs.n, 3);
        for i in 0..n {
            let solo = rt
                .execute(
                    &name,
                    &[
                        Tensor::new(vec![1, r], xs[i * r..(i + 1) * r].to_vec()),
                        Tensor::new(vec![r, m.d], wq.clone()),
                        Tensor::new(vec![r, m.d], wk.clone()),
                        Tensor::new(vec![r, m.d], wv.clone()),
                        Tensor::new(vec![m.c, m.d], kvs[i].0.clone()),
                        Tensor::new(vec![m.c, m.d], kvs[i].1.clone()),
                        Tensor::new(vec![m.c], kvs[i].2.clone()),
                    ],
                )
                .unwrap();
            for k in 0..3 {
                assert_eq!(
                    &outs.out[k][i * m.d..(i + 1) * m.d],
                    solo[k].data.as_slice(),
                    "qkv output {k} stream {i} diverged"
                );
            }
        }
    }

    #[test]
    fn shape_validation_rejects_wrong_input() {
        let rt = rt();
        let m = rt.manifest.model("tiny").unwrap().clone();
        let r = m.d_buckets[0];
        let name = format!("projres_tiny_r{r}");
        let bad = Tensor::zeros(vec![1, 1]);
        assert!(rt.execute(&name, &[bad.clone(), bad.clone(), bad]).is_err());
    }

    #[test]
    fn executable_cache_reuses() {
        let rt = rt();
        let m = rt.manifest.model("tiny").unwrap().clone();
        let r = *m.h_buckets.last().unwrap();
        let name = format!("projres_tiny_r{r}");
        let a = Tensor::zeros(vec![m.t, r]);
        let w = Tensor::zeros(vec![r, m.d]);
        let res = Tensor::zeros(vec![m.t, m.d]);
        rt.execute(&name, &[a.clone(), w.clone(), res.clone()]).unwrap();
        let cached = rt.cached();
        rt.execute(&name, &[a, w, res]).unwrap();
        assert_eq!(rt.cached(), cached);
        assert!(rt.warmup("tiny").unwrap() >= 30);
        assert!(rt.cached() >= 30);
    }
}
