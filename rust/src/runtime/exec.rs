//! Host reference executor: the default runtime backend.
//!
//! Implements the exact stage semantics of `python/compile/kernels/ref.py`
//! in pure Rust, dispatching on the artifact `kind` recorded in the
//! manifest. f64 accumulation keeps dense outputs permutation-stable (the
//! engine's reorder tests compare outputs across different summation
//! orders at 1e-3 tolerance).

use std::collections::HashSet;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::model::ModelSpec;
use crate::runtime::{Manifest, Tensor};

/// Large-negative mask value (not -inf: keeps softmax finite) — mirrors
/// `ref.py::NEG_INF`.
const NEG_INF: f64 = -1e9;

/// Reference runtime with the same API as the PJRT backend.
pub struct XlaRuntime {
    pub manifest: Manifest,
    /// Names "compiled" so far (warmup/caching accounting parity with the
    /// PJRT backend's executable cache).
    compiled: Mutex<HashSet<String>>,
}

impl XlaRuntime {
    /// Open the artifact directory. If `manifest.tsv` exists it is loaded
    /// (so a PJRT-built artifact set drives the same shapes); otherwise the
    /// manifest is synthesized from the runnable model specs.
    pub fn open(artifact_dir: &Path) -> Result<Self> {
        let path = artifact_dir.join("manifest.tsv");
        let manifest = if path.exists() {
            Manifest::load(&path).with_context(|| format!("loading manifest from {path:?}"))?
        } else {
            Manifest::parse(&synthesized_manifest_tsv())?
        };
        Ok(Self {
            manifest,
            compiled: Mutex::new(HashSet::new()),
        })
    }

    pub fn platform(&self) -> String {
        "host-reference".to_string()
    }

    /// Pre-"compile" every artifact of a model (API parity; the reference
    /// executor has no real compile step).
    pub fn warmup(&self, model: &str) -> Result<usize> {
        let names: Vec<String> = self
            .manifest
            .artifacts
            .iter()
            .filter(|a| a.model == model)
            .map(|a| a.name.clone())
            .collect();
        let mut cache = self.compiled.lock().unwrap();
        for n in &names {
            cache.insert(n.clone());
        }
        Ok(names.len())
    }

    /// Number of distinct artifacts executed or warmed so far.
    pub fn cached(&self) -> usize {
        self.compiled.lock().unwrap().len()
    }

    /// Execute an artifact with the given inputs; validates shapes against
    /// the manifest.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let meta = self
            .manifest
            .artifact(name)
            .with_context(|| format!("unknown artifact {name}"))?
            .clone();
        anyhow::ensure!(
            inputs.len() == meta.inputs.len(),
            "{name}: expected {} inputs, got {}",
            meta.inputs.len(),
            inputs.len()
        );
        for (i, (t, spec)) in inputs.iter().zip(&meta.inputs).enumerate() {
            anyhow::ensure!(
                &t.dims == spec,
                "{name}: input {i} shape {:?} != manifest {:?}",
                t.dims,
                spec
            );
        }
        self.compiled.lock().unwrap().insert(name.to_string());
        let model = self
            .manifest
            .model(&meta.model)
            .with_context(|| format!("{name}: unknown model {}", meta.model))?;
        let out = match meta.kind.as_str() {
            "qkv_append" | "qkv_decode" => {
                let (xs, wq, wk, wv, kc, vc, mask) = (
                    &inputs[0], &inputs[1], &inputs[2], &inputs[3], &inputs[4], &inputs[5],
                    &inputs[6],
                );
                let t = xs.dims[0];
                let d = wq.dims[1];
                let c = kc.dims[0];
                let q = matmul(xs, wq);
                let k = matmul(xs, wk);
                let v = matmul(xs, wv);
                // keys/vals = concat(cache, new); mask = concat(mask, 1s).
                let mut keys = kc.data.clone();
                keys.extend_from_slice(&k.data);
                let mut vals = vc.data.clone();
                vals.extend_from_slice(&v.data);
                let mut full_mask = mask.data.clone();
                full_mask.extend(std::iter::repeat(1.0f32).take(t));
                let attn = mha_attention(&q.data, &keys, &vals, &full_mask, t, c + t, d, model.nh);
                vec![Tensor::new(vec![t, d], attn), k, v]
            }
            "gateup" | "gateup_dec" => {
                let gate = matmul(&inputs[0], &inputs[1]);
                let up = matmul(&inputs[0], &inputs[2]);
                let act: Vec<f32> = gate
                    .data
                    .iter()
                    .zip(&up.data)
                    .map(|(&g, &u)| (silu(g as f64) * u as f64) as f32)
                    .collect();
                vec![Tensor::new(gate.dims, act)]
            }
            "projres" | "projres_dec" => {
                let y = matmul(&inputs[0], &inputs[1]);
                let res = &inputs[2];
                let out: Vec<f32> = y.data.iter().zip(&res.data).map(|(&a, &b)| a + b).collect();
                vec![Tensor::new(res.dims.clone(), out)]
            }
            other => anyhow::bail!("{name}: unknown artifact kind {other}"),
        };
        anyhow::ensure!(
            out.len() == meta.outputs,
            "{name}: produced {} outputs, manifest says {}",
            out.len(),
            meta.outputs
        );
        Ok(out)
    }
}

/// `a[t,r] @ b[r,n]` with f64 accumulation.
fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (t, r) = (a.dims[0], a.dims[1]);
    let (rb, n) = (b.dims[0], b.dims[1]);
    assert_eq!(r, rb, "contraction mismatch {r} vs {rb}");
    let mut out = vec![0.0f32; t * n];
    for ti in 0..t {
        let mut acc = vec![0.0f64; n];
        let row = &a.data[ti * r..(ti + 1) * r];
        for (kk, &av) in row.iter().enumerate() {
            if av == 0.0 {
                continue; // zero-padded budget rows contribute nothing
            }
            let brow = &b.data[kk * n..(kk + 1) * n];
            let av = av as f64;
            for (j, &bv) in brow.iter().enumerate() {
                acc[j] += av * bv as f64;
            }
        }
        for (o, &v) in out[ti * n..(ti + 1) * n].iter_mut().zip(&acc) {
            *o = v as f32;
        }
    }
    Tensor::new(vec![t, n], out)
}

fn silu(x: f64) -> f64 {
    x / (1.0 + (-x).exp())
}

/// Multi-head attention of `t` query tokens over `s` key/value slots —
/// mirror of `ref.py::mha_attention` (max-subtracted softmax).
fn mha_attention(
    q: &[f32],
    keys: &[f32],
    vals: &[f32],
    mask: &[f32],
    t: usize,
    s: usize,
    d: usize,
    nh: usize,
) -> Vec<f32> {
    assert_eq!(d % nh, 0, "head split {d} % {nh}");
    let hd = d / nh;
    let scale = 1.0 / (hd as f64).sqrt();
    let mut out = vec![0.0f32; t * d];
    let mut scores = vec![0.0f64; s];
    for h in 0..nh {
        let off = h * hd;
        for ti in 0..t {
            let qrow = &q[ti * d + off..ti * d + off + hd];
            let mut max = f64::MIN;
            for (j, sc) in scores.iter_mut().enumerate() {
                let krow = &keys[j * d + off..j * d + off + hd];
                let dot: f64 = qrow
                    .iter()
                    .zip(krow)
                    .map(|(&a, &b)| a as f64 * b as f64)
                    .sum();
                let v = dot * scale + (1.0 - mask[j] as f64) * NEG_INF;
                *sc = v;
                max = max.max(v);
            }
            let mut denom = 0.0f64;
            for sc in scores.iter_mut() {
                *sc = (*sc - max).exp();
                denom += *sc;
            }
            let mut acc = vec![0.0f64; hd];
            for (j, &p) in scores.iter().enumerate() {
                let vrow = &vals[j * d + off..j * d + off + hd];
                let p = p / denom;
                for (a, &v) in acc.iter_mut().zip(vrow) {
                    *a += p * v as f64;
                }
            }
            for (e, &v) in acc.iter().enumerate() {
                out[ti * d + off + e] = v as f32;
            }
        }
    }
    out
}

// ------------------------------------------------- manifest synthesis

/// Round half-to-even (Python `round` semantics — the bucket grid depends
/// on it: `round(192*0.375/16) = round(4.5) = 4`).
fn round_half_even(x: f64) -> i64 {
    let floor = x.floor();
    let frac = x - floor;
    let f = floor as i64;
    if frac > 0.5 {
        f + 1
    } else if frac < 0.5 {
        f
    } else if f % 2 == 0 {
        f
    } else {
        f + 1
    }
}

/// Budget buckets over dim `n` — mirror of `python/compile/model.py
/// ModelDims.buckets`.
pub fn budget_buckets(n: usize) -> Vec<usize> {
    let fractions = [1.0, 0.75, 0.5, 0.375, 0.25];
    let mut out = Vec::new();
    for f in fractions {
        let r = (round_half_even(n as f64 * f / 16.0) * 16).max(16) as usize;
        let r = r.min(n);
        if !out.contains(&r) {
            out.push(r);
        }
    }
    out
}

/// Build the manifest TSV for all runnable models — the same rows
/// `python/compile/aot.py` writes, minus the (unneeded) HLO files.
pub fn synthesized_manifest_tsv() -> String {
    let mut tsv = String::new();
    for spec in [ModelSpec::tiny(), ModelSpec::small(), ModelSpec::base()] {
        let (name, d, h, t, c) = (
            spec.name.clone(),
            spec.d,
            spec.h,
            spec.tokens_per_frame,
            spec.cache_slots,
        );
        let d_buckets = budget_buckets(d);
        let h_buckets = budget_buckets(h);
        let list = |b: &[usize]| {
            b.iter()
                .map(|x| x.to_string())
                .collect::<Vec<_>>()
                .join(",")
        };
        tsv.push_str(&format!(
            "model\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\n",
            name,
            d,
            h,
            spec.nh,
            t,
            c,
            spec.layers,
            list(&d_buckets),
            list(&h_buckets)
        ));
        let shapes = |dims: &[Vec<usize>]| {
            dims.iter()
                .map(|s| {
                    if s.is_empty() {
                        "scalar".to_string()
                    } else {
                        s.iter()
                            .map(|x| x.to_string())
                            .collect::<Vec<_>>()
                            .join(",")
                    }
                })
                .collect::<Vec<_>>()
                .join(";")
        };
        let mut artifact =
            |kind: &str, r: usize, tt: usize, outputs: usize, inputs: &[Vec<usize>]| {
                let aname = Manifest::artifact_name(kind, &name, r);
                tsv.push_str(&format!(
                    "artifact\t{}\t{}.hlo.txt\t{}\t{}\t{}\t{}\t{}\t{}\n",
                    aname,
                    aname,
                    kind,
                    name,
                    r,
                    tt,
                    outputs,
                    shapes(inputs)
                ));
            };
        for &r in &d_buckets {
            for (tt, stage) in [(t, "qkv_append"), (1, "qkv_decode")] {
                artifact(
                    stage,
                    r,
                    tt,
                    3,
                    &[
                        vec![tt, r],
                        vec![r, d],
                        vec![r, d],
                        vec![r, d],
                        vec![c, d],
                        vec![c, d],
                        vec![c],
                    ],
                );
            }
            for (tt, stage) in [(t, "gateup"), (1, "gateup_dec")] {
                artifact(stage, r, tt, 1, &[vec![tt, r], vec![r, h], vec![r, h]]);
            }
        }
        let mut proj: Vec<usize> = d_buckets
            .iter()
            .chain(h_buckets.iter())
            .copied()
            .collect();
        proj.sort_unstable();
        proj.dedup();
        for &r in &proj {
            for (tt, stage) in [(t, "projres"), (1, "projres_dec")] {
                artifact(stage, r, tt, 1, &[vec![tt, r], vec![r, d], vec![tt, d]]);
            }
        }
    }
    tsv
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn rt() -> XlaRuntime {
        // Any directory without a manifest.tsv falls back to synthesis.
        XlaRuntime::open(&PathBuf::from("artifacts")).unwrap()
    }

    #[test]
    fn buckets_match_python_grid() {
        // Mirrors ModelDims.buckets incl. the round-half-even tie at
        // 192 * 0.375 / 16 = 4.5.
        assert_eq!(budget_buckets(64), vec![64, 48, 32, 16]);
        assert_eq!(budget_buckets(192), vec![192, 144, 96, 64, 48]);
        assert_eq!(budget_buckets(256), vec![256, 192, 128, 96, 64]);
        assert_eq!(budget_buckets(768), vec![768, 576, 384, 288, 192]);
    }

    #[test]
    fn opens_and_lists_manifest() {
        let rt = rt();
        assert!(rt.manifest.artifacts.len() >= 30);
        assert!(rt.manifest.model("tiny").is_some());
        assert!(rt.manifest.model("small").is_some());
        assert!(rt.manifest.model("base").is_some());
        assert_eq!(rt.platform(), "host-reference");
    }

    #[test]
    fn executes_projres_matches_host_matmul() {
        let rt = rt();
        let m = rt.manifest.model("tiny").unwrap().clone();
        let r = m.d_buckets[0];
        let name = format!("projres_tiny_r{r}");
        let t = m.t;
        let mut rng = crate::rng::Rng::new(3);
        let a = Tensor::new(
            vec![t, r],
            (0..t * r).map(|_| rng.normal() as f32 * 0.3).collect(),
        );
        let w = Tensor::new(
            vec![r, m.d],
            (0..r * m.d).map(|_| rng.normal() as f32 * 0.3).collect(),
        );
        let res = Tensor::new(
            vec![t, m.d],
            (0..t * m.d).map(|_| rng.normal() as f32 * 0.3).collect(),
        );
        let out = rt
            .execute(&name, &[a.clone(), w.clone(), res.clone()])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].dims, vec![t, m.d]);
        for ti in 0..t {
            for j in 0..m.d {
                let mut acc = res.data[ti * m.d + j] as f64;
                for k in 0..r {
                    acc += a.data[ti * r + k] as f64 * w.data[k * m.d + j] as f64;
                }
                let got = out[0].data[ti * m.d + j] as f64;
                assert!(
                    (got - acc).abs() < 1e-3,
                    "mismatch at ({ti},{j}): {got} vs {acc}"
                );
            }
        }
    }

    #[test]
    fn gateup_matches_silu_formula() {
        let rt = rt();
        let m = rt.manifest.model("tiny").unwrap().clone();
        let r = *m.d_buckets.last().unwrap();
        let name = format!("gateup_dec_tiny_r{r}");
        let xs = Tensor::new(vec![1, r], (0..r).map(|i| 0.01 * i as f32).collect());
        let wg = Tensor::new(vec![r, m.h], vec![0.02; r * m.h]);
        let wu = Tensor::new(vec![r, m.h], vec![0.03; r * m.h]);
        let out = rt.execute(&name, &[xs.clone(), wg, wu]).unwrap();
        let g: f64 = xs.data.iter().map(|&x| x as f64 * 0.02).sum();
        let u: f64 = xs.data.iter().map(|&x| x as f64 * 0.03).sum();
        let want = (g / (1.0 + (-g).exp())) * u;
        assert!(
            (out[0].data[0] as f64 - want).abs() < 1e-4,
            "{} vs {want}",
            out[0].data[0]
        );
    }

    #[test]
    fn masked_cache_slots_are_ignored() {
        let rt = rt();
        let m = rt.manifest.model("tiny").unwrap().clone();
        let r = m.d_buckets[0];
        let name = format!("qkv_append_tiny_r{r}");
        let mut rng = crate::rng::Rng::new(7);
        let xs = Tensor::new(
            vec![m.t, r],
            (0..m.t * r).map(|_| rng.normal() as f32 * 0.2).collect(),
        );
        let w = |seed: u64| {
            let mut rng = crate::rng::Rng::new(seed);
            Tensor::new(
                vec![r, m.d],
                (0..r * m.d).map(|_| rng.normal() as f32 * 0.2).collect(),
            )
        };
        let (wq, wk, wv) = (w(1), w(2), w(3));
        let mask = Tensor::zeros(vec![m.c]);
        let clean = rt
            .execute(
                &name,
                &[
                    xs.clone(),
                    wq.clone(),
                    wk.clone(),
                    wv.clone(),
                    Tensor::zeros(vec![m.c, m.d]),
                    Tensor::zeros(vec![m.c, m.d]),
                    mask.clone(),
                ],
            )
            .unwrap();
        // Garbage in masked cache slots must not change the output.
        let garbage = Tensor::new(vec![m.c, m.d], vec![7.5; m.c * m.d]);
        let dirty = rt
            .execute(
                &name,
                &[xs, wq, wk, wv, garbage.clone(), garbage, mask],
            )
            .unwrap();
        for (a, b) in clean[0].data.iter().zip(&dirty[0].data) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn shape_validation_rejects_wrong_input() {
        let rt = rt();
        let m = rt.manifest.model("tiny").unwrap().clone();
        let r = m.d_buckets[0];
        let name = format!("projres_tiny_r{r}");
        let bad = Tensor::zeros(vec![1, 1]);
        assert!(rt.execute(&name, &[bad.clone(), bad.clone(), bad]).is_err());
    }

    #[test]
    fn executable_cache_reuses() {
        let rt = rt();
        let m = rt.manifest.model("tiny").unwrap().clone();
        let r = *m.h_buckets.last().unwrap();
        let name = format!("projres_tiny_r{r}");
        let a = Tensor::zeros(vec![m.t, r]);
        let w = Tensor::zeros(vec![r, m.d]);
        let res = Tensor::zeros(vec![m.t, m.d]);
        rt.execute(&name, &[a.clone(), w.clone(), res.clone()]).unwrap();
        let cached = rt.cached();
        rt.execute(&name, &[a, w, res]).unwrap();
        assert_eq!(rt.cached(), cached);
        assert!(rt.warmup("tiny").unwrap() >= 30);
        assert!(rt.cached() >= 30);
    }
}
